//! Quickstart: load the AOT artifacts, pretrain LeNet on synthetic MNIST,
//! and run a short ReLeQ search that proposes per-layer bitwidths.
//!
//!     cargo run --release --example quickstart
//!
//! This exercises the full three-layer stack: the Pallas fused
//! quantize+matmul kernel (Layer 1) inside the lowered train/eval HLO
//! (Layer 2), driven by the Rust coordinator (Layer 3).

use std::sync::Arc;

use anyhow::Result;
use releq::coordinator::{SearchConfig, Searcher};
use releq::metrics::sparkline;
use releq::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    let dir = releq::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::new(dir)?);
    let net = manifest.network("lenet")?;

    println!("== ReLeQ quickstart: {} (L={} layers, P={} params) ==", net.name, net.l, net.p);

    let mut cfg = SearchConfig::default();
    cfg.episodes = 120;
    cfg.env.pretrain_steps = 200;
    cfg.env.retrain_steps = 3;
    cfg.seed = 11;

    let mut searcher = Searcher::new(engine.clone(), &manifest, net, cfg)?;
    println!(
        "pretrained full-precision accuracy: {:.3}",
        searcher.env.acc_fullp
    );

    let result = searcher.run()?;
    println!("episodes run        : {}", result.episodes_run);
    println!("reward curve        : {}", sparkline(&result.log.rewards(), 60));
    println!("state-of-acc curve  : {}", sparkline(&result.log.state_accs(), 60));
    println!("state-of-quant curve: {}", sparkline(&result.log.state_qs(), 60));
    println!("chosen bitwidths    : {:?}", result.bits);
    println!("average bitwidth    : {:.2}", result.avg_bits);
    println!(
        "accuracy: full-precision {:.3} -> quantized {:.3} (loss {:.2}%)",
        result.acc_fullp, result.acc_final, result.acc_loss_pct
    );
    println!(
        "env stats: {:?} (cache {} entries)",
        searcher.env.stats(),
        searcher.env.cache_len()
    );
    Ok(())
}
