//! Enumerate LeNet's quantization design space (7^4 = 2401 assignments, as in
//! the paper's Fig 6) and print the Pareto frontier, marking where ReLeQ's
//! published solution {2,2,3,2} lands.
//!
//!     cargo run --release --example pareto_frontier [-- --net lenet --samples 2500]

use std::sync::Arc;

use anyhow::Result;
use releq::baselines::paper_releq_solution;
use releq::coordinator::{EnvConfig, QuantEnv};
use releq::pareto;
use releq::runtime::{Engine, Manifest};
use releq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args());
    let net_name = args.str_of("net", "lenet");
    let dir = releq::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::new(dir)?);
    let net = manifest.network(&net_name)?;

    let mut env_cfg = EnvConfig::default();
    env_cfg.pretrain_steps = releq::config::preset(&net_name).env.pretrain_steps;
    // one shared-core env: the per-shard workers all query this pretrained
    // snapshot (one pretrain total), and the paper-solution probe below
    // reuses its warm memo
    let env = QuantEnv::new(
        engine.clone(),
        net,
        manifest.bits_max,
        manifest.fp_bits,
        env_cfg,
    )?;
    println!("{net_name}: Acc_FullP {:.4}", env.acc_fullp);

    let mut cfg = pareto::EnumConfig::default();
    cfg.max_points = args.usize_of("samples", 2500);
    let space = pareto::space_size(&cfg, net.l);
    let shards = args.usize_of("shards", releq::parallel::default_shards(cfg.max_points));
    println!(
        "design space: {space} assignments (bits {}..{}); {shards} shard(s)",
        cfg.min_bits, cfg.max_bits
    );

    let t0 = std::time::Instant::now();
    let (points, exhaustive) = pareto::enumerate_sharded(&env, &cfg, shards)?;
    println!(
        "evaluated {} points ({}) in {:.1}s",
        points.len(),
        if exhaustive { "exhaustive" } else { "sampled" },
        t0.elapsed().as_secs_f64()
    );

    let frontier = pareto::pareto_frontier(&points);
    println!("\nPareto frontier ({} points):", frontier.len());
    println!("{:>8} {:>9}  bits", "state_q", "state_acc");
    for &i in &frontier {
        println!("{:>8.3} {:>9.3}  {:?}", points[i].state_q, points[i].state_acc, points[i].bits);
    }

    if let Some(bits) = paper_releq_solution(&net_name) {
        if bits.len() == net.l {
            let sa = env.state_acc(&bits)?;
            let sq = env.state_q(&bits);
            println!("\npaper's ReLeQ solution {bits:?}: state_q {sq:.3}, state_acc {sa:.3}");
        }
    }
    Ok(())
}
