//! END-TO-END driver (deliverable (b) / system-prompt requirement): run the
//! complete ReLeQ system on a real small workload and report the paper's
//! headline metrics.
//!
//!     cargo run --release --example e2e_releq [-- --net lenet --episodes 300]
//!
//! Pipeline exercised, proving all three layers compose:
//!   1. synthetic dataset generation (data substrate)
//!   2. full-precision pretraining through the AOT train artifact
//!      (Layer-2 JAX model wrapping the Layer-1 Pallas fused qmatmul kernel)
//!   3. the ReLeQ search: LSTM-PPO agent (AOT HLO) + quantization environment
//!      + asymmetric reward (Layer-3 coordinator)
//!   4. final long retrain of the converged bitwidths
//!   5. hardware projection on the Stripes + bit-serial CPU simulators
//!
//! The reward/accuracy learning curves are logged per episode to
//! results/e2e_<net>.csv and summarized here — EXPERIMENTS.md records a run.

use std::rc::Rc;

use anyhow::Result;
use releq::config;
use releq::coordinator::Searcher;
use releq::metrics::{sparkline, SearchLog};
use releq::runtime::{Engine, Manifest};
use releq::sim::{Stripes, StripesConfig, TvmCpu, TvmCpuConfig};
use releq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args());
    let net_name = args.str_of("net", "lenet");
    let dir = releq::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Rc::new(Engine::new(dir)?);
    let net = manifest.network(&net_name)?;

    let mut cfg = config::resolve(&net_name, &args)?;
    if let Some(e) = args.opt_str("episodes") {
        cfg.episodes = e.parse()?;
    }

    println!("=== ReLeQ end-to-end: {} (L={}, P={}, dataset {}) ===",
             net.name, net.l, net.p, net.dataset);
    let t0 = std::time::Instant::now();
    let mut searcher = Searcher::new(engine.clone(), &manifest, net, cfg)?;
    let t_pre = t0.elapsed().as_secs_f64();
    println!("[1] pretrained: Acc_FullP = {:.4} ({t_pre:.1}s)", searcher.env.acc_fullp);

    let result = searcher.run()?;
    let t_search = t0.elapsed().as_secs_f64() - t_pre;
    println!("[2] search done: {} episodes in {:.1}s", result.episodes_run, t_search);
    let ma = |s: &[f64]| SearchLog::moving_average(s, 20);
    println!("    reward   : {}", sparkline(&ma(&result.log.rewards()), 64));
    println!("    state_acc: {}", sparkline(&ma(&result.log.state_accs()), 64));
    println!("    state_q  : {}", sparkline(&ma(&result.log.state_qs()), 64));

    println!("[3] solution: bits {:?} (avg {:.2})", result.bits, result.avg_bits);
    println!(
        "    accuracy: fp {:.4} -> quantized {:.4} (loss {:.2}%, paper target < 0.3%)",
        result.acc_fullp, result.acc_final, result.acc_loss_pct
    );

    let stripes = Stripes::new(StripesConfig::default());
    let (sp, en) = stripes.speedup_energy(net, &result.bits);
    let tvm = TvmCpu::new(TvmCpuConfig::default());
    let cpu = tvm.speedup(net, &result.bits);
    println!("[4] hardware projection vs 8-bit: Stripes {sp:.2}x speedup / {en:.2}x energy; CPU {cpu:.2}x");

    std::fs::create_dir_all("results")?;
    result
        .log
        .write_csv(std::path::Path::new(&format!("results/e2e_{net_name}.csv")))?;
    println!(
        "[5] env: {} evals ({} cache hits), {} train + {} eval PJRT execs; log -> results/e2e_{net_name}.csv",
        searcher.env.stats.evals,
        searcher.env.stats.cache_hits,
        searcher.env.stats.train_execs,
        searcher.env.stats.eval_execs
    );
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
