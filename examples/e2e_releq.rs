//! END-TO-END driver (deliverable (b) / system-prompt requirement): run the
//! complete ReLeQ system on real small workloads and report the paper's
//! headline metrics.
//!
//!     cargo run --release --example e2e_releq [-- --net lenet --episodes 300]
//!     cargo run --release --example e2e_releq -- --nets lenet,simplenet,svhn10
//!     cargo run --release --example e2e_releq -- --net lenet --rollout batched
//!
//! Pipeline exercised, proving all three layers compose:
//!   1. synthetic dataset generation (data substrate)
//!   2. full-precision pretraining through the AOT train artifact
//!      (Layer-2 JAX model wrapping the Layer-1 Pallas fused qmatmul kernel)
//!   3. the ReLeQ search: LSTM-PPO agent (AOT HLO) + quantization environment
//!      + asymmetric reward (Layer-3 coordinator)
//!   4. final long retrain of the converged bitwidths
//!   5. hardware projection on the Stripes + bit-serial CPU simulators
//!
//! With `--nets a,b,c` the per-network pipelines fan out across shard
//! threads over the shared `Send + Sync` engine (EXPERIMENTS.md §Perf); the
//! reports print in the order the networks were listed, not completion
//! order. The reward/accuracy learning curves are logged per episode to
//! results/e2e_<net>.csv.

use std::sync::Arc;

use anyhow::Result;
use releq::config;
use releq::coordinator::Searcher;
use releq::metrics::{sparkline, SearchLog};
use releq::parallel;
use releq::runtime::{Engine, Manifest};
use releq::sim::{Stripes, StripesConfig, TvmCpu, TvmCpuConfig};
use releq::util::cli::Args;

/// One network's full pipeline. Returns the report as a string so the
/// sharded driver can print merged output deterministically.
fn run_one(engine: &Arc<Engine>, manifest: &Manifest, net_name: &str,
           args: &Args) -> Result<String> {
    use std::fmt::Write;
    let net = manifest.network(net_name)?;
    // full resolution (preset -> --config TOML -> CLI flags, --episodes and
    // --rollout included), same as the single-net path always did
    let cfg = config::resolve(net_name, args)?;

    let mut out = String::new();
    writeln!(out, "=== ReLeQ end-to-end: {} (L={}, P={}, dataset {}) ===",
             net.name, net.l, net.p, net.dataset)?;
    let t0 = std::time::Instant::now();
    let mut searcher = Searcher::new(engine.clone(), manifest, net, cfg)?;
    let t_pre = t0.elapsed().as_secs_f64();
    writeln!(out, "[1] pretrained: Acc_FullP = {:.4} ({t_pre:.1}s)", searcher.env.acc_fullp)?;

    let result = searcher.run()?;
    let t_search = t0.elapsed().as_secs_f64() - t_pre;
    writeln!(out, "[2] search done: {} episodes in {:.1}s", result.episodes_run, t_search)?;
    let ma = |s: &[f64]| SearchLog::moving_average(s, 20);
    writeln!(out, "    reward   : {}", sparkline(&ma(&result.log.rewards()), 64))?;
    writeln!(out, "    state_acc: {}", sparkline(&ma(&result.log.state_accs()), 64))?;
    writeln!(out, "    state_q  : {}", sparkline(&ma(&result.log.state_qs()), 64))?;

    writeln!(out, "[3] solution: bits {:?} (avg {:.2})", result.bits, result.avg_bits)?;
    writeln!(
        out,
        "    accuracy: fp {:.4} -> quantized {:.4} (loss {:.2}%, paper target < 0.3%)",
        result.acc_fullp, result.acc_final, result.acc_loss_pct
    )?;

    let stripes = Stripes::new(StripesConfig::default());
    let (sp, en) = stripes.speedup_energy(net, &result.bits);
    let tvm = TvmCpu::new(TvmCpuConfig::default());
    let cpu = tvm.speedup(net, &result.bits);
    writeln!(
        out,
        "[4] hardware projection vs 8-bit: Stripes {sp:.2}x speedup / {en:.2}x energy; CPU {cpu:.2}x"
    )?;

    std::fs::create_dir_all("results")?;
    result
        .log
        .write_csv(std::path::Path::new(&format!("results/e2e_{net_name}.csv")))?;
    let stats = searcher.env.stats();
    writeln!(
        out,
        "[5] env: {} evals ({} cache hits), {} train + {} eval PJRT execs; \
         agent: {} acts / {} batched acts / {} param uploads; log -> results/e2e_{net_name}.csv",
        stats.evals,
        stats.cache_hits,
        stats.train_execs,
        stats.eval_execs,
        searcher.agent.act_calls,
        searcher.agent.act_batch_calls,
        searcher.agent.param_uploads
    )?;
    writeln!(out, "wall time: {:.1}s", t0.elapsed().as_secs_f64())?;
    Ok(out)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args());
    let dir = releq::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::new(dir)?);

    // multi-network mode: fan the per-network pipelines across shard threads
    let nets: Vec<String> = match args.opt_str("nets") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => vec![args.str_of("net", "lenet")],
    };

    let t0 = std::time::Instant::now();
    if nets.len() == 1 {
        print!("{}", run_one(&engine, &manifest, &nets[0], &args)?);
        return Ok(());
    }
    let n_nets = nets.len();
    let shards = parallel::default_shards(n_nets);
    println!("running {n_nets} networks on {shards} shard(s): {nets:?}\n");
    let chunks = parallel::chunk_evenly(nets, shards);
    let reports = parallel::run_sharded(chunks, |_, chunk| {
        chunk
            .iter()
            .map(|net_name| run_one(&engine, &manifest, net_name, &args))
            .collect::<Result<Vec<String>>>()
    })?;
    for r in reports.into_iter().flatten() {
        println!("{r}");
    }
    println!("total wall time ({n_nets} networks): {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
