//! ReLeQ vs the ADMM bitwidth-selection baseline (paper §4.6, Table 4).
//! Runs our ADMM selector on the pretrained weights, compares its solution
//! against ReLeQ's on accuracy + both hardware simulators.
//!
//!     cargo run --release --example admm_compare [-- --net lenet]

use std::sync::Arc;

use anyhow::Result;
use releq::baselines::{paper_releq_solution, paper_solution, AdmmConfig, AdmmSelector};
use releq::coordinator::{EnvConfig, QuantEnv};
use releq::runtime::{Engine, Manifest};
use releq::sim::{Stripes, StripesConfig, TvmCpu, TvmCpuConfig};
use releq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args());
    let net_name = args.str_of("net", "lenet");
    let manifest = Manifest::load(&releq::artifacts_dir())?;
    let engine = Arc::new(Engine::new(releq::artifacts_dir())?);
    let net = manifest.network(&net_name)?;

    let mut env_cfg = EnvConfig::default();
    env_cfg.pretrain_steps = releq::config::preset(&net_name).env.pretrain_steps;
    let env = QuantEnv::new(engine, net, manifest.bits_max, manifest.fp_bits, env_cfg)?;

    let releq_bits = paper_releq_solution(&net_name)
        .filter(|b| b.len() == net.l)
        .unwrap_or_else(|| vec![4; net.l]);
    let admm_paper = paper_solution(&net_name);
    let target = args.f64_of(
        "target-bits",
        admm_paper
            .as_ref()
            .map(|b| b.iter().map(|&x| x as f64).sum::<f64>() / b.len() as f64)
            .unwrap_or(5.0),
    );
    let admm_ours = AdmmSelector::new(AdmmConfig::default()).select(net, &env.pretrained, target);

    let stripes = Stripes::new(StripesConfig::default());
    let tvm = TvmCpu::new(TvmCpuConfig::default());
    println!("{net_name}: Acc_FullP {:.4}\n", env.acc_fullp);
    println!(
        "{:<22} {:<20} {:>9} {:>8} {:>9} {:>9}",
        "method", "bits", "acc", "cpu", "stripes", "energy"
    );
    let mut rows = vec![
        ("ReLeQ (paper)".to_string(), releq_bits.clone()),
        ("ADMM (ours)".to_string(), admm_ours),
    ];
    if let Some(b) = admm_paper {
        rows.push(("ADMM (paper)".to_string(), b));
    }
    let mut first: Option<(f64, f64, f64)> = None;
    for (name, bits) in rows {
        let acc = env.retrain_and_eval(&bits, env.cfg.long_retrain_steps)?;
        let cpu = tvm.speedup(net, &bits);
        let (sp, en) = stripes.speedup_energy(net, &bits);
        println!(
            "{:<22} {:<20} {:>9.4} {:>7.2}x {:>8.2}x {:>8.2}x",
            name,
            format!("{bits:?}"),
            acc,
            cpu,
            sp,
            en
        );
        if let Some((c0, s0, e0)) = first {
            println!(
                "{:<22} {:<20} {:>9} {:>7.2}x {:>8.2}x {:>8.2}x   <- ReLeQ advantage",
                "", "", "", c0 / cpu, s0 / sp, e0 / en
            );
        } else {
            first = Some((cpu, sp, en));
        }
    }
    println!("\npaper Table 4: ReLeQ over ADMM = 1.20-1.42x (TVM), 1.22-1.86x (Stripes), 1.25-1.87x (energy)");
    Ok(())
}
