//! Minimal client for the `releq serve` daemon: submit a job, poll the live
//! tail, print the solution, and demonstrate the archive hit on resubmit.
//!
//! Usage (daemon first: `releq serve --addr 127.0.0.1:7463`):
//!   cargo run --example serve_client -- [addr] [net] [episodes]
//! Defaults: 127.0.0.1:7463 lenet 48

use releq::serve::http::request;
use releq::util::json::Json;

fn submit(addr: &str, net: &str, episodes: usize) -> u64 {
    let body = Json::parse(&format!(
        r#"{{"net": "{net}", "config": {{"episodes": {episodes}, "rollout": "batched"}}, "deadline_ms": 1800000}}"#
    ))
    .unwrap();
    let (status, resp) = request(addr, "POST", "/v1/jobs", Some(&body)).unwrap();
    assert!(status == 200 || status == 202, "submit failed ({status}): {}", resp.dump());
    println!("submitted job {} (status {}, source {})", resp.u("id"), resp.s("status"), resp.s("source"));
    resp.u("id") as u64
}

fn wait(addr: &str, id: u64) -> Json {
    loop {
        let (status, j) = request(addr, "GET", &format!("/v1/jobs/{id}"), None).unwrap();
        assert_eq!(status, 200, "poll failed: {}", j.dump());
        let state = j.s("status").to_string();
        println!(
            "job {id}: {state}, episode {}/{}",
            j.u("episodes_run"),
            j.u("episodes_total")
        );
        match state.as_str() {
            "done" => {
                let (rs, result) = request(addr, "GET", &format!("/v1/jobs/{id}/result"), None).unwrap();
                assert_eq!(rs, 200, "result fetch failed: {}", result.dump());
                return result;
            }
            "failed" | "cancelled" => panic!("job {id} ended as {state}: {}", j.dump()),
            _ => std::thread::sleep(std::time::Duration::from_millis(500)),
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let addr = args.get(1).map(String::as_str).unwrap_or("127.0.0.1:7463").to_string();
    let net = args.get(2).map(String::as_str).unwrap_or("lenet").to_string();
    let episodes: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(48);

    let id = submit(&addr, &net, episodes);
    let result = wait(&addr, id);
    println!(
        "{net}: bits {:?} (avg {:.2}), acc {:.4} (loss {:.2}%), reward {:.3}, {} pareto points",
        result.req("bits").as_arr().unwrap().iter().map(|b| b.as_usize().unwrap()).collect::<Vec<_>>(),
        result.f("avg_bits"),
        result.f("acc_final"),
        result.f("acc_loss_pct"),
        result.f("reward"),
        result.req("pareto").as_arr().unwrap().len(),
    );

    // identical resubmission: answered from the archive, zero new evals
    let id2 = submit(&addr, &net, episodes);
    let (s2, j2) = request(&addr, "GET", &format!("/v1/jobs/{id2}"), None).unwrap();
    assert_eq!(s2, 200);
    println!("resubmit: job {id2} status {} source {}", j2.s("status"), j2.s("source"));

    let (ss, stats) = request(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(ss, 200);
    println!("stats: {}", stats.dump());
}
