use std::sync::Arc;
use releq::coordinator::{EnvConfig, QuantEnv};
use releq::runtime::{Engine, Manifest};
fn main() {
    let manifest = Manifest::load(&releq::artifacts_dir()).unwrap();
    let engine = Arc::new(Engine::new(releq::artifacts_dir()).unwrap());
    let net = manifest.network("resnet20").unwrap();
    let mut cfg = EnvConfig::default();
    cfg.pretrain_steps = 60;
    cfg.retrain_steps = 10;
    let env = QuantEnv::new(engine, net, manifest.bits_max, manifest.fp_bits, cfg).unwrap();
    // accuracy_unfused is memoized now (PR 4): give each probe branch a
    // disjoint set of bits vectors so both time real executions, not hits
    for (name, fused, base) in [("unfused", false, 0usize), ("fused", true, 5)] {
        let t0 = std::time::Instant::now();
        let n = 5;
        for j in 0..n {
            let i = base + j;
            let mut bits = vec![8u32; net.l];
            bits[i % net.l] = 3 + (i as u32 % 4);
            bits[(i + 3) % net.l] = 2 + (i as u32 % 5);
            let _ = if fused { env.accuracy(&bits).unwrap() } else { env.accuracy_unfused(&bits).unwrap() };
        }
        println!("resnet20 accuracy query {name}: {:.0} ms/query", t0.elapsed().as_secs_f64() * 1000.0 / n as f64);
    }
}
