use std::sync::Arc;
use releq::coordinator::{EnvConfig, QuantEnv};
use releq::runtime::{Engine, Manifest};
fn main() {
    let manifest = Manifest::load(&releq::artifacts_dir()).unwrap();
    let engine = Arc::new(Engine::new(releq::artifacts_dir()).unwrap());
    let net = manifest.network("resnet20").unwrap();
    let mut cfg = EnvConfig::default();
    cfg.pretrain_steps = 60;
    cfg.retrain_steps = 10;
    let env = QuantEnv::new(engine, net, manifest.bits_max, manifest.fp_bits, cfg).unwrap();
    for (name, fused) in [("unfused", false), ("fused", true)] {
        let t0 = std::time::Instant::now();
        let n = 5;
        for i in 0..n {
            let mut bits = vec![8u32; net.l];
            bits[i % net.l] = 3 + (i as u32 % 4);
            bits[(i + 3) % net.l] = 2 + (i as u32 % 5);
            let _ = if fused { env.accuracy(&bits).unwrap() } else { env.accuracy_unfused(&bits).unwrap() };
        }
        println!("resnet20 accuracy query {name}: {:.0} ms/query", t0.elapsed().as_secs_f64() * 1000.0 / n as f64);
    }
}
