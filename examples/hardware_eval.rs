//! Project ReLeQ solutions onto the two hardware substrates (Fig 8 / Fig 9):
//! the Stripes bit-serial accelerator and the TVM-style bit-serial CPU.
//! Uses the paper's published bitwidths (no search run required).
//!
//!     cargo run --release --example hardware_eval

use anyhow::Result;
use releq::baselines::paper_releq_solution;
use releq::runtime::Manifest;
use releq::sim::{gmean, Stripes, StripesConfig, TvmCpu, TvmCpuConfig};

fn main() -> Result<()> {
    let manifest = Manifest::load(&releq::artifacts_dir())?;
    let stripes = Stripes::new(StripesConfig::default());
    let tvm = TvmCpu::new(TvmCpuConfig::default());

    println!(
        "{:<11} {:>8} {:>14} {:>14} {:>12}",
        "network", "avg bits", "CPU speedup", "Stripes speed", "Stripes energy"
    );
    let (mut cpus, mut sps, mut ens) = (vec![], vec![], vec![]);
    for net in &manifest.networks {
        let Some(bits) = paper_releq_solution(&net.name) else { continue };
        if bits.len() != net.l {
            continue;
        }
        let avg = bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64;
        let cpu = tvm.speedup(net, &bits);
        let (sp, en) = stripes.speedup_energy(net, &bits);
        println!("{:<11} {:>8.2} {:>13.2}x {:>13.2}x {:>11.2}x", net.name, avg, cpu, sp, en);
        cpus.push(cpu);
        sps.push(sp);
        ens.push(en);
    }
    println!(
        "{:<11} {:>8} {:>13.2}x {:>13.2}x {:>11.2}x",
        "gmean", "", gmean(&cpus), gmean(&sps), gmean(&ens)
    );
    println!("\npaper: 2.2x CPU (Fig 8); 2.0x speedup / 2.7x energy on Stripes (Fig 9)");

    // per-layer breakdown for one network, showing where the cycles go
    let net = manifest.network("lenet")?;
    let bits = paper_releq_solution("lenet").unwrap();
    let report = stripes.simulate(net, &bits);
    println!("\nlenet per-layer Stripes breakdown at {bits:?}:");
    println!("{:<8} {:>5} {:>12} {:>12}", "layer", "bits", "cycles", "energy(pJ)");
    for l in &report.layers {
        println!("{:<8} {:>5} {:>12.0} {:>12.0}", l.name, l.bits, l.cycles, l.energy_pj);
    }
    Ok(())
}
