"""AOT path tests: HLO-text lowering of every artifact entry point, manifest
integrity, and executability of the lowered modules on the CPU PJRT client
(the exact compile path the Rust runtime uses)."""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import agent as A
from compile import models, train
from compile.hlo import to_hlo_text


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_text(fn, args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def test_lenet_train_lowers_and_is_parseable():
    apply_fn, init_fn, b = models.build("lenet")
    _, step, _ = train.make_fns(apply_fn, init_fn)
    P, L = b.param_count, len(b.layers)
    text = lower_text(step, (f32(P), f32(P), f32(8, 16, 16, 1), f32(8), f32(L), f32()))
    assert "HloModule" in text
    assert "ENTRY" in text
    # no serialized-proto path anywhere (the interchange gotcha)
    assert len(text) > 1000


def test_agent_act_lowers():
    act = A.make_act(True)
    P = A.param_count(True)
    text = lower_text(act, (f32(P), f32(A.STATE_DIM), f32(A.HIDDEN), f32(A.HIDDEN)))
    assert "HloModule" in text


@pytest.mark.parametrize("rec", [True, False])
def test_agent_act_batch_lowers(rec):
    """The lockstep hot-path artifact: vmapped act over B lanes."""
    act_batch = A.make_act_batch(rec)
    P = A.param_count(rec)
    B = 8
    text = lower_text(
        act_batch, (f32(P), f32(B, A.STATE_DIM), f32(B, A.HIDDEN), f32(B, A.HIDDEN)))
    assert "HloModule" in text


def test_batched_retrain_eval_lowers():
    """The megabatch accuracy evaluator: vmapped fused retrain+eval over K
    candidate bits lanes (tiny shapes — lowering only)."""
    apply_fn, init_fn, b = models.build("lenet")
    P, L = b.param_count, len(b.layers)
    K, N, BATCH, EB = 3, 32, 8, 16
    batched = train.make_batched_retrain_eval(apply_fn, init_fn, 2, BATCH)
    text = lower_text(
        batched,
        (f32(P), f32(P), f32(N, 16, 16, 1), f32(N), f32(K), f32(K, L), f32(),
         f32(EB, 16, 16, 1), f32(EB)))
    assert "HloModule" in text


def test_batched_retrain_eval_matches_scalar_lanes():
    """Lane i of the vmapped evaluator must reproduce the scalar fused
    artifact's (loss, n_correct) for the same (cursor, bits) — the contract
    the Rust memo relies on for schedule-independent cached values
    (n_correct is an integer count, so it must match exactly)."""
    apply_fn, init_fn, b = models.build("lenet")
    P, L = b.param_count, len(b.layers)
    K, N, BATCH, EB = 4, 32, 8, 16
    rng = np.random.default_rng(7)
    params = jnp.asarray(rng.normal(0, 0.1, P), jnp.float32)
    mom = jnp.zeros(P, jnp.float32)
    tx = jnp.asarray(rng.normal(0, 1, (N, 16, 16, 1)), jnp.float32)
    ty = jnp.asarray(rng.integers(0, b.num_classes, N), jnp.float32)
    vx = jnp.asarray(rng.normal(0, 1, (EB, 16, 16, 1)), jnp.float32)
    vy = jnp.asarray(rng.integers(0, b.num_classes, EB), jnp.float32)
    cursors = jnp.asarray([0.0, 1.0, 3.0, 1.0], jnp.float32)
    bits = jnp.asarray(
        rng.integers(2, 9, (K, L)), jnp.float32).at[3].set(8.0)
    lr = jnp.float32(0.05)

    fused = jax.jit(train.make_fused_retrain_eval(apply_fn, init_fn, 2, BATCH))
    batched = jax.jit(train.make_batched_retrain_eval(apply_fn, init_fn, 2, BATCH))
    bl, bc = batched(params, mom, tx, ty, cursors, bits, lr, vx, vy)
    for i in range(K):
        sl, sc = fused(params, mom, tx, ty, cursors[i], bits[i], lr, vx, vy)
        assert float(sc) == float(bc[i]), f"lane {i} n_correct diverged"
        np.testing.assert_allclose(float(sl), float(bl[i]), rtol=1e-6)


def test_fused_retrain_eval_matches_per_step_path():
    """The fused monolith must reproduce the per-step program exactly on
    n_correct: the Rust runtime memoizes `accuracy_unfused` (per-step
    train_step executions + evaluate) into the same cache the fused and
    batched paths read, so a divergence here would let an unfused probe
    poison fused callers sharing one env core. n_correct is an argmax-match
    count, which is what makes exact agreement achievable across the two
    separately compiled programs. (The compiled-artifact version of this
    tripwire is rust/tests/eval_batch_parity.rs::
    unfused_path_matches_fused_bit_identical — artifact-gated; this test is
    the one that runs in CI.)"""
    apply_fn, init_fn, b = models.build("lenet")
    P = b.param_count
    L = len(b.layers)
    K_STEPS, N, BATCH, EB = 3, 32, 8, 16
    rng = np.random.default_rng(11)
    params = jnp.asarray(rng.normal(0, 0.1, P), jnp.float32)
    mom = jnp.zeros(P, jnp.float32)
    tx = jnp.asarray(rng.normal(0, 1, (N, 16, 16, 1)), jnp.float32)
    ty = jnp.asarray(rng.integers(0, b.num_classes, N), jnp.float32)
    vx = jnp.asarray(rng.normal(0, 1, (EB, 16, 16, 1)), jnp.float32)
    vy = jnp.asarray(rng.integers(0, b.num_classes, EB), jnp.float32)
    lr = jnp.float32(0.05)

    _, train_step, evaluate = train.make_fns(apply_fn, init_fn)
    train_step = jax.jit(train_step)
    evaluate = jax.jit(evaluate)
    fused = jax.jit(train.make_fused_retrain_eval(apply_fn, init_fn, K_STEPS, BATCH))

    n_batches = N // BATCH
    for cursor in (0, 1, 3):
        bits = jnp.asarray(rng.integers(2, 9, L), jnp.float32)
        # per-step path: same batch-slicing rule the fused program bakes in
        p, m = params, mom
        for i in range(K_STEPS):
            start = ((cursor + i) % n_batches) * BATCH
            p, m, _, _ = train_step(
                p, m, tx[start:start + BATCH], ty[start:start + BATCH], bits, lr)
        sl, sc = evaluate(p, vx, vy, bits)
        fl, fc = fused(params, mom, tx, ty, jnp.float32(cursor), bits, lr, vx, vy)
        assert float(sc) == float(fc), f"cursor {cursor}: n_correct diverged"
        np.testing.assert_allclose(float(sl), float(fl), rtol=1e-5)


def test_hlo_text_parses_back():
    """The HLO text must parse back through XLA's text parser — the exact
    ingestion path the rust `xla` crate uses (`HloModuleProto::from_text_file`).
    The end-to-end execute check lives in the rust integration tests."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    text = lower_text(fn, (f32(2, 2), f32(2, 2)))
    module = xc._xla.hlo_module_from_text(text)
    assert "dot" in module.to_string()
    # numerics of the original function (sanity anchor for the rust test)
    got = jax.jit(fn)(jnp.eye(2), jnp.eye(2))[0]
    np.testing.assert_allclose(np.asarray(got), np.eye(2) + 1.0)


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_matches_models(manifest):
    assert manifest["state_dim"] == A.STATE_DIM
    assert manifest["n_actions"] == A.N_ACTIONS
    assert set(manifest["networks"]) == set(models.REGISTRY)
    for name, meta in manifest["networks"].items():
        _, _, b = models.build(name)
        assert meta["p"] == b.param_count, name
        assert meta["l"] == len(b.layers), name
        assert meta["input"] == list(b.input_shape), name
        # the megabatch evaluator rides the fused family: present together
        # or absent together (rust falls back to 0 for older manifests)
        ebk = meta.get("eval_batch_k", 0)
        assert (ebk > 0) == (meta["fused_k"] > 0), name
        for lj, lm in zip(meta["layers"], b.layers):
            assert lj["w_offset"] == lm.w_offset
            assert lj["n_macs"] == lm.n_macs


def test_manifest_agent_counts(manifest):
    assert manifest["agent"]["lstm"]["p"] == A.param_count(True)
    assert manifest["agent"]["fc"]["p"] == A.param_count(False)
    # lockstep lane width: baked = PPO batch (rust falls back to
    # episodes_per_update when the key predates the batched-act artifact)
    assert manifest.get("act_batch", manifest["episodes_per_update"]) \
        == manifest["episodes_per_update"]


def test_artifact_files_exist(manifest):
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in manifest["networks"]:
        for kind in ("init", "train", "eval"):
            p = os.path.join(adir, f"{name}_{kind}.hlo.txt")
            assert os.path.exists(p), p
    for name, meta in manifest["networks"].items():
        p = os.path.join(adir, f"agent_lstm_update_l{meta['l']}.hlo.txt")
        assert os.path.exists(p), p
        if meta.get("eval_batch_k", 0) > 0:
            p = os.path.join(adir, f"{name}_retrain_eval_batch.hlo.txt")
            assert os.path.exists(p), p
    for p in ("agent_lstm_act", "agent_fc_act", "agent_lstm_init", "agent_fc_init"):
        assert os.path.exists(os.path.join(adir, f"{p}.hlo.txt"))


def test_manifest_sha256_matches_files(manifest):
    """Schema-1 manifests must carry per-artifact sha256 digests that match
    the emitted files byte-for-byte — the serve registry and the Rust
    loader verify installs against exactly these values."""
    if "schema_version" not in manifest:
        pytest.skip("legacy manifest (pre-schema); digests not stamped")
    assert manifest["schema_version"] >= 1
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    from compile.aot import artifact_files

    for name, meta in manifest["networks"].items():
        assert meta.get("version", 0) >= 1, name
        digests = meta.get("sha256", {})
        expected = artifact_files(name, meta["fused_k"])
        assert set(digests) == set(expected), name
        for fname, want in digests.items():
            with open(os.path.join(adir, fname), "rb") as f:
                got = hashlib.sha256(f.read()).hexdigest()
            assert got == want, f"{fname}: digest mismatch"
