"""Layer-2 model-zoo tests: shapes, parameter layout, MAC accounting,
quantized-training behaviour, and episode-length contracts with the paper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, train

# (name, expected quantizable-layer count) — paper Table 2 / §1
EXPECTED_L = {
    "lenet": 4,
    "simplenet": 5,
    "alexnet": 8,
    "vgg11": 9,
    "svhn10": 10,
    "resnet20": 20,
    "mobilenet": 28,
}


@pytest.mark.parametrize("name", list(models.REGISTRY))
def test_layer_counts_match_paper(name):
    _, _, b = models.build(name)
    assert len(b.layers) == EXPECTED_L[name]


@pytest.mark.parametrize("name", list(models.REGISTRY))
def test_param_layout_contiguous(name):
    _, _, b = models.build(name)
    off = 0
    for lm in b.layers:
        assert lm.w_offset == off
        off = lm.w_offset + lm.w_len
        assert lm.b_offset == off
        off = lm.b_offset + lm.b_len
    assert off == b.param_count


@pytest.mark.parametrize("name", list(models.REGISTRY))
def test_forward_shapes_and_init(name):
    apply_fn, init_fn, b = models.build(name)
    params = init_fn(0)
    assert params.shape == (b.param_count,)
    assert bool(jnp.all(jnp.isfinite(params)))
    h, w, c = b.input_shape
    x = jnp.ones((2, h, w, c), jnp.float32)
    bits = jnp.full((len(b.layers),), 8.0, jnp.float32)
    logits = apply_fn(params, x, bits)
    assert logits.shape == (2, b.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", list(models.REGISTRY))
def test_macs_positive_and_dominated_by_convs(name):
    _, _, b = models.build(name)
    assert all(lm.n_macs > 0 for lm in b.layers)
    assert all(lm.w_len == int(np.prod(lm.w_shape)) for lm in b.layers)


def test_mobilenet_alternates_dw_pw():
    _, _, b = models.build("mobilenet")
    kinds = [lm.kind for lm in b.layers]
    assert kinds[0] == "conv"
    assert kinds[-1] == "dense"
    body = kinds[1:-1]
    assert body[0::2] == ["dwconv"] * 13
    assert body[1::2] == ["conv1x1"] * 13


def test_quantization_changes_output_but_fp_does_not():
    apply_fn, init_fn, b = models.build("lenet")
    params = init_fn(0)
    x = jnp.asarray(np.random.RandomState(0).randn(4, 16, 16, 1), jnp.float32)
    l = len(b.layers)
    y_fp = apply_fn(params, x, jnp.full((l,), 9.0))
    y_fp2 = apply_fn(params, x, jnp.full((l,), 16.0))
    y_q2 = apply_fn(params, x, jnp.full((l,), 2.0))
    np.testing.assert_allclose(np.asarray(y_fp), np.asarray(y_fp2), rtol=1e-6)
    assert not np.allclose(np.asarray(y_fp), np.asarray(y_q2))


def test_per_layer_bits_are_independent():
    apply_fn, init_fn, b = models.build("lenet")
    params = init_fn(1)
    x = jnp.asarray(np.random.RandomState(1).randn(4, 16, 16, 1), jnp.float32)
    l = len(b.layers)
    base = np.asarray(apply_fn(params, x, jnp.full((l,), 8.0)))
    for i in range(l):
        bits = np.full((l,), 8.0, np.float32)
        bits[i] = 2.0
        out = np.asarray(apply_fn(params, x, jnp.asarray(bits)))
        assert not np.allclose(base, out), f"layer {i} bits had no effect"


def test_train_step_reduces_loss():
    apply_fn, init_fn, b = models.build("simplenet")
    init, step, evaluate = train.make_fns(apply_fn, init_fn)
    params, mom = jax.jit(init)(jnp.float32(3))
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(32, 16, 16, 3), jnp.float32)
    y = jnp.asarray(rs.randint(0, 10, 32), jnp.float32)
    bits = jnp.full((len(b.layers),), 9.0)
    js = jax.jit(step)
    first = None
    for i in range(30):
        params, mom, loss, acc = js(params, mom, x, y, bits, jnp.float32(0.01))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_evaluate_counts_correct():
    apply_fn, init_fn, b = models.build("lenet")
    init, step, evaluate = train.make_fns(apply_fn, init_fn)
    params, _ = jax.jit(init)(jnp.float32(0))
    x = jnp.zeros((8, 16, 16, 1), jnp.float32)
    bits = jnp.full((4,), 9.0)
    logits = apply_fn(params, x, bits)
    pred = int(jnp.argmax(logits[0]))
    y_right = jnp.full((8,), float(pred))
    _, ncorrect = evaluate(params, x, y_right, bits)
    assert int(ncorrect) == 8
    y_wrong = jnp.full((8,), float((pred + 1) % 10))
    _, ncorrect = evaluate(params, x, y_wrong, bits)
    assert int(ncorrect) == 0


def test_resnet_residual_shapes():
    apply_fn, init_fn, b = models.build("resnet20")
    params = init_fn(0)
    x = jnp.ones((1, 16, 16, 3), jnp.float32)
    logits = apply_fn(params, x, jnp.full((20,), 8.0))
    assert logits.shape == (1, 10)


def test_dataset_mapping_complete():
    for name in models.REGISTRY:
        assert name in models.DATASETS
