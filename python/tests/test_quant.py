"""Quantizer (WRPN eq. 1) unit tests + STE gradient semantics."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import quant


@given(k=st.sampled_from([2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]),
       seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_mid_tread_on_grid_and_clipped(k, seed):
    w = jnp.asarray(np.random.RandomState(seed).randn(64) * 1.5, jnp.float32)
    q = np.asarray(quant.quantize_mid_tread(w, k))
    levels = 2 ** (k - 1) - 1
    np.testing.assert_allclose(q * levels, np.round(q * levels), atol=1e-4)
    assert np.abs(q).max() <= 1.0 + 1e-6


def test_mid_tread_includes_zero_mid_rise_excludes():
    w = jnp.zeros((4,), jnp.float32)
    assert np.all(np.asarray(quant.quantize_mid_tread(w, 3.0)) == 0.0)
    assert np.all(np.asarray(quant.quantize_mid_rise(w, 3.0)) != 0.0)


def test_fp_sentinel_is_identity():
    w = jnp.asarray([-2.0, -0.5, 0.0, 0.7, 3.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(quant.fake_quant(w, 9.0)), np.asarray(w))


def test_binary_k2_levels():
    w = jnp.asarray([-0.9, -0.2, 0.2, 0.9], jnp.float32)
    q = np.asarray(quant.fake_quant(w, 2.0))
    np.testing.assert_array_equal(q, [-1.0, 0.0, 0.0, 1.0])


def test_ste_gradient_inside_and_outside():
    w = jnp.asarray([-1.5, -0.5, 0.5, 1.5], jnp.float32)

    def f(w):
        return jnp.sum(quant.fake_quant(w, 4.0))

    g = np.asarray(jax.grad(f)(w))
    np.testing.assert_array_equal(g, [0.0, 1.0, 1.0, 0.0])


def test_ste_gradient_identity_at_fp():
    w = jnp.asarray([-1.5, 0.5, 2.0], jnp.float32)

    def f(w):
        return jnp.sum(quant.fake_quant(w, 9.0))

    g = np.asarray(jax.grad(f)(w))
    np.testing.assert_array_equal(g, [1.0, 1.0, 1.0])


def test_error_monotone_in_bits():
    w = jnp.asarray(np.random.RandomState(0).randn(512) * 0.5, jnp.float32)
    errs = []
    for k in [2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]:
        q = quant.quantize_mid_tread(w, k)
        errs.append(float(jnp.sum((q - jnp.clip(w, -1, 1)) ** 2)))
    assert all(a > b for a, b in zip(errs, errs[1:])), errs


def test_quant_levels_count():
    # k bits -> 2^(k-1)-1 positive levels, symmetric, plus zero
    for k in [2, 3, 4, 8]:
        w = jnp.asarray(np.linspace(-1, 1, 4001), jnp.float32)
        q = np.unique(np.asarray(quant.quantize_mid_tread(w, float(k))))
        assert len(q) == 2 * (2 ** (k - 1) - 1) + 1
