"""PPO agent tests: act/update shapes, probability semantics, learning on a
contextual-bandit toy problem (validating the PPO-in-HLO math end to end),
and the LSTM's actual use of recurrent state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import agent as A


@pytest.mark.parametrize("rec", [True, False])
def test_act_outputs(rec):
    act = jax.jit(A.make_act(rec))
    p = A.init_params(0, rec)
    s = jnp.ones((A.STATE_DIM,))
    h = jnp.zeros((A.HIDDEN,))
    probs, value, h2, c2 = act(p, s, h, h)
    assert probs.shape == (A.N_ACTIONS,)
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-5)
    assert np.all(np.asarray(probs) >= 0)
    assert h2.shape == (A.HIDDEN,)
    assert c2.shape == (A.HIDDEN,)
    assert np.isfinite(float(value))


def test_initial_policy_near_uniform():
    act = jax.jit(A.make_act(True))
    p = A.init_params(7, True)
    for seed in range(3):
        s = jnp.asarray(np.random.RandomState(seed).rand(A.STATE_DIM), jnp.float32)
        probs, _, _, _ = act(p, s, jnp.zeros((A.HIDDEN,)), jnp.zeros((A.HIDDEN,)))
        np.testing.assert_allclose(np.asarray(probs), 1.0 / A.N_ACTIONS, atol=0.02)


def test_lstm_state_matters_fc_state_ignored():
    s = jnp.ones((A.STATE_DIM,))
    h0 = jnp.zeros((A.HIDDEN,))
    h1 = jnp.ones((A.HIDDEN,))
    # trained-ish params (random but not tiny) so the policy isn't saturated-uniform
    p_lstm = A.init_params(1, True) + 0.05 * jax.random.normal(
        jax.random.PRNGKey(0), (A.param_count(True),))
    act = jax.jit(A.make_act(True))
    pr0, v0, _, _ = act(p_lstm, s, h0, h0)
    pr1, v1, _, _ = act(p_lstm, s, h1, h1)
    assert not np.allclose(np.asarray(pr0), np.asarray(pr1)) or v0 != v1
    p_fc = A.init_params(1, False)
    act_fc = jax.jit(A.make_act(False))
    pr0, v0, _, _ = act_fc(p_fc, s, h0, h0)
    pr1, v1, _, _ = act_fc(p_fc, s, h1, h1)
    np.testing.assert_array_equal(np.asarray(pr0), np.asarray(pr1))
    assert float(v0) == float(v1)


def test_update_shapes_and_stats():
    upd = jax.jit(A.make_update(True))
    P = A.param_count(True)
    p = A.init_params(0, True)
    B, L = 8, 5
    st = jnp.ones((B, L, A.STATE_DIM))
    a = jnp.zeros((B, L))
    olp = jnp.log(jnp.full((B, L), 1.0 / A.N_ACTIONS))
    adv = jnp.ones((B, L))
    ret = jnp.ones((B, L))
    z = jnp.zeros((P,))
    out = upd(p, z, z, jnp.float32(0), st, a, olp, adv, ret,
              jnp.float32(0.1), jnp.float32(0.01), jnp.float32(1e-4))
    p2, m2, v2, t2, pi_l, v_l, ent, kl = out
    assert p2.shape == (P,)
    assert float(t2) == 1.0
    assert np.isfinite(float(pi_l)) and np.isfinite(float(v_l))
    # entropy of a uniform 8-way policy is ln 8
    np.testing.assert_allclose(float(ent), np.log(A.N_ACTIONS), atol=0.01)
    # fresh policy == old policy -> tiny KL
    assert abs(float(kl)) < 1e-3
    assert not np.allclose(np.asarray(p2), np.asarray(p))


@pytest.mark.parametrize("rec", [True, False])
def test_ppo_learns_contextual_bandit(rec):
    """State s has feature s[0] in {0, 1}; the rewarded action is 1 if
    s[0] == 0 else 6. PPO through the exact update artifact math must push
    the policy toward the rewarded actions."""
    act = jax.jit(A.make_act(rec))
    upd = jax.jit(A.make_update(rec))
    P = A.param_count(rec)
    p = A.init_params(3, rec)
    m = jnp.zeros((P,))
    v = jnp.zeros((P,))
    t = jnp.float32(0)
    B, L = 8, 4
    rng = np.random.RandomState(0)

    def episode(p):
        states = np.zeros((L, A.STATE_DIM), np.float32)
        acts = np.zeros((L,), np.float32)
        logps = np.zeros((L,), np.float32)
        rewards = np.zeros((L,), np.float32)
        values = np.zeros((L,), np.float32)
        h = jnp.zeros((A.HIDDEN,))
        c = jnp.zeros((A.HIDDEN,))
        for i in range(L):
            ctx = float(rng.randint(2))
            states[i, 0] = ctx
            probs, val, h, c = act(p, jnp.asarray(states[i]), h, c)
            pr = np.asarray(probs)
            a = rng.choice(A.N_ACTIONS, p=pr / pr.sum())
            target = 1 if ctx == 0.0 else 6
            rewards[i] = 1.0 if a == target else 0.0
            acts[i] = a
            logps[i] = np.log(max(pr[a], 1e-8))
            values[i] = float(val)
        return states, acts, logps, rewards, values

    def avg_reward(p, n=40):
        tot = 0.0
        for _ in range(n):
            _, _, _, r, _ = episode(p)
            tot += r.mean()
        return tot / n

    before = avg_reward(p)
    for it in range(30):
        bs, ba, blp, badv, bret = [], [], [], [], []
        for _ in range(B):
            s, a, lp, r, val = episode(p)
            # returns = reward-to-go; advantage = r2g - value, normalized below
            r2g = np.cumsum(r[::-1])[::-1]
            bs.append(s)
            ba.append(a)
            blp.append(lp)
            badv.append(r2g - val)
            bret.append(r2g)
        adv = np.stack(badv)
        adv = (adv - adv.mean()) / (adv.std() + 1e-6)
        args = (p, m, v, t, jnp.asarray(np.stack(bs)), jnp.asarray(np.stack(ba)),
                jnp.asarray(np.stack(blp)), jnp.asarray(adv),
                jnp.asarray(np.stack(bret)), jnp.float32(0.2), jnp.float32(0.01),
                jnp.float32(3e-3))
        p, m, v, t = upd(*args)[:4]
    after = avg_reward(p)
    assert after > before + 0.25, f"bandit not learned: {before:.3f} -> {after:.3f}"


def test_param_layout_slots_contiguous():
    for rec in (True, False):
        slots = A.LSTM_SLOTS if rec else A.FC_SLOTS
        off = 0
        for s in slots:
            assert s.offset == off
            off += s.size
        assert off == A.param_count(rec)


@pytest.mark.parametrize("rec", [True, False])
def test_act_batch_matches_per_lane_act(rec):
    """The vmapped batch act must reproduce the scalar act lane-for-lane:
    the Rust lockstep driver relies on act_batch being a drop-in for B
    independent act calls."""
    B = 8
    act = jax.jit(A.make_act(rec))
    act_batch = jax.jit(A.make_act_batch(rec))
    p = A.init_params(3, rec)
    rng = np.random.RandomState(0)
    s = jnp.asarray(rng.rand(B, A.STATE_DIM), jnp.float32)
    h = jnp.asarray(rng.rand(B, A.HIDDEN), jnp.float32)
    c = jnp.asarray(rng.rand(B, A.HIDDEN), jnp.float32)
    probs_b, val_b, h_b, c_b = act_batch(p, s, h, c)
    assert probs_b.shape == (B, A.N_ACTIONS)
    assert val_b.shape == (B,)
    assert h_b.shape == (B, A.HIDDEN)
    assert c_b.shape == (B, A.HIDDEN)
    for i in range(B):
        probs_i, val_i, h_i, c_i = act(p, s[i], h[i], c[i])
        np.testing.assert_allclose(np.asarray(probs_b[i]), np.asarray(probs_i),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(val_b[i]), float(val_i),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(h_b[i]), np.asarray(h_i),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(c_b[i]), np.asarray(c_i),
                                   rtol=1e-5, atol=1e-6)
