"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/bitwidths; assert_allclose against ref — the
core correctness signal for the fused quantize+matmul kernel.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import qmatmul as qk
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernel", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernel")


def rand(shape, seed, scale=1.0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# quantize kernel
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    k=st.sampled_from([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 16.0]),
    seed=st.integers(0, 10_000),
)
def test_quantize_pallas_matches_ref(m, n, k, seed):
    w = rand((m, n), seed, scale=0.8)
    got = qk.quantize_pallas(w, k)
    want = ref.quantize_ref(w, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


def test_quantize_identity_above_fp_bits():
    w = rand((8, 8), 0, scale=3.0)  # includes values outside (-1, 1)
    got = qk.quantize_pallas(w, 9.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))


def test_quantize_values_on_grid():
    w = rand((16, 16), 1)
    for k in [2.0, 3.0, 5.0, 8.0]:
        q = np.asarray(qk.quantize_pallas(w, k))
        levels = 2 ** (k - 1) - 1
        steps = q * levels
        np.testing.assert_allclose(steps, np.round(steps), atol=1e-4)
        assert np.abs(q).max() <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# fused qmatmul forward
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 48),
    kk=st.integers(1, 48),
    n=st.integers(1, 48),
    bits=st.sampled_from([2.0, 3.0, 4.0, 6.0, 8.0, 9.0]),
    seed=st.integers(0, 10_000),
)
def test_qmatmul_matches_ref(m, kk, n, bits, seed):
    x = rand((m, kk), seed)
    w = rand((kk, n), seed + 1, scale=0.7)
    got = qk.qmatmul(x, w, bits)
    want = ref.qmatmul_ref(x, w, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_qmatmul_blockspec_tiling_exercised():
    # shapes larger than one block in every grid dimension
    m, kk, n = 40, 72, 56
    x = rand((m, kk), 3)
    w = rand((kk, n), 4, scale=0.6)
    got = qk.qmatmul_fwd_pallas(x, w, 4.0, bm=16, bk=32, bn=16)
    want = ref.qmatmul_ref(x, w, 4.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matmul_pallas_plain():
    a = rand((17, 23), 5)
    b = rand((23, 9), 6)
    np.testing.assert_allclose(
        np.asarray(qk.matmul_pallas(a, b)), np.asarray(a @ b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# backward (custom VJP with STE)
# ---------------------------------------------------------------------------

@given(
    m=st.integers(2, 24),
    kk=st.integers(2, 24),
    n=st.integers(2, 24),
    bits=st.sampled_from([2.0, 4.0, 8.0, 9.0]),
    seed=st.integers(0, 10_000),
)
def test_qmatmul_grads_match_ref(m, kk, n, bits, seed):
    x = rand((m, kk), seed)
    w = rand((kk, n), seed + 1, scale=0.9)
    gy = rand((m, n), seed + 2)

    def loss(x, w):
        return jnp.sum(qk.qmatmul(x, w, bits) * gy)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    rx, rw = ref.qmatmul_grads_ref(x, w, bits, gy)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5, atol=1e-5)


def test_ste_kills_gradient_outside_clip_range():
    x = rand((4, 6), 7)
    w = jnp.asarray(np.linspace(-2.0, 2.0, 6 * 5).reshape(6, 5), jnp.float32)

    def loss(w):
        return jnp.sum(qk.qmatmul(x, w, 3.0))

    gw = np.asarray(jax.grad(loss)(w))
    outside = np.abs(np.asarray(w)) > 1.0
    assert np.all(gw[outside] == 0.0)
    assert np.any(gw[~outside] != 0.0)


def test_vmem_footprint_estimate():
    # default MXU blocks must fit VMEM with double buffering (~16 MiB budget)
    assert qk.vmem_footprint_bytes() == 2 * 3 * 128 * 128 * 4
    assert qk.vmem_footprint_bytes() < 16 * 1024 * 1024


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_qmatmul_dtype_preserved(dtype):
    x = rand((8, 8), 0).astype(dtype)
    w = rand((8, 8), 1).astype(dtype)
    assert qk.qmatmul(x, w, 4.0).dtype == dtype
