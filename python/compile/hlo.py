"""HLO-text lowering helper (the AOT interchange format).

HLO *text*, NOT serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which the rust `xla` crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly.  Lower with ``return_tuple=True`` and unwrap with
``to_tuple*`` on the rust side.  (See /opt/xla-example/README.md.)
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, out_path: str) -> int:
    """jit-lower ``fn`` at the given abstract args and write HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)
