"""Layer-2 training/eval computations lowered to AOT artifacts.

Three jitted entry points per network, all operating on a single flat f32
parameter vector so the Rust runtime is network-agnostic:

* ``init(seed)``                               -> params
* ``train_step(params, mom, x, y, bits, lr)``  -> params', mom', loss, acc
* ``evaluate(params, x, y, bits)``             -> loss, n_correct

``bits`` is the per-layer bitwidth vector the RL agent proposes (f32, length
L); entries >= FP_BITS select the full-precision path (pretraining and the
Acc_FullP baseline).  The optimizer is SGD with momentum 0.9 — the quantized
*short-retrain* the paper uses between agent steps (§3: "retraining for a
shortened amount of epochs").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

MOMENTUM = 0.9


def cross_entropy(logits, labels_i32):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels_i32, logits.shape[-1], dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def make_fns(apply_fn, init_fn):
    """Builds the three jittable closures for one network."""

    def init(seed_f32):
        params = init_fn(seed_f32.astype(jnp.int32))
        return (params, jnp.zeros_like(params))

    def loss_fn(params, x, y, bits):
        logits = apply_fn(params, x, bits)
        loss = cross_entropy(logits, y.astype(jnp.int32))
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y.astype(jnp.int32))
                       .astype(jnp.float32))
        return loss, acc

    def train_step(params, mom, x, y, bits, lr):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, y, bits)
        mom = MOMENTUM * mom + grads
        params = params - lr * mom
        return (params, mom, loss, acc)

    def evaluate(params, x, y, bits):
        logits = apply_fn(params, x, bits)
        loss = cross_entropy(logits, y.astype(jnp.int32))
        ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y.astype(jnp.int32))
                           .astype(jnp.float32))
        return (loss, ncorrect)

    return init, train_step, evaluate


def make_fused_retrain_eval(apply_fn, init_fn, k_steps: int, batch: int,
                            unroll: bool = True):
    """The environment's whole accuracy query as ONE executable (perf pass,
    EXPERIMENTS.md §Perf): `k_steps` quantized SGD steps from the snapshot
    (batches sliced on-device from the resident training set by a cursor) and
    the validation evaluation — so the Rust hot path transfers only the bits
    vector, the cursor and the learning rate per query instead of streaming
    parameters and batches back and forth on every step.

    (params, mom, train_x[N,...], train_y[N], cursor, bits, lr, val_x, val_y)
      -> (loss, n_correct)

    N must be a multiple of `batch`; batch b_i starts at
    ((cursor + i) mod (N/batch)) * batch, matching Split::fill_batch's
    wrapping semantics on the Rust side.
    """
    init, train_step, evaluate = make_fns(apply_fn, init_fn)

    def retrain_eval(params, mom, train_x, train_y, cursor, bits, lr, val_x, val_y):
        n_batches = train_x.shape[0] // batch
        cursor = cursor.astype(jnp.int32)

        def one_step(p, m, i):
            start = ((cursor + i) % n_batches) * batch
            x = jax.lax.dynamic_slice_in_dim(train_x, start, batch, axis=0)
            y = jax.lax.dynamic_slice_in_dim(train_y, start, batch, axis=0)
            p, m, _, _ = train_step(p, m, x, y, bits, lr)
            return p, m

        if unroll:
            # unrolled (k_steps is static): straight-line HLO lets XLA fuse
            # the quantize/matmul chain across steps — ~2.3x faster at run
            # time than the scan form on the CPU backend, but compile time
            # grows with k * graph size (EXPERIMENTS.md §Perf). Used for the
            # shallow networks.
            for i in range(k_steps):
                params, mom = one_step(params, mom, i)
        else:
            # scan form: the loop body compiles once — deep networks at
            # k = 10 would take minutes to compile unrolled (measured >13 min
            # for ResNet-20), so they trade ~1.5x runtime for a fast compile.
            def body(carry, i):
                p, m = one_step(carry[0], carry[1], i)
                return (p, m), 0.0

            (params, mom), _ = jax.lax.scan(
                body, (params, mom), jnp.arange(k_steps, dtype=jnp.int32))
        return evaluate(params, val_x, val_y, bits)

    return retrain_eval


def make_batched_retrain_eval(apply_fn, init_fn, k_steps: int, batch: int,
                              unroll: bool = True):
    """K independent accuracy queries as ONE executable: ``jax.vmap`` of the
    fused retrain+eval over K candidate ``bits`` lanes (and their per-lane
    cursors — the retrain start-batch is bits-derived on the Rust side), with
    the snapshot, momentum, resident training set, lr and validation set
    broadcast across lanes.

    (params, mom, train_x[N,...], train_y[N], cursor[K], bits[K,L], lr,
     val_x, val_y) -> (loss[K], n_correct[K])

    Each lane computes exactly the function `make_fused_retrain_eval` lowers
    for a single query — lanes never interact — so lane ``i``'s ``n_correct``
    must equal the scalar fused artifact's output for the same bits vector
    (an integer count of argmax matches; pinned by
    ``rust/tests/eval_batch_parity.rs`` against the compiled artifacts). The
    Rust coordinator pays one PJRT dispatch for up to K distinct candidate
    vectors per rollout step instead of one per candidate, padding short
    batches by repeating the last candidate (pad lanes are discarded
    host-side)."""
    fused = make_fused_retrain_eval(apply_fn, init_fn, k_steps, batch, unroll)
    return jax.vmap(fused, in_axes=(None, None, None, None, 0, 0, None, None, None))
