"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here, and
``python/tests/test_kernel.py`` sweeps shapes/dtypes (hypothesis) asserting
allclose between the kernel (interpret mode) and these oracles.  This is the
CORE correctness signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp

FP_BITS = 9.0


def quantize_ref(w, k):
    """Mid-tread WRPN quantizer, identity at k >= FP_BITS (paper eq. 1)."""
    levels = jnp.exp2(k - 1.0) - 1.0
    wc = jnp.clip(w, -1.0, 1.0)
    wq = jnp.round(levels * wc) / levels
    return jnp.where(k >= FP_BITS, w, wq)


def qmatmul_ref(x, w, k):
    """Fused quantize+matmul oracle: x @ quantize(w, k)."""
    return jnp.dot(x, quantize_ref(w, k))


def matmul_ref(a, b):
    return jnp.dot(a, b)


def ste_mask_ref(w, k):
    in_range = (jnp.abs(w) <= 1.0).astype(w.dtype)
    return jnp.where(k >= FP_BITS, jnp.ones_like(in_range), in_range)


def qmatmul_grads_ref(x, w, k, gy):
    """Reference VJP of qmatmul wrt (x, w) with the STE through the quantizer."""
    wq = quantize_ref(w, k)
    dx = jnp.dot(gy, wq.T)
    dw = jnp.dot(x.T, gy) * ste_mask_ref(w, k)
    return dx, dw
