"""Layer-1 Pallas kernels: fused fake-quantize + matmul (the training hot-spot).

The paper's quantized-training substrate (WRPN, eq. 1) fake-quantizes every
weight matrix on every forward pass.  Done naively this materializes a
dequantized copy of the weights in HBM each step.  The fused kernel here
quantizes each weight *tile* in VMEM right before it enters the matmul, so the
dequantized tensor never round-trips to HBM:

    grid = (M/bm, N/bn, K/bk)            # K innermost: accumulate in-place
    x tile   (bm, bk)  <- VMEM
    w tile   (bk, bn)  <- VMEM, quantized in-register
    out tile (bm, bn)  accumulated across the K steps

TPU adaptation (DESIGN.md §Hardware-Adaptation): block sizes default to the
MXU-native 128x128x128; the bitwidth scalar lives in a (1,1) block that every
grid step maps to, standing in for SMEM scalar storage.  ``interpret=True``
always — the CPU PJRT plugin cannot execute Mosaic custom-calls, and the AOT
HLO artifacts must run on the rust CPU client.

The backward pass is exposed as two more Pallas kernels (plain tiled matmuls)
composed with the straight-through-estimator mask; ``qmatmul`` wraps the lot
in a ``jax.custom_vjp`` so Layer-2 models call one differentiable primitive.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FP_BITS = 9.0

# MXU-native tile edge. On real TPU hardware this is the systolic array width;
# under interpret=True it just sets the BlockSpec schedule we are validating.
MXU_TILE = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _block(dim: int, target: int = MXU_TILE) -> int:
    """Pick a block edge: full MXU tile when the dim allows, else the padded dim."""
    if dim >= target:
        return target
    return _round_up(dim, 8)


def _quantize_tile(w, k):
    """In-register mid-tread quantization of one VMEM tile (identity at k>=FP_BITS)."""
    levels = jnp.exp2(k - 1.0) - 1.0
    wc = jnp.clip(w, -1.0, 1.0)
    wq = jnp.round(levels * wc) / levels
    return jnp.where(k >= FP_BITS, w, wq)


def _qmatmul_kernel(x_ref, w_ref, k_ref, o_ref):
    """One (bm, bn) output tile; K-step `pl.program_id(2)` accumulates in place."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = k_ref[0, 0]
    wq = _quantize_tile(w_ref[...], k)
    o_ref[...] += jnp.dot(x_ref[...], wq, preferred_element_type=o_ref.dtype)


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Plain tiled matmul (used by the backward pass)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def _quantize_kernel(w_ref, k_ref, o_ref):
    """Standalone elementwise quantizer kernel (tile-parallel)."""
    o_ref[...] = _quantize_tile(w_ref[...], k_ref[0, 0])


def _pad2(a, m, n):
    pm, pn = m - a.shape[0], n - a.shape[1]
    if pm == 0 and pn == 0:
        return a
    return jnp.pad(a, ((0, pm), (0, pn)))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def qmatmul_fwd_pallas(x, w, k, *, bm=None, bk=None, bn=None):
    """Fused ``x @ quantize(w, k)`` via the Pallas kernel (forward only)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm = bm or _block(M)
    bk = bk or _block(K)
    bn = bn or _block(N)
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    xp = _pad2(x, Mp, Kp)
    wp = _pad2(w, Kp, Np)
    kk = jnp.asarray(k, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, 1), lambda i, j, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=True,
    )(xp, wp, kk)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_pallas(a, b, *, bm=None, bk=None, bn=None):
    """Plain tiled Pallas matmul ``a @ b`` (backward-pass building block)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm = bm or _block(M)
    bk = bk or _block(K)
    bn = bn or _block(N)
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    ap = _pad2(a, Mp, Kp)
    bp = _pad2(b, Kp, Np)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:M, :N]


@jax.jit
def quantize_pallas(w, k):
    """Elementwise Pallas fake-quantizer over a 2-D weight matrix."""
    M, N = w.shape
    bm, bn = _block(M), _block(N)
    Mp, Np = _round_up(M, bm), _round_up(N, bn)
    wp = _pad2(w, Mp, Np)
    kk = jnp.asarray(k, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _quantize_kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), w.dtype),
        interpret=True,
    )(wp, kk)
    return out[:M, :N]


@jax.custom_vjp
def qmatmul(x, w, k):
    """Differentiable fused quantize+matmul: ``x @ quantize(w, k)``.

    Forward and both backward matmuls run as Pallas kernels; the quantizer
    gradient is the straight-through estimator (identity inside the clip
    range).  ``k`` is a runtime f32 scalar; ``k >= FP_BITS`` disables
    quantization (full-precision path).
    """
    return qmatmul_fwd_pallas(x, w, k)


def _qmatmul_vjp_fwd(x, w, k):
    return qmatmul_fwd_pallas(x, w, k), (x, w, k)


def _qmatmul_vjp_bwd(res, gy):
    x, w, k = res
    # Rematerialize the quantized weights (cheaper than saving them: one
    # elementwise pass vs an extra (K, N) residual held across the step).
    wq = quantize_pallas(w, k)
    dx = matmul_pallas(gy, wq.T)
    ste = (jnp.abs(w) <= 1.0).astype(w.dtype)
    ste = jnp.where(k >= FP_BITS, jnp.ones_like(ste), ste)
    dw = matmul_pallas(x.T, gy) * ste
    return dx, dw, None


qmatmul.defvjp(_qmatmul_vjp_fwd, _qmatmul_vjp_bwd)


def vmem_footprint_bytes(bm: int = MXU_TILE, bk: int = MXU_TILE, bn: int = MXU_TILE,
                         dtype_bytes: int = 4, double_buffered: bool = True) -> int:
    """VMEM footprint estimate for the fused kernel's BlockSpec schedule.

    Used by DESIGN.md §Perf / EXPERIMENTS.md §Perf: x-tile + w-tile + out-tile,
    times two when the HBM->VMEM pipeline double-buffers the input tiles.
    """
    tiles = bm * bk + bk * bn + bm * bn
    mult = 2 if double_buffered else 1
    return tiles * dtype_bytes * mult
