"""Layer-2 PPO agent (paper §2.7, §4.7) lowered to AOT artifacts.

Architecture — exactly the paper's:

* shared LSTM first hidden layer over the state embedding (H = 64 here,
  width-scaled with the rest of the testbed),
* policy head: FC 128 -> FC 128 -> |bitwidth set| softmax,
* value head:  FC 128 -> FC 64 -> 1.

Two entry points:

* ``act(params, s[D], h, c)`` -> (probs[A], value, h', c')
  called once per layer-step on the Rust hot path; the Rust coordinator
  carries (h, c) across the layers of an episode so bitwidth choices are
  conditioned on previous layers' context (paper §1, LSTM motivation).

* ``update(params, m, v, t, states[B,L,D], actions[B,L], old_logp[B,L],
  adv[B,L], ret[B,L], clip_eps, ent_coef, lr)``
  -> (params', m', v', pi_loss, v_loss, entropy, approx_kl)
  one PPO epoch over a batch of B whole episodes: re-runs the LSTM over each
  episode with ``lax.scan``, computes the clipped surrogate
  (min(r A, clip(r, 1±eps) A)), value loss and entropy bonus, and applies one
  Adam step (lr 1e-4, the paper's Table 3).  The Rust driver calls it
  3x per update (paper: 3 epochs) and owns GAE / advantage normalization.

An FC-only agent variant (the paper's §2.7 "x1.33 faster with LSTM" ablation)
replaces the LSTM cell with a dense layer but keeps the same interface (h, c
pass through untouched).

All parameters live in one flat f32 vector (offsets below) so the Rust side
handles the agent exactly like the model networks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

STATE_DIM = 8     # D — must match rust/src/coordinator/embedding.rs
N_ACTIONS = 8     # A — bitwidths {1..8} (paper Fig 2a)
HIDDEN = 64       # LSTM hidden size
PH1, PH2 = 128, 128   # policy head widths (paper: 128, 128)
VH1, VH2 = 128, 64    # value head widths (paper: 128, 64)
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


@dataclasses.dataclass
class Slot:
    name: str
    shape: Tuple[int, ...]
    offset: int

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def _layout(recurrent: bool) -> List[Slot]:
    slots: List[Slot] = []
    off = 0

    def add(name, shape):
        nonlocal off
        s = Slot(name, shape, off)
        slots.append(s)
        off += s.size
        return s

    if recurrent:
        add("lstm_wx", (STATE_DIM, 4 * HIDDEN))
        add("lstm_wh", (HIDDEN, 4 * HIDDEN))
        add("lstm_b", (4 * HIDDEN,))
    else:
        add("enc_w", (STATE_DIM, HIDDEN))
        add("enc_b", (HIDDEN,))
    add("pi_w1", (HIDDEN, PH1))
    add("pi_b1", (PH1,))
    add("pi_w2", (PH1, PH2))
    add("pi_b2", (PH2,))
    add("pi_w3", (PH2, N_ACTIONS))
    add("pi_b3", (N_ACTIONS,))
    add("v_w1", (HIDDEN, VH1))
    add("v_b1", (VH1,))
    add("v_w2", (VH1, VH2))
    add("v_b2", (VH2,))
    add("v_w3", (VH2, 1))
    add("v_b3", (1,))
    return slots


LSTM_SLOTS = _layout(recurrent=True)
FC_SLOTS = _layout(recurrent=False)


def param_count(recurrent: bool) -> int:
    slots = LSTM_SLOTS if recurrent else FC_SLOTS
    return slots[-1].offset + slots[-1].size


def _unpack(params, recurrent: bool) -> Dict[str, jnp.ndarray]:
    slots = LSTM_SLOTS if recurrent else FC_SLOTS
    return {s.name: params[s.offset:s.offset + s.size].reshape(s.shape)
            for s in slots}


def init_params(seed: int, recurrent: bool) -> jnp.ndarray:
    """Orthogonal-ish (scaled normal) init; small final policy layer so the
    initial policy is near-uniform (standard PPO practice)."""
    slots = LSTM_SLOTS if recurrent else FC_SLOTS
    key = jax.random.PRNGKey(seed)
    chunks = []
    for s in slots:
        key, sub = jax.random.split(key)
        if len(s.shape) == 1:
            chunks.append(jnp.zeros(s.shape, jnp.float32))
        else:
            std = (1.0 / s.shape[0]) ** 0.5
            if s.name == "pi_w3":
                std *= 0.01  # near-uniform initial policy
            chunks.append(jax.random.normal(sub, s.shape, jnp.float32).reshape(-1) * std)
    return jnp.concatenate(chunks)


def init_params_traced(seed_f32, recurrent: bool) -> jnp.ndarray:
    """Same init with a traced f32 seed operand (the AOT artifact entry)."""
    return init_params(seed_f32.astype(jnp.int32), recurrent)


def _lstm_cell(p, s, h, c):
    gates = s @ p["lstm_wx"] + h @ p["lstm_wh"] + p["lstm_b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def _encode(p, s, h, c, recurrent: bool):
    if recurrent:
        h, c = _lstm_cell(p, s, h, c)
        return h, h, c
    e = jax.nn.relu(s @ p["enc_w"] + p["enc_b"])
    return e, h, c


def _heads(p, e):
    x = jax.nn.relu(e @ p["pi_w1"] + p["pi_b1"])
    x = jax.nn.relu(x @ p["pi_w2"] + p["pi_b2"])
    logits = x @ p["pi_w3"] + p["pi_b3"]
    y = jax.nn.relu(e @ p["v_w1"] + p["v_b1"])
    y = jax.nn.relu(y @ p["v_w2"] + p["v_b2"])
    value = (y @ p["v_w3"] + p["v_b3"])[..., 0]
    return logits, value


def make_act(recurrent: bool):
    def act(params, s, h, c):
        p = _unpack(params, recurrent)
        e, h2, c2 = _encode(p, s, h, c, recurrent)
        logits, value = _heads(p, e)
        return (jax.nn.softmax(logits), value, h2, c2)

    return act


def make_act_batch(recurrent: bool):
    """Vectorized act: ``(params, s[B,D], h[B,H], c[B,H]) ->
    (probs[B,A], value[B], h'[B,H], c'[B,H])``.

    One lowered execution serves a whole lockstep batch of B independent
    episode lanes (params broadcast, per-lane state/hidden), so the Rust
    driver pays one PJRT dispatch per *layer* instead of one per
    (layer, episode)."""
    return jax.vmap(make_act(recurrent), in_axes=(None, 0, 0, 0))


def _episode_logits(p, states, recurrent: bool):
    """Run the encoder over one episode's L states -> (logits[L,A], values[L])."""
    if recurrent:
        def step(carry, s):
            h, c = carry
            h, c = _lstm_cell(p, s, h, c)
            return (h, c), h

        h0 = jnp.zeros((HIDDEN,), jnp.float32)
        (_, _), enc = jax.lax.scan(step, (h0, h0), states)
    else:
        enc = jax.nn.relu(states @ p["enc_w"] + p["enc_b"])
    return _heads(p, enc)


def make_update(recurrent: bool):
    def ppo_loss(params, states, actions, old_logp, adv, ret, clip_eps, ent_coef):
        p = _unpack(params, recurrent)
        logits, values = jax.vmap(
            lambda s: _episode_logits(p, s, recurrent))(states)  # [B,L,A],[B,L]
        logp_all = jax.nn.log_softmax(logits)
        a = actions.astype(jnp.int32)
        logp = jnp.take_along_axis(logp_all, a[..., None], axis=-1)[..., 0]
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
        pi_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
        v_loss = 0.5 * jnp.mean((values - ret) ** 2)
        entropy = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1))
        approx_kl = jnp.mean(old_logp - logp)
        total = pi_loss + 0.5 * v_loss - ent_coef * entropy
        return total, (pi_loss, v_loss, entropy, approx_kl)

    def update(params, m, v, t, states, actions, old_logp, adv, ret,
               clip_eps, ent_coef, lr):
        grads, aux = jax.grad(ppo_loss, has_aux=True)(
            params, states, actions, old_logp, adv, ret, clip_eps, ent_coef)
        pi_loss, v_loss, entropy, approx_kl = aux
        t = t + 1.0
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
        mhat = m / (1.0 - ADAM_B1 ** t)
        vhat = v / (1.0 - ADAM_B2 ** t)
        params = params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return (params, m, v, t, pi_loss, v_loss, entropy, approx_kl)

    return update
