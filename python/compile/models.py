"""The seven benchmark DNNs (paper §4.1), width-scaled for the 1-core CPU-PJRT
testbed.

Substitution (DESIGN.md §7): each network keeps the paper network's **layer
count, layer-type sequence and relative layer-size profile** but is width-
scaled and fed 16x16 synthetic images.  The RL search space dimension
(L layers x 8 bitwidths) and the cost-model weighting across layers — the
things that shape ReLeQ's search — are preserved exactly.

Quantizable-layer counts (the RL episode length L):

    lenet      4   (2 conv + 2 fc)                 — paper Table 2: 4
    simplenet  5   (4 conv + 1 fc)                 — paper Table 2: 5
    alexnet    8   (5 conv + 3 fc)                 — paper Table 2: 8
    vgg11      9   (8 conv + 1 fc)                 — paper Table 2: 9
    svhn10    10   (8 conv + 2 fc)                 — paper Table 2: 10
    resnet20  20   (stem + 9 blocks x 2 + fc)      — paper §1: l = 20
                   (paper's Table 2 row lists 23 entries, likely counting
                   shortcut projections; we use paramless option-A shortcuts)
    mobilenet 28   (conv + 13 x (dw + pw) + fc)    — paper Table 2 row lists 30
                   entries; standard MobileNet-V1 has 28 weight layers.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from .layers import ModelBuilder

INPUT_HW = 16
NUM_CLASSES = 10


def lenet() -> ModelBuilder:
    b = ModelBuilder("lenet", (INPUT_HW, INPUT_HW, 1), NUM_CLASSES)
    b.conv(8, ksize=5, pool=2)
    b.conv(16, ksize=5, pool=2)
    b.dense(64)
    b.dense(NUM_CLASSES, act=False)
    return b


def simplenet() -> ModelBuilder:
    b = ModelBuilder("simplenet", (INPUT_HW, INPUT_HW, 3), NUM_CLASSES)
    b.conv(16, pool=2)
    b.conv(16)
    b.conv(32, pool=2)
    b.conv(32, pool=2)
    b.dense(NUM_CLASSES, act=False)
    return b


def alexnet() -> ModelBuilder:
    b = ModelBuilder("alexnet", (INPUT_HW, INPUT_HW, 3), NUM_CLASSES)
    b.conv(12, ksize=5, pool=2)
    b.conv(24, pool=2)
    b.conv(32)
    b.conv(32)
    b.conv(24, pool=2)
    b.dense(96)
    b.dense(96)
    b.dense(NUM_CLASSES, act=False)
    return b


def vgg11() -> ModelBuilder:
    b = ModelBuilder("vgg11", (INPUT_HW, INPUT_HW, 3), NUM_CLASSES)
    b.conv(16, pool=2)
    b.conv(32, pool=2)
    b.conv(48)
    b.conv(48, pool=2)
    b.conv(64)
    b.conv(64)
    b.conv(64)
    b.conv(64, pool=2)
    b.dense(NUM_CLASSES, act=False)
    return b


def svhn10() -> ModelBuilder:
    b = ModelBuilder("svhn10", (INPUT_HW, INPUT_HW, 3), NUM_CLASSES)
    b.conv(16)
    b.conv(16, pool=2)
    b.conv(24)
    b.conv(24, pool=2)
    b.conv(32)
    b.conv(32, pool=2)
    b.conv(48)
    b.conv(48, pool=2)
    b.dense(64)
    b.dense(NUM_CLASSES, act=False)
    return b


def resnet20() -> ModelBuilder:
    b = ModelBuilder("resnet20", (INPUT_HW, INPUT_HW, 3), NUM_CLASSES)
    b.conv(8)  # stem
    widths = [8, 8, 8, 16, 16, 16, 32, 32, 32]
    strides = [1, 1, 1, 2, 1, 1, 2, 1, 1]
    for w, s in zip(widths, strides):
        b.begin_residual()
        b.conv(w, stride=s)
        b.conv(w, act=False)
        b.end_residual(stride=s)
    b.global_avg_pool()
    b.dense(NUM_CLASSES, act=False)
    return b


def mobilenet() -> ModelBuilder:
    """MobileNet-V1 profile: full conv stem, 13 depthwise-separable blocks
    (dw3x3 + pw1x1), global-avg-pool, classifier."""
    b = ModelBuilder("mobilenet", (INPUT_HW, INPUT_HW, 3), NUM_CLASSES)
    b.conv(8, stride=2)  # stem
    # (out_ch, dw_stride) per block, scaled from the 32..1024 original profile
    blocks = [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1),
              (64, 1), (64, 1), (64, 1), (64, 1), (64, 1),
              (96, 1), (128, 2), (128, 1)]
    for ch, s in blocks:
        b.dwconv(stride=s)
        b.conv1x1(ch)
    b.global_avg_pool()
    b.dense(NUM_CLASSES, act=False)
    return b


# Registry: name -> builder. Order matters (stable manifest / experiment order).
REGISTRY: Dict[str, Callable[[], ModelBuilder]] = {
    "lenet": lenet,
    "simplenet": simplenet,
    "alexnet": alexnet,
    "vgg11": vgg11,
    "svhn10": svhn10,
    "resnet20": resnet20,
    "mobilenet": mobilenet,
}

# Which synthetic dataset stands in for the paper's dataset (DESIGN.md §7).
DATASETS: Dict[str, str] = {
    "lenet": "mnist_syn",
    "simplenet": "cifar_syn",
    "alexnet": "imagenet_syn",
    "vgg11": "cifar_syn",
    "svhn10": "svhn_syn",
    "resnet20": "cifar_syn",
    "mobilenet": "imagenet_syn",
}


def build(name: str):
    """Returns (apply_fn, init_fn, builder) for a registered network."""
    return REGISTRY[name]().finalize()
