"""WRPN-style weight fake-quantization with straight-through-estimator gradients.

This is the quantized-training substrate the paper builds on (section 4.2,
eq. 1): weights are clipped to (-1, 1) and quantized mid-tread with ``k`` bits,
of which one bit is the sign:

    w_q = round((2^(k-1) - 1) * clip(w, -1, 1)) / (2^(k-1) - 1)

``k`` is a *runtime* operand (f32 scalar per layer) so a single AOT-lowered
HLO artifact serves every bitwidth pattern the RL agent explores.  A bitwidth
``k >= FP_BITS`` selects the identity (full-precision) path, used for
pretraining and for the Acc_FullP baseline.

The backward pass is the straight-through estimator: the quantizer behaves as
identity inside the clip range and kills the gradient outside it, matching
WRPN / DoReFa practice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Bitwidths >= FP_BITS mean "do not quantize" (full-precision path).
FP_BITS = 9.0


def quant_levels(k):
    """Number of positive quantization levels for bitwidth ``k`` (mid-tread).

    One of the ``k`` bits is the sign bit, leaving ``2^(k-1) - 1`` positive
    levels (zero is a level).  ``k`` may be a traced f32 scalar.
    """
    return jnp.exp2(k - 1.0) - 1.0


def quantize_mid_tread(w, k):
    """Mid-tread fake-quantization (zero IS a representable level)."""
    levels = quant_levels(k)
    wc = jnp.clip(w, -1.0, 1.0)
    return jnp.round(levels * wc) / levels


def quantize_mid_rise(w, k):
    """Mid-rise fake-quantization (levels shifted half a step; zero excluded).

    Provided for completeness — the paper (following WRPN) uses mid-tread.
    """
    levels = quant_levels(k)
    wc = jnp.clip(w, -1.0, 1.0)
    return (jnp.floor(levels * wc) + 0.5) / levels


@jax.custom_vjp
def fake_quant(w, k):
    """Fake-quantize ``w`` to ``k`` bits (mid-tread) with an STE gradient.

    ``k >= FP_BITS`` selects the identity path (full precision).
    """
    return jnp.where(k >= FP_BITS, w, quantize_mid_tread(w, k))


def _fake_quant_fwd(w, k):
    return fake_quant(w, k), (w, k)


def _fake_quant_bwd(res, g):
    w, k = res
    # STE: identity inside the clip range, zero outside; identity when the
    # full-precision path was taken.
    in_range = (jnp.abs(w) <= 1.0).astype(g.dtype)
    mask = jnp.where(k >= FP_BITS, jnp.ones_like(in_range), in_range)
    return g * mask, None


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def ste_mask(w, k):
    """The STE gradient mask used by ``fake_quant``'s VJP (exposed for the
    Pallas backward kernels and for the test oracle)."""
    in_range = (jnp.abs(w) <= 1.0).astype(w.dtype)
    return jnp.where(k >= FP_BITS, jnp.ones_like(in_range), in_range)
