"""Layer-2 building blocks: quantized layers over the L1 Pallas kernel.

Every *quantizable* layer (the units the RL agent assigns a bitwidth to) is
one of:

* ``dense``     — fully-connected; routed through the fused Pallas
                  ``qmatmul`` kernel (quantize-in-VMEM + MXU matmul).
* ``conv1x1``   — pointwise convolution (MobileNet); reshaped to a matmul and
                  routed through the same Pallas kernel (on TPU a 1x1 conv IS
                  an MXU matmul).
* ``conv``      — spatial convolution; weights go through ``fake_quant`` (same
                  math, same STE) and the conv itself through XLA's native
                  convolution. DESIGN.md §Hardware-Adaptation: on TPU, spatial
                  convs lower to the MXU via XLA's own im2col-free path, so the
                  Pallas fusion is applied where it pays (matmul-shaped ops).
* ``dwconv``    — depthwise spatial convolution (MobileNet), same treatment.

Biases are kept in full precision and excluded from the quantization cost
model, matching the paper's weight-only quantization (§2.4: "ReLeQ only
quantizes weights").
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .quant import fake_quant
from .kernels.qmatmul import qmatmul


@dataclasses.dataclass
class LayerMeta:
    """Metadata for one quantizable layer — mirrored into the manifest and the
    Rust cost model (State_of_Quantization, simulators, embeddings)."""

    name: str
    kind: str                 # dense | conv | conv1x1 | dwconv
    w_shape: Tuple[int, ...]  # weight tensor shape
    w_offset: int             # offset of the weight in the flat param vector
    w_len: int
    b_offset: int
    b_len: int
    n_macs: int               # MACs per example
    in_dim: int               # fan-in  (for the state embedding)
    out_dim: int              # fan-out


class ModelBuilder:
    """Builds a model as (flat-param layout, apply_fn, layer metadata).

    The parameter vector is a single flat f32 array so the Rust runtime can
    treat every network uniformly (one Literal in, one out); layers address it
    by static offsets recorded here and in the manifest.
    """

    def __init__(self, name: str, input_shape: Tuple[int, int, int], num_classes: int):
        self.name = name
        self.input_shape = input_shape  # (H, W, C)
        self.num_classes = num_classes
        self.layers: List[LayerMeta] = []
        self._applies: List[Callable] = []
        self._inits: List[Callable] = []
        self._offset = 0
        self._cur = input_shape  # tracks (H, W, C) through the graph

    # ---- parameter allocation -------------------------------------------------

    def _alloc(self, n: int) -> int:
        off = self._offset
        self._offset += n
        return off

    @property
    def param_count(self) -> int:
        return self._offset

    # ---- layer constructors ---------------------------------------------------

    def conv(self, out_ch: int, ksize: int = 3, stride: int = 1,
             pool: Optional[int] = None, act: bool = True) -> "ModelBuilder":
        """Spatial conv (SAME padding) + optional max-pool + optional ReLU."""
        h, w, cin = self._cur
        wshape = (ksize, ksize, cin, out_ch)
        wlen = ksize * ksize * cin * out_ch
        woff = self._alloc(wlen)
        boff = self._alloc(out_ch)
        ho, wo = -(-h // stride), -(-w // stride)
        macs = ho * wo * ksize * ksize * cin * out_ch
        idx = len(self.layers)
        self.layers.append(LayerMeta(
            name=f"conv{idx}", kind="conv", w_shape=wshape, w_offset=woff,
            w_len=wlen, b_offset=boff, b_len=out_ch, n_macs=macs,
            in_dim=ksize * ksize * cin, out_dim=out_ch))

        def apply(params, x, k, _w=(woff, wlen, wshape), _b=(boff, out_ch),
                  _s=stride, _pool=pool, _act=act):
            wt = params[_w[0]:_w[0] + _w[1]].reshape(_w[2])
            bt = params[_b[0]:_b[0] + _b[1]]
            wq = fake_quant(wt, k)
            y = lax.conv_general_dilated(
                x, wq, window_strides=(_s, _s), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            y = y + bt
            if _act:
                y = jax.nn.relu(y)
            if _pool:
                y = lax.reduce_window(y, -jnp.inf, lax.max,
                                      (1, _pool, _pool, 1), (1, _pool, _pool, 1),
                                      "VALID")
            return y

        def init(key, _w=wshape):
            fan_in = _w[0] * _w[1] * _w[2]
            std = (2.0 / fan_in) ** 0.5
            kw, _ = jax.random.split(key)
            return [jax.random.normal(kw, _w, jnp.float32).reshape(-1) * std,
                    jnp.zeros((_w[3],), jnp.float32)]

        self._applies.append(apply)
        self._inits.append(init)
        self._cur = (ho // (pool or 1), wo // (pool or 1), out_ch)
        return self

    def dwconv(self, ksize: int = 3, stride: int = 1) -> "ModelBuilder":
        """Depthwise spatial conv (SAME) + ReLU (MobileNet block, first half)."""
        h, w, cin = self._cur
        wshape = (ksize, ksize, 1, cin)
        wlen = ksize * ksize * cin
        woff = self._alloc(wlen)
        boff = self._alloc(cin)
        ho, wo = -(-h // stride), -(-w // stride)
        macs = ho * wo * ksize * ksize * cin
        idx = len(self.layers)
        self.layers.append(LayerMeta(
            name=f"dw{idx}", kind="dwconv", w_shape=wshape, w_offset=woff,
            w_len=wlen, b_offset=boff, b_len=cin, n_macs=macs,
            in_dim=ksize * ksize, out_dim=cin))

        def apply(params, x, k, _w=(woff, wlen, wshape), _b=(boff, cin), _s=stride,
                  _c=cin):
            wt = params[_w[0]:_w[0] + _w[1]].reshape(_w[2])
            bt = params[_b[0]:_b[0] + _b[1]]
            wq = fake_quant(wt, k)
            y = lax.conv_general_dilated(
                x, wq, window_strides=(_s, _s), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=_c)
            return jax.nn.relu(y + bt)

        def init(key, _w=wshape):
            fan_in = _w[0] * _w[1]
            std = (2.0 / fan_in) ** 0.5
            return [jax.random.normal(key, _w, jnp.float32).reshape(-1) * std,
                    jnp.zeros((_w[3],), jnp.float32)]

        self._applies.append(apply)
        self._inits.append(init)
        self._cur = (ho, wo, cin)
        return self

    def conv1x1(self, out_ch: int, act: bool = True) -> "ModelBuilder":
        """Pointwise conv — reshaped to (B*H*W, Cin) @ (Cin, Cout) through the
        fused Pallas qmatmul kernel."""
        h, w, cin = self._cur
        wshape = (cin, out_ch)
        wlen = cin * out_ch
        woff = self._alloc(wlen)
        boff = self._alloc(out_ch)
        macs = h * w * cin * out_ch
        idx = len(self.layers)
        self.layers.append(LayerMeta(
            name=f"pw{idx}", kind="conv1x1", w_shape=wshape, w_offset=woff,
            w_len=wlen, b_offset=boff, b_len=out_ch, n_macs=macs,
            in_dim=cin, out_dim=out_ch))

        def apply(params, x, k, _w=(woff, wlen, wshape), _b=(boff, out_ch), _act=act):
            wt = params[_w[0]:_w[0] + _w[1]].reshape(_w[2])
            bt = params[_b[0]:_b[0] + _b[1]]
            b, hh, ww, c = x.shape
            y = qmatmul(x.reshape(b * hh * ww, c), wt, k) + bt
            if _act:
                y = jax.nn.relu(y)
            return y.reshape(b, hh, ww, -1)

        def init(key, _w=wshape):
            std = (2.0 / _w[0]) ** 0.5
            return [jax.random.normal(key, _w, jnp.float32).reshape(-1) * std,
                    jnp.zeros((_w[1],), jnp.float32)]

        self._applies.append(apply)
        self._inits.append(init)
        self._cur = (h, w, out_ch)
        return self

    def dense(self, out_dim: int, act: bool = True) -> "ModelBuilder":
        """Fully-connected layer through the fused Pallas qmatmul kernel.
        Flattens spatial input if necessary."""
        if len(self._cur) == 3:
            in_dim = self._cur[0] * self._cur[1] * self._cur[2]
        else:
            in_dim = self._cur[0]
        wshape = (in_dim, out_dim)
        wlen = in_dim * out_dim
        woff = self._alloc(wlen)
        boff = self._alloc(out_dim)
        idx = len(self.layers)
        self.layers.append(LayerMeta(
            name=f"fc{idx}", kind="dense", w_shape=wshape, w_offset=woff,
            w_len=wlen, b_offset=boff, b_len=out_dim, n_macs=wlen,
            in_dim=in_dim, out_dim=out_dim))

        def apply(params, x, k, _w=(woff, wlen, wshape), _b=(boff, out_dim), _act=act):
            wt = params[_w[0]:_w[0] + _w[1]].reshape(_w[2])
            bt = params[_b[0]:_b[0] + _b[1]]
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            y = qmatmul(x, wt, k) + bt
            if _act:
                y = jax.nn.relu(y)
            return y

        def init(key, _w=wshape):
            std = (1.0 / _w[0]) ** 0.5
            return [jax.random.normal(key, _w, jnp.float32).reshape(-1) * std,
                    jnp.zeros((_w[1],), jnp.float32)]

        self._applies.append(apply)
        self._inits.append(init)
        self._cur = (out_dim,)
        return self

    # ---- non-parametric graph ops ----------------------------------------------

    def global_avg_pool(self) -> "ModelBuilder":
        h, w, c = self._cur

        def apply_nop(params, x, k):
            return jnp.mean(x, axis=(1, 2))

        # Non-quantizable op: fold into the previous layer's apply chain by
        # registering a passthrough (consumes no bits entry).
        self._applies.append(("nop", apply_nop))
        self._cur = (c,)
        return self

    def begin_residual(self) -> "ModelBuilder":
        """Push the current activation onto the residual stack (ResNet block)."""
        self._applies.append(("res_begin",))
        return self

    def end_residual(self, stride: int = 1) -> "ModelBuilder":
        """Pop the residual, align it (option-A shortcut: strided average pool +
        zero channel padding — paramless, so it adds no quantizable layer), add
        and ReLU.  The preceding conv should use ``act=False``."""
        self._applies.append(("res_end", stride))
        return self

    # ---- assembled model --------------------------------------------------------

    def finalize(self):
        """Returns (apply_fn, init_fn, self).

        apply_fn(params_flat, x_nhwc, bits[L]) -> logits
        init_fn(seed_scalar)                   -> params_flat
        """
        applies = list(self._applies)
        inits = list(self._inits)
        n_layers = len(self.layers)

        def apply_fn(params, x, bits):
            li = 0
            res_stack = []
            for entry in applies:
                if isinstance(entry, tuple):
                    tag = entry[0]
                    if tag == "nop":
                        x = entry[1](params, x, None)
                    elif tag == "res_begin":
                        res_stack.append(x)
                    elif tag == "res_end":
                        stride = entry[1]
                        sc = res_stack.pop()
                        if stride > 1:
                            sc = lax.reduce_window(
                                sc, 0.0, lax.add,
                                (1, stride, stride, 1), (1, stride, stride, 1),
                                "VALID") / float(stride * stride)
                        cdiff = x.shape[-1] - sc.shape[-1]
                        if cdiff > 0:
                            sc = jnp.pad(sc, ((0, 0), (0, 0), (0, 0), (0, cdiff)))
                        x = jax.nn.relu(x + sc)
                    else:  # pragma: no cover - defensive
                        raise ValueError(f"unknown marker {tag}")
                else:
                    x = entry(params, x, bits[li])
                    li += 1
            return x

        def init_fn(seed):
            key = jax.random.PRNGKey(seed)
            keys = jax.random.split(key, max(n_layers, 2))
            chunks = []
            for i, init in enumerate(inits):
                chunks.extend(init(keys[i]))
            return jnp.concatenate(chunks)

        return apply_fn, init_fn, self
