"""AOT compiler: lowers every Layer-2 computation to HLO-text artifacts.

Runs ONCE at build time (`make artifacts`); the Rust coordinator is fully
self-contained afterwards.  Outputs, under ``artifacts/``:

* ``<net>_init.hlo.txt``    (seed)                          -> (params, mom)
* ``<net>_train.hlo.txt``   (params, mom, x, y, bits, lr)   -> (params, mom, loss, acc)
* ``<net>_eval.hlo.txt``    (params, x, y, bits)            -> (loss, n_correct)
* ``<net>_retrain_eval.hlo.txt`` — fused k-step quantized retrain + eval with
  a device-resident training set (the coordinator's accuracy-query hot path;
  see EXPERIMENTS.md §Perf)
* ``<net>_retrain_eval_batch.hlo.txt`` — jax.vmap of the fused retrain+eval
  over ``EVAL_BATCH_K`` candidate bits lanes sharing one resident train/val
  set: one PJRT execution scores up to K candidate bitwidth vectors (the
  megabatch accuracy evaluator; manifest ``eval_batch_k``)
* ``agent_{lstm,fc}_init.hlo.txt``   (seed)                 -> params
* ``agent_{lstm,fc}_act.hlo.txt``    (params, s, h, c)      -> (probs, value, h', c')
* ``agent_{lstm,fc}_act_batch.hlo.txt`` (params, s[B,D], h[B,H], c[B,H])
  -> (probs[B,A], value[B], h'[B,H], c'[B,H]) — the lockstep-rollout hot
  path: one execution serves all B episode lanes of a PPO batch
* ``agent_lstm_update_l<L>.hlo.txt`` (11 operands)          -> (params', m', v', t', stats...)
  for every network episode length L (+ the FC ablation update for LeNet)
* ``manifest.json`` — shapes, flat-param layouts, per-layer metadata (weight
  offsets, MACs, fan-in/out) consumed by the Rust runtime and cost models.

Usage: ``python -m compile.aot --out-dir ../artifacts [--only lenet,agent]``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from . import agent as agent_mod
from . import models, train
from .hlo import lower_to_file

TRAIN_BATCH = 32
EVAL_BATCH = 512
TRAIN_SIZE = 2048  # resident training set for the fused retrain_eval artifact
EPISODES_PER_UPDATE = 8  # B: whole episodes per PPO minibatch
# K: candidate bits lanes per retrain_eval_batch execution. = the lockstep
# lane width, so one rollout step's worth of distinct candidates fits in one
# execution even when every lane proposes a different vector. Compile time
# of the vmapped unrolled graph grows ~K x, which the shallow (fused_k > 0)
# networks absorb; the deep nets skip the fused family entirely.
EVAL_BATCH_K = 8

# manifest.json format: schema 1 adds per-network `version` (monotonic,
# bumped when any artifact digest changes) and `sha256` (per-file digests,
# verified by the Rust loader and the serve registry). Versionless
# manifests load with digest checks skipped (legacy fallback).
SCHEMA_VERSION = 1


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# fused retrain+eval steps per network (matches rust config presets).
# 0 = no fused artifact: the unrolled form wins ~4-34% at small k but its
# compile time explodes with k * graph size, and the scan form is 1.5-2.5x
# SLOWER at run time than per-step execution on the CPU backend — so only
# the shallow networks get the fused artifact (EXPERIMENTS.md §Perf).
FUSED_K = {
    "lenet": 4, "simplenet": 4, "alexnet": 3, "vgg11": 3, "svhn10": 3,
    "resnet20": 0, "mobilenet": 0,
}


def artifact_files(name: str, fused_k: int) -> list:
    """The HLO artifacts a network emits (mirrors rust registry::expected_files)."""
    files = [f"{name}_init.hlo.txt", f"{name}_train.hlo.txt", f"{name}_eval.hlo.txt"]
    if fused_k > 0:
        files.append(f"{name}_retrain_eval.hlo.txt")
        files.append(f"{name}_retrain_eval_batch.hlo.txt")
    return files


def _digests(name: str, out_dir: str, fused_k: int) -> dict:
    out = {}
    for fname in artifact_files(name, fused_k):
        h = hashlib.sha256()
        with open(os.path.join(out_dir, fname), "rb") as f:
            h.update(f.read())
        out[fname] = h.hexdigest()
    return out


def lower_network(name: str, out_dir: str, manifest: dict,
                  old_networks: dict) -> None:
    apply_fn, init_fn, builder = models.build(name)
    init, train_step, evaluate = train.make_fns(apply_fn, init_fn)
    P = builder.param_count
    H, W, C = builder.input_shape
    L = len(builder.layers)
    fused_k = FUSED_K.get(name, 4)

    t0 = time.time()
    lower_to_file(init, (f32(),), os.path.join(out_dir, f"{name}_init.hlo.txt"))
    lower_to_file(
        train_step,
        (f32(P), f32(P), f32(TRAIN_BATCH, H, W, C), f32(TRAIN_BATCH), f32(L), f32()),
        os.path.join(out_dir, f"{name}_train.hlo.txt"))
    lower_to_file(
        evaluate,
        (f32(P), f32(EVAL_BATCH, H, W, C), f32(EVAL_BATCH), f32(L)),
        os.path.join(out_dir, f"{name}_eval.hlo.txt"))
    if fused_k > 0:
        fused = train.make_fused_retrain_eval(
            apply_fn, init_fn, fused_k, TRAIN_BATCH, unroll=True)
        lower_to_file(
            fused,
            (f32(P), f32(P), f32(TRAIN_SIZE, H, W, C), f32(TRAIN_SIZE), f32(),
             f32(L), f32(), f32(EVAL_BATCH, H, W, C), f32(EVAL_BATCH)),
            os.path.join(out_dir, f"{name}_retrain_eval.hlo.txt"))
        batched = train.make_batched_retrain_eval(
            apply_fn, init_fn, fused_k, TRAIN_BATCH, unroll=True)
        lower_to_file(
            batched,
            (f32(P), f32(P), f32(TRAIN_SIZE, H, W, C), f32(TRAIN_SIZE),
             f32(EVAL_BATCH_K), f32(EVAL_BATCH_K, L), f32(),
             f32(EVAL_BATCH, H, W, C), f32(EVAL_BATCH)),
            os.path.join(out_dir, f"{name}_retrain_eval_batch.hlo.txt"))
    dt = time.time() - t0

    digests = _digests(name, out_dir, fused_k)
    old = old_networks.get(name, {})
    old_version = int(old.get("version", 1))
    if not old.get("sha256"):
        version = 1  # first stamped emit (or legacy predecessor)
    elif old["sha256"] == digests:
        version = old_version  # bit-identical re-emit keeps its version
    else:
        version = old_version + 1  # the registry enforces monotonic upgrades

    manifest["networks"][name] = {
        "l": L,
        "p": P,
        "version": version,
        "sha256": digests,
        "fused_k": fused_k,
        # lanes baked into <net>_retrain_eval_batch (0 = no batch artifact,
        # same gate as the fused family; rust falls back to 0 when the key
        # predates the megabatch evaluator)
        "eval_batch_k": EVAL_BATCH_K if fused_k > 0 else 0,
        "train_size": TRAIN_SIZE,
        "input": [H, W, C],
        "classes": builder.num_classes,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "dataset": models.DATASETS[name],
        "layers": [
            {
                "name": lm.name,
                "kind": lm.kind,
                "w_shape": list(lm.w_shape),
                "w_offset": lm.w_offset,
                "w_len": lm.w_len,
                "b_offset": lm.b_offset,
                "b_len": lm.b_len,
                "n_macs": lm.n_macs,
                "in_dim": lm.in_dim,
                "out_dim": lm.out_dim,
            }
            for lm in builder.layers
        ],
    }
    print(f"[aot] {name}: L={L} P={P} ({dt:.1f}s)", flush=True)


def lower_agent(out_dir: str, manifest: dict, episode_lengths) -> None:
    D, A, B = agent_mod.STATE_DIM, agent_mod.N_ACTIONS, EPISODES_PER_UPDATE
    for recurrent, tag in ((True, "lstm"), (False, "fc")):
        P = agent_mod.param_count(recurrent)
        act = agent_mod.make_act(recurrent)

        def agent_init(seed, _rec=recurrent):
            return agent_mod.init_params_traced(seed, _rec)

        lower_to_file(agent_init, (f32(),),
                      os.path.join(out_dir, f"agent_{tag}_init.hlo.txt"))
        lower_to_file(
            act, (f32(P), f32(D), f32(agent_mod.HIDDEN), f32(agent_mod.HIDDEN)),
            os.path.join(out_dir, f"agent_{tag}_act.hlo.txt"))
        act_batch = agent_mod.make_act_batch(recurrent)
        lower_to_file(
            act_batch,
            (f32(P), f32(B, D), f32(B, agent_mod.HIDDEN), f32(B, agent_mod.HIDDEN)),
            os.path.join(out_dir, f"agent_{tag}_act_batch.hlo.txt"))
        manifest["agent"][tag] = {"p": P}
        print(f"[aot] agent_{tag}: P={P} (act_batch B={B})", flush=True)

    update = agent_mod.make_update(True)
    for L in sorted(set(episode_lengths)):
        P = agent_mod.param_count(True)
        lower_to_file(
            update,
            (f32(P), f32(P), f32(P), f32(),
             f32(B, L, D), f32(B, L), f32(B, L), f32(B, L), f32(B, L),
             f32(), f32(), f32()),
            os.path.join(out_dir, f"agent_lstm_update_l{L}.hlo.txt"))
        print(f"[aot] agent_lstm_update L={L}", flush=True)
    # FC-ablation update: only for the LeNet episode length (ablation A2).
    update_fc = agent_mod.make_update(False)
    L = min(episode_lengths)
    P = agent_mod.param_count(False)
    lower_to_file(
        update_fc,
        (f32(P), f32(P), f32(P), f32(),
         f32(B, L, D), f32(B, L), f32(B, L), f32(B, L), f32(B, L),
         f32(), f32(), f32()),
        os.path.join(out_dir, f"agent_fc_update_l{L}.hlo.txt"))
    print(f"[aot] agent_fc_update L={L}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: network names and/or 'agent'")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(filter(None, args.only.split(",")))

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {
        "schema_version": SCHEMA_VERSION,
        "fp_bits": 9.0,
        "bits_max": 8,
        "state_dim": agent_mod.STATE_DIM,
        "n_actions": agent_mod.N_ACTIONS,
        "hidden": agent_mod.HIDDEN,
        "episodes_per_update": EPISODES_PER_UPDATE,
        # lanes baked into the agent_*_act_batch artifacts (the lockstep
        # rollout batch width; = episodes_per_update so one PPO batch rolls
        # out in exactly one lane-set)
        "act_batch": EPISODES_PER_UPDATE,
        "networks": {},
        "agent": {},
    }
    old_networks = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        # prior entries feed version-bump detection on every run...
        old_networks = old.get("networks", {})
        if only:
            # ...and survive verbatim on incremental runs
            manifest["networks"].update(old_networks)
            manifest["agent"].update(old.get("agent", {}))

    t0 = time.time()
    for name in models.REGISTRY:
        if only and name not in only:
            continue
        lower_network(name, args.out_dir, manifest, old_networks)

    lengths = [net["l"] for net in manifest["networks"].values()]
    if not only or "agent" in only:
        if not lengths:
            print("[aot] no networks in manifest; skipping agent", file=sys.stderr)
        else:
            lower_agent(args.out_dir, manifest, lengths)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {args.out_dir}", flush=True)


if __name__ == "__main__":
    main()
