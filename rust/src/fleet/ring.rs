//! Consistent-hash ring over worker names.
//!
//! The router's job placement must be *sticky*: a given (net, env
//! fingerprint) pair should land on the same worker every time, so that
//! worker's QuantEnv / AccMemo session is already warm and the fleet as a
//! whole preserves the one-pretrain invariant. A consistent hash gives
//! that stickiness **and** minimal reshuffle: adding or removing one
//! worker moves only the keys that hash adjacent to its points — every
//! other session stays home, warm.
//!
//! Implementation is the classic vnode ring: each worker name is hashed
//! at [`DEFAULT_VNODES`] points (FNV-1a of `name` + vnode index, the
//! repo's one stable hash, so placement is identical across builds and
//! hosts), the points are kept sorted, and a key routes to the first
//! point clockwise from its own hash ([`Ring::route`]). Fallback order
//! for work stealing and health-aware skipping is the continued
//! clockwise walk ([`Ring::successors`]): deterministic, and distinct —
//! each worker appears once.

use crate::util::fnv::Fnv;

/// Vnodes per worker. 64 points per worker keeps the expected load
/// imbalance across a handful of workers within a few percent while the
/// whole ring stays a few-KB sorted Vec.
pub const DEFAULT_VNODES: usize = 64;

/// Immutable ring over worker indices `0..names.len()`.
pub struct Ring {
    /// Sorted (point hash, worker index). Ties (astronomically unlikely
    /// with 64-bit FNV) resolve by worker index via the tuple sort.
    points: Vec<(u64, usize)>,
    workers: usize,
}

impl Ring {
    pub fn new(names: &[String], vnodes: usize) -> Ring {
        let mut points = Vec::with_capacity(names.len() * vnodes);
        for (i, name) in names.iter().enumerate() {
            for v in 0..vnodes {
                let h = Fnv::new().write_str(name).write_u64(v as u64).finish();
                points.push((h, i));
            }
        }
        points.sort_unstable();
        Ring { points, workers: names.len() }
    }

    pub fn len(&self) -> usize {
        self.workers
    }

    pub fn is_empty(&self) -> bool {
        self.workers == 0
    }

    /// Home worker for `key`: owner of the first ring point at or after
    /// the key's hash, wrapping at the top.
    pub fn route(&self, key: u64) -> Option<usize> {
        self.successors(key).next()
    }

    /// Workers in ring order starting from `key`'s home, each yielded
    /// once. This is the steal / fallback order: position 0 is the home
    /// worker, later positions are progressively "colder" hosts.
    pub fn successors(&self, key: u64) -> Successors<'_> {
        let start = self.points.partition_point(|&(h, _)| h < key);
        Successors { ring: self, pos: start, emitted: 0, seen: vec![false; self.workers] }
    }
}

pub struct Successors<'a> {
    ring: &'a Ring,
    pos: usize,
    emitted: usize,
    seen: Vec<bool>,
}

impl Iterator for Successors<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.emitted < self.ring.workers {
            let (_, w) = self.ring.points[self.pos % self.ring.points.len()];
            self.pos += 1;
            if !self.seen[w] {
                self.seen[w] = true;
                self.emitted += 1;
                return Some(w);
            }
        }
        None
    }
}

/// Affinity key for a job: the session identity the workers themselves
/// warm caches under. Hashing the env fingerprint (which already folds
/// net + env config) with the net name again is cheap insurance against
/// fingerprint collisions across nets.
pub fn job_key(net: &str, env_fp: u64) -> u64 {
    Fnv::new().write_str(net).write_u64(env_fp).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("w{i}")).collect()
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let r = Ring::new(&names(3), DEFAULT_VNODES);
        for k in 0..200u64 {
            let key = job_key("net", k);
            let a = r.route(key).unwrap();
            let b = r.route(key).unwrap();
            assert_eq!(a, b);
            assert!(a < 3);
        }
    }

    #[test]
    fn successors_visit_every_worker_once() {
        let r = Ring::new(&names(4), DEFAULT_VNODES);
        let order: Vec<usize> = r.successors(job_key("net", 7)).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn removing_a_worker_only_moves_its_own_keys() {
        // Ring semantics, not Vec-index semantics: compare by NAME. With
        // ["w0","w1","w2"] vs ["w0","w2"], every key w1 did not own must
        // keep its owner name.
        let full = Ring::new(&names(3), DEFAULT_VNODES);
        let reduced_names = vec!["w0".to_string(), "w2".to_string()];
        let reduced = Ring::new(&reduced_names, DEFAULT_VNODES);
        let all = names(3);
        let mut moved = 0usize;
        for k in 0..500u64 {
            let key = job_key("net", k);
            let before = &all[full.route(key).unwrap()];
            let after = &reduced_names[reduced.route(key).unwrap()];
            if before == "w1" {
                moved += 1; // orphaned keys must land somewhere
            } else {
                assert_eq!(before, after, "key {k} moved off a surviving worker");
            }
        }
        assert!(moved > 0, "w1 owned no keys — vnode spread is broken");
    }

    #[test]
    fn joining_a_worker_only_claims_keys_for_itself() {
        let small = Ring::new(&names(3), DEFAULT_VNODES);
        let grown = Ring::new(&names(4), DEFAULT_VNODES);
        let mut claimed = 0usize;
        for k in 0..500u64 {
            let key = job_key("net", k);
            let before = small.route(key).unwrap();
            let after = grown.route(key).unwrap();
            if after == 3 {
                claimed += 1;
            } else {
                assert_eq!(before, after, "key {k} moved between pre-existing workers");
            }
        }
        assert!(claimed > 0, "the new worker claimed nothing");
    }

    #[test]
    fn load_spread_is_roughly_uniform() {
        let r = Ring::new(&names(4), DEFAULT_VNODES);
        let mut counts = [0usize; 4];
        for k in 0..4000u64 {
            counts[r.route(job_key("net", k)).unwrap()] += 1;
        }
        for &c in &counts {
            // expected 1000 each; 64 vnodes keeps skew well inside 2x
            assert!(c > 400 && c < 2000, "skewed spread: {counts:?}");
        }
    }
}
