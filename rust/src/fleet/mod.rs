//! `releq fleet`: a front-end router over N `releq serve` workers.
//!
//! One `releq serve` daemon is bounded by one process's engine pool. The
//! fleet scales the serve surface horizontally without giving up the two
//! properties that make the daemon fast — warm sessions and the solution
//! archive:
//!
//! * **Consistent-hash placement** ([`ring`]): jobs route by session key
//!   (net + env-config fingerprint), so repeat jobs land on the worker
//!   that already pretrained that exact env. One pretrain per session
//!   fleet-wide, not per worker.
//! * **Health-aware fallback + work stealing** ([`router`]): a down or
//!   draining home worker is skipped (least-loaded fallback), and a home
//!   worker answering 429 hands the job to up to `--steal-budget` ring
//!   successors before the 429 reaches the client.
//! * **Archive replication** ([`merge`]): periodic pull-merge rounds make
//!   every worker's solved records visible fleet-wide (content-addressed
//!   union, max hit count wins), so an exact resubmission is a zero-eval
//!   archive hit at any entry point.
//! * **Keep-alive transport** (`serve::http`): router→worker requests
//!   multiplex over pooled persistent connections.
//!
//! The router itself holds no engine, no artifacts, and no sessions — it
//! can run anywhere. Workers are spawned as child processes
//! (`--spawn-workers N`, ephemeral ports, per-worker archives) and/or
//! joined at known addresses (`--worker-addrs host:port,...`).

pub mod merge;
pub mod ring;
pub mod router;

pub use merge::RoundStats;
pub use ring::{job_key, Ring, DEFAULT_VNODES};
pub use router::{Health, Router, Worker};

use std::io::BufRead;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::FleetConfig;
use crate::serve::http::{self, Request, Response};
use crate::serve::{page_params, Archive};
use crate::util::json::Json;
use crate::util::lock_recover;
use crate::util::signals;

/// Budget for one worker's drain during fleet shutdown — generous, since
/// a drain finishes every in-flight search episode.
const SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(600);

/// Shared fleet state handed to every connection thread.
pub struct Fleet {
    pub router: Arc<Router>,
    /// the fleet-wide merged archive (what `GET /v1/archive` serves)
    pub archive: Arc<Archive>,
    cfg: FleetConfig,
    local_addr: SocketAddr,
    shutdown: AtomicBool,
    /// spawned `releq serve` child processes (empty for pure joins)
    children: Mutex<Vec<Child>>,
    merge_rounds: AtomicU64,
    last_merge: Mutex<RoundStats>,
}

/// The bound-but-not-yet-serving fleet front end; `bind` then `run`.
pub struct FleetServer {
    listener: TcpListener,
    fleet: Arc<Fleet>,
}

impl FleetServer {
    pub fn bind(cfg: FleetConfig) -> Result<FleetServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        let archive = Arc::new(Archive::open(&cfg.archive)?);
        let mut workers: Vec<Arc<Worker>> = Vec::new();
        let mut children = Vec::new();
        for i in 0..cfg.spawn_workers {
            let (w, child) = spawn_worker(i, &cfg)?;
            workers.push(Arc::new(w));
            children.push(child);
        }
        for addr in &cfg.worker_addrs {
            // joined workers are named by address — stable across router
            // restarts, which keeps ring placement stable too
            workers.push(Arc::new(Worker::new(addr, addr)));
        }
        // one synchronous probe so the first route sees real health/load
        for w in &workers {
            w.probe();
        }
        let router = Arc::new(Router::new(workers, cfg.steal_budget));
        Ok(FleetServer {
            listener,
            fleet: Arc::new(Fleet {
                router,
                archive,
                cfg,
                local_addr,
                shutdown: AtomicBool::new(false),
                children: Mutex::new(children),
                merge_rounds: AtomicU64::new(0),
                last_merge: Mutex::new(RoundStats::default()),
            }),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.fleet.local_addr
    }

    pub fn fleet(&self) -> Arc<Fleet> {
        self.fleet.clone()
    }

    /// Accept loop plus the background threads (health monitor, periodic
    /// merge, signal watcher). Returns after a `POST /v1/shutdown` — or a
    /// SIGTERM/SIGINT — has merged archives, drained the workers, and
    /// persisted the fleet archive.
    pub fn run(self) -> Result<()> {
        signals::install();
        {
            let f = self.fleet.clone();
            std::thread::spawn(move || loop {
                if f.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if signals::triggered() {
                    eprintln!("[fleet] termination signal: draining workers");
                    f.interrupt();
                    return;
                }
                std::thread::sleep(Duration::from_millis(100));
            });
        }
        let f = self.fleet.clone();
        std::thread::spawn(move || health_loop(&f));
        if self.fleet.cfg.merge_interval_ms > 0 {
            let f = self.fleet.clone();
            std::thread::spawn(move || merge_loop(&f));
        }
        for conn in self.listener.incoming() {
            if self.fleet.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[fleet] accept error: {e}");
                    continue;
                }
            };
            let f = self.fleet.clone();
            std::thread::spawn(move || handle_conn(&f, stream));
        }
        self.fleet.reap_children();
        Ok(())
    }
}

fn handle_conn(f: &Arc<Fleet>, stream: TcpStream) {
    let st = http::serve_conn(stream, f.cfg.access_log, "fleet", |req| route(f, req));
    if st.exit {
        f.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(f.local_addr); // kick the accept loop
    }
}

fn health_loop(f: &Arc<Fleet>) {
    let interval = Duration::from_millis(f.cfg.health_interval_ms);
    // seeded from the startup probes: a worker that was already Down at
    // bind doesn't fire a spurious failover on the first round
    let mut was_down: Vec<bool> =
        f.router.workers.iter().map(|w| w.health() == Health::Down).collect();
    while !f.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(interval);
        for (i, w) in f.router.workers.iter().enumerate() {
            let down = w.probe() == Health::Down;
            if down && !was_down[i] {
                // Up→Down transition: re-dispatch this worker's in-flight
                // jobs to ring successors (checkpoint replication lets the
                // successor resume them rather than restart)
                eprintln!("[fleet] worker {} went down", w.name);
                let moved = f.router.failover(i);
                if moved > 0 {
                    eprintln!("[fleet] re-dispatched {moved} in-flight job(s) from {}", w.name);
                }
            }
            was_down[i] = down;
        }
    }
}

fn merge_loop(f: &Arc<Fleet>) {
    let interval = Duration::from_millis(f.cfg.merge_interval_ms);
    while !f.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(interval);
        if f.shutdown.load(Ordering::SeqCst) {
            break;
        }
        f.run_merge();
    }
}

impl Fleet {
    /// One replication round: pull-merge every reachable worker, push the
    /// union back out, persist the merged archive (throttled). Durable
    /// fleets also replicate search checkpoints worker→worker in the same
    /// round, so a ring successor can resume a failed-over job from its
    /// last checkpoint instead of restarting it.
    pub fn run_merge(&self) -> RoundStats {
        let mut round = merge::merge_round(&self.router.workers, &self.archive);
        if self.cfg.durable {
            round.checkpoints_replicated = merge::checkpoint_round(&self.router.workers);
        }
        self.merge_rounds.fetch_add(1, Ordering::Relaxed);
        *lock_recover(&self.last_merge) = round.clone();
        if let Err(e) = self.archive.save_throttled(Duration::from_secs(5)) {
            eprintln!("[fleet] archive save after merge failed: {e:#}");
        }
        round
    }

    /// Signal-driven shutdown: the same sequence as `POST /v1/shutdown`
    /// (final replication round, drain every reachable worker, persist the
    /// merged archive) without an HTTP requester to answer.
    pub fn interrupt(&self) {
        let _ = merge::merge_round(&self.router.workers, &self.archive);
        for w in &self.router.workers {
            if let Err(e) = w.call_timeout("POST", "/v1/shutdown", None, SHUTDOWN_TIMEOUT) {
                eprintln!("[fleet] worker {} did not drain: {e:#}", w.name);
            }
        }
        if let Err(e) = self.archive.save() {
            eprintln!("[fleet] archive save on shutdown failed: {e:#}");
        }
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.local_addr); // kick the accept loop
    }

    /// Wait briefly for spawned workers to exit on their own (they were
    /// just asked to shut down), then make sure.
    fn reap_children(&self) {
        let mut children = lock_recover(&self.children);
        for _ in 0..50 {
            if children.iter_mut().all(|c| matches!(c.try_wait(), Ok(Some(_)))) {
                break;
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        for c in children.iter_mut() {
            if !matches!(c.try_wait(), Ok(Some(_))) {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
}

/// Dispatch one request. The bool asks the accept loop to exit (completed
/// fleet shutdown). Same surface as one worker, plus
/// `POST /v1/fleet/merge` to force a replication round.
pub fn route(f: &Fleet, req: &Request) -> (Response, bool) {
    let path = req.path.split('?').next().unwrap_or("");
    let segs: Vec<&str> = path.trim_matches('/').split('/').collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "jobs"]) => {
            let body = match req.json() {
                Ok(j) => j,
                Err(e) => return (Response::error(400, &format!("{e:#}")), false),
            };
            (f.router.submit(&body), false)
        }
        ("GET", ["v1", "jobs"]) => (list_jobs(f, req), false),
        ("GET", ["v1", "jobs", id]) => (f.router.forward_job(id, "GET", ""), false),
        ("GET", ["v1", "jobs", id, "result"]) => {
            (f.router.forward_job(id, "GET", "/result"), false)
        }
        ("POST", ["v1", "jobs", id, "cancel"]) => {
            (f.router.forward_job(id, "POST", "/cancel"), false)
        }
        ("GET", ["v1", "archive"]) => (list_archive(f, req), false),
        ("POST", ["v1", "archive", "merge"]) => (merge_in(f, req), false),
        ("POST", ["v1", "fleet", "merge"]) => {
            let round = f.run_merge();
            let mut out = match round.to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("RoundStats::to_json returns an object"),
            };
            out.insert("records".to_string(), Json::Num(f.archive.len() as f64));
            (Response::ok(Json::Obj(out)), false)
        }
        ("GET", ["v1", "stats"]) => (stats(f), false),
        ("GET", ["v1", "health"]) => (f.router.health(), false),
        ("POST", ["v1", "networks"]) => {
            let body = match req.json() {
                Ok(j) => j,
                Err(e) => return (Response::error(400, &format!("{e:#}")), false),
            };
            (f.router.broadcast("POST", "/v1/networks", &body), false)
        }
        ("POST", ["v1", "shutdown"]) => shutdown_fleet(f),
        _ => {
            let known = matches!(
                segs.as_slice(),
                ["v1", "jobs"]
                    | ["v1", "jobs", _]
                    | ["v1", "jobs", _, "result"]
                    | ["v1", "jobs", _, "cancel"]
                    | ["v1", "archive"]
                    | ["v1", "archive", "merge"]
                    | ["v1", "fleet", "merge"]
                    | ["v1", "stats"]
                    | ["v1", "health"]
                    | ["v1", "networks"]
                    | ["v1", "shutdown"]
            );
            if known {
                (Response::error(405, "method not allowed for this endpoint"), false)
            } else {
                (Response::error(404, "no such endpoint"), false)
            }
        }
    }
}

/// `GET /v1/jobs` on the fleet surface: fleet-id cursor over the router's
/// job table (same `?cursor=&limit=` contract as one worker).
fn list_jobs(f: &Fleet, req: &Request) -> Response {
    let (cursor, limit) = match page_params(req) {
        Ok(p) => p,
        Err(resp) => return resp,
    };
    let cursor = match cursor {
        None => None,
        Some(c) => match c.parse::<u64>() {
            Ok(n) => Some(n),
            Err(_) => return Response::error(400, "cursor must be a job id"),
        },
    };
    f.router.list_jobs(cursor, limit)
}

/// `GET /v1/archive` serves the MERGED fleet archive (complete as of the
/// last replication round).
fn list_archive(f: &Fleet, req: &Request) -> Response {
    let (cursor, limit) = match page_params(req) {
        Ok(p) => p,
        Err(resp) => return resp,
    };
    let (records, next) = f.archive.page(cursor.as_deref(), limit);
    Response::ok(Json::obj(vec![
        ("records", Json::Obj(records.into_iter().collect())),
        ("next_cursor", next.map(Json::Str).unwrap_or(Json::Null)),
    ]))
}

/// `POST /v1/archive/merge` into the merged archive — lets an external
/// feed (another fleet, a backup) seed records; the next push round
/// propagates them to the workers.
fn merge_in(f: &Fleet, req: &Request) -> Response {
    let body = match req.json() {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    match f.archive.merge_json(&body) {
        Ok(st) => {
            let mut out = match st.to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("MergeStats::to_json returns an object"),
            };
            out.insert("records".to_string(), Json::Num(f.archive.len() as f64));
            Response::ok(Json::Obj(out))
        }
        Err(e) => Response::error(400, &format!("{e:#}")),
    }
}

fn stats(f: &Fleet) -> Response {
    let extra = vec![
        (
            "archive",
            Json::obj(vec![
                ("path", Json::Str(f.archive.path().display().to_string())),
                ("records", Json::Num(f.archive.len() as f64)),
            ]),
        ),
        (
            "merge",
            Json::obj(vec![
                (
                    "rounds",
                    Json::Num(f.merge_rounds.load(Ordering::Relaxed) as f64),
                ),
                ("last", lock_recover(&f.last_merge).to_json()),
            ]),
        ),
    ];
    Response::ok(f.router.stats(extra))
}

/// Fleet shutdown: final replication round (no worker's solutions are
/// lost), drain every reachable worker, persist the merged archive. Dead
/// workers are tolerated — a fleet that lost a worker still exits clean.
fn shutdown_fleet(f: &Fleet) -> (Response, bool) {
    let round = merge::merge_round(&f.router.workers, &f.archive);
    let mut drained = 0usize;
    let mut unreachable = 0usize;
    for w in &f.router.workers {
        match w.call_timeout("POST", "/v1/shutdown", None, SHUTDOWN_TIMEOUT) {
            Ok((200, _)) => drained += 1,
            Ok(_) | Err(_) => unreachable += 1,
        }
    }
    let body = vec![
        ("drained_workers", Json::Num(drained as f64)),
        ("unreachable_workers", Json::Num(unreachable as f64)),
        ("final_merge", round.to_json()),
        ("archived_records", Json::Num(f.archive.len() as f64)),
    ];
    match f.archive.save() {
        Ok(()) => (Response::ok(Json::obj(body)), true),
        Err(e) => (
            Response::error(500, &format!("workers drained, but archive save failed: {e:#}")),
            true,
        ),
    }
}

/// Per-worker archive path: `<stem>.w{i}.json` beside the fleet archive.
fn worker_archive(base: &std::path::Path, i: usize) -> std::path::PathBuf {
    let stem = base
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("fleet_archive");
    base.with_file_name(format!("{stem}.w{i}.json"))
}

/// Per-worker durability paths (only used with `--durable`): the job WAL
/// `<stem>.w{i}.wal` and the checkpoint directory `<stem>.w{i}.ckpt`, both
/// beside the fleet archive like the per-worker archives.
fn worker_wal(base: &std::path::Path, i: usize) -> std::path::PathBuf {
    let stem = base
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("fleet_archive");
    base.with_file_name(format!("{stem}.w{i}.wal"))
}

fn worker_ckpt_dir(base: &std::path::Path, i: usize) -> std::path::PathBuf {
    let stem = base
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("fleet_archive");
    base.with_file_name(format!("{stem}.w{i}.ckpt"))
}

/// Spawn one `releq serve` child on an ephemeral port and parse its
/// listening address off stdout. The child's remaining output is echoed
/// with a `[w{i}]` prefix so fleet logs stay attributable.
fn spawn_worker(i: usize, cfg: &FleetConfig) -> Result<(Worker, Child)> {
    let exe = std::env::current_exe().context("resolving the releq binary for worker spawn")?;
    let archive = worker_archive(&cfg.archive, i);
    let mut cmd = Command::new(exe);
    cmd.arg("serve")
        .args(["--addr", "127.0.0.1:0"])
        .arg("--archive")
        .arg(&archive)
        .args(["--workers", &cfg.worker_threads.to_string()])
        .args(["--queue-cap", &cfg.worker_queue_cap.to_string()])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if cfg.durable {
        // per-worker job journal + checkpoint dir: a crashed worker's jobs
        // recover on ITS restart, while checkpoint replication (the merge
        // loop) lets OTHER workers resume them on failover
        cmd.arg("--wal").arg(worker_wal(&cfg.archive, i));
        cmd.arg("--checkpoint-dir").arg(worker_ckpt_dir(&cfg.archive, i));
    }
    if cfg.access_log {
        cmd.arg("--access-log");
    }
    let mut child = cmd.spawn().with_context(|| format!("spawning worker {i}"))?;
    let stdout = child.stdout.take().context("worker stdout")?;
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    // the listening line is among the first prints; engine bring-up
    // happens before bind, so just read until we see it (or EOF = the
    // worker died, e.g. missing artifacts)
    for _ in 0..64 {
        line.clear();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        eprintln!("[w{i}] {}", line.trim_end());
        if let Some(pos) = line.find("listening on http://") {
            addr = Some(line[pos + "listening on http://".len()..].trim().to_string());
            break;
        }
    }
    let Some(addr) = addr else {
        let _ = child.kill();
        let _ = child.wait();
        anyhow::bail!("worker {i} exited before reporting a listening address");
    };
    std::thread::spawn(move || {
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => eprintln!("[w{i}] {}", line.trim_end()),
            }
        }
    });
    Ok((Worker::new(&format!("w{i}"), &addr), child))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_archive_paths_sit_beside_the_fleet_archive() {
        let base = std::path::Path::new("/data/fleet_archive.json");
        assert_eq!(
            worker_archive(base, 0),
            std::path::Path::new("/data/fleet_archive.w0.json")
        );
        assert_eq!(
            worker_archive(std::path::Path::new("arch.json"), 2),
            std::path::Path::new("arch.w2.json")
        );
    }

    #[test]
    fn worker_durability_paths_sit_beside_the_fleet_archive() {
        let base = std::path::Path::new("/data/fleet_archive.json");
        assert_eq!(
            worker_wal(base, 1),
            std::path::Path::new("/data/fleet_archive.w1.wal")
        );
        assert_eq!(
            worker_ckpt_dir(base, 1),
            std::path::Path::new("/data/fleet_archive.w1.ckpt")
        );
    }
}
