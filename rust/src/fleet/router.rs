//! The fleet's front end: job placement, forwarding, and aggregation.
//!
//! The router owns the public HTTP surface and forwards every job to one
//! of N `releq serve` workers. Placement is the consistent hash in
//! [`super::ring`] keyed on the job's session identity (net + env config
//! fingerprint), so repeat jobs land on the worker whose QuantEnv /
//! AccMemo is already warm — the one-pretrain invariant, fleet-wide.
//! When the home worker is unavailable the fallback order is
//! health-aware and least-loaded: ring successors, with the tail sorted
//! by each worker's last observed queue depth. A home worker answering
//! 429 (queue full) triggers bounded work stealing — up to
//! `steal_budget` additional workers are offered the job before the 429
//! is surfaced to the client.
//!
//! Transport is the keep-alive [`Conn`] pool, one pool per worker:
//! router→worker exchanges reuse sockets instead of paying TCP setup per
//! request. One sharp edge is inherent to that design: a pooled
//! connection can go stale (worker restarted, idle timeout fired), which
//! surfaces as an error on the NEXT request. The pool retries exactly
//! once on a fresh dial. For a `POST /v1/jobs` this can double-submit if
//! the stale connection actually delivered the request before dying —
//! bounded waste, not corruption: the duplicate lands on the same warm
//! session and (for archive-hit jobs) costs zero evaluations.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config;
use crate::serve::env_fingerprint;
use crate::serve::http::{self, Conn, Response};
use crate::util::json::Json;
use crate::util::lock_recover;

use super::ring::{job_key, Ring, DEFAULT_VNODES};

/// Pooled keep-alive connections kept per worker. Two is enough for the
/// router's concurrency sweet spot (submissions + a poll stream); excess
/// connections are simply closed on return.
const POOL_CAP: usize = 2;
/// Fleet job-table retention. Old completed mappings age out lowest-id
/// first, mirroring the workers' own finished-job retention.
const JOB_TABLE_CAP: usize = 4096;
/// Health-probe budget: a hung worker costs milliseconds per round.
pub const PROBE_TIMEOUT: Duration = Duration::from_secs(2);

/// Health as last observed by the monitor thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// `/v1/health` answered 200
    Up,
    /// reachable but degraded (health answered non-200: breaker open,
    /// watchdog tripped, draining)
    Degraded,
    /// unreachable
    Down,
}

const H_UP: u8 = 0;
const H_DEGRADED: u8 = 1;
const H_DOWN: u8 = 2;

/// One worker as the router sees it: address, health, load estimate, and
/// a keep-alive connection pool.
pub struct Worker {
    /// display name (`w0`.. for spawned workers, the address for joins)
    pub name: String,
    pub addr: String,
    health: AtomicU8,
    /// last observed `queue_depth + running` from the health probe — the
    /// "least-loaded" ordering key for fallback placement
    load: AtomicU64,
    /// jobs this router routed here (lifetime counter)
    pub routed: AtomicU64,
    pool: Mutex<Vec<Conn>>,
}

impl Worker {
    pub fn new(name: &str, addr: &str) -> Worker {
        Worker {
            name: name.to_string(),
            addr: addr.to_string(),
            // optimistic start: workers are probed before the first route
            health: AtomicU8::new(H_UP),
            load: AtomicU64::new(0),
            routed: AtomicU64::new(0),
            pool: Mutex::new(Vec::new()),
        }
    }

    pub fn health(&self) -> Health {
        match self.health.load(Ordering::Relaxed) {
            H_UP => Health::Up,
            H_DEGRADED => Health::Degraded,
            _ => Health::Down,
        }
    }

    /// Reachable (Up or Degraded) — the merge loop still replicates with
    /// a degraded worker; only routing avoids it.
    pub fn is_up(&self) -> bool {
        self.health.load(Ordering::Relaxed) != H_DOWN
    }

    /// Eligible for new job placements.
    pub fn routable(&self) -> bool {
        self.health.load(Ordering::Relaxed) == H_UP
    }

    pub fn load(&self) -> u64 {
        self.load.load(Ordering::Relaxed)
    }

    fn set_health(&self, h: u8) {
        self.health.store(h, Ordering::Relaxed);
    }

    /// One `/v1/health` probe: updates health state and the load
    /// estimate. Called by the fleet's monitor thread and once at
    /// startup before the first route.
    pub fn probe(&self) -> Health {
        match http::request_timeout(&self.addr, "GET", "/v1/health", None, PROBE_TIMEOUT) {
            Ok((status, body)) => {
                let depth = body.get("queue_depth").and_then(Json::as_f64).unwrap_or(0.0);
                let running = body.get("running").and_then(Json::as_f64).unwrap_or(0.0);
                self.load.store((depth + running) as u64, Ordering::Relaxed);
                self.set_health(if status == 200 { H_UP } else { H_DEGRADED });
            }
            Err(_) => self.set_health(H_DOWN),
        }
        self.health()
    }

    /// One request over the pooled keep-alive transport. A stale pooled
    /// connection is retried exactly once on a fresh dial (see the module
    /// docs for the double-submit caveat). A transport failure on the
    /// fresh dial marks the worker Down immediately — the health monitor
    /// will bring it back when it answers again.
    pub fn call(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        if let Some(mut c) = lock_recover(&self.pool).pop() {
            if let Ok(r) = c.request(method, path, body) {
                self.recycle(c);
                return Ok(r);
            }
            // stale pooled socket — fall through to a fresh dial
        }
        let mut c = match Conn::connect(&self.addr) {
            Ok(c) => c,
            Err(e) => {
                self.set_health(H_DOWN);
                return Err(e);
            }
        };
        match c.request(method, path, body) {
            Ok(r) => {
                self.recycle(c);
                Ok(r)
            }
            Err(e) => {
                self.set_health(H_DOWN);
                Err(e)
            }
        }
    }

    /// Close-mode request with an explicit budget — the merge loop's
    /// transport (periodic bulk transfer doesn't need the pool, and must
    /// not hang behind a wedged worker).
    pub fn call_timeout(
        &self, method: &str, path: &str, body: Option<&Json>, timeout: Duration,
    ) -> Result<(u16, Json)> {
        http::request_timeout(&self.addr, method, path, body, timeout)
    }

    fn recycle(&self, c: Conn) {
        if c.is_reusable() {
            let mut pool = lock_recover(&self.pool);
            if pool.len() < POOL_CAP {
                pool.push(c);
            }
        }
    }
}

/// Router-side counters, surfaced under `router` in `/v1/stats`.
#[derive(Default)]
pub struct Counters {
    /// jobs successfully placed
    pub routed: AtomicU64,
    /// ... on their consistent-hash home worker
    pub routed_home: AtomicU64,
    /// ... on another worker after the home answered 429 (work stealing)
    pub stolen: AtomicU64,
    /// ... on another worker because the home was down/degraded/draining
    pub rerouted: AtomicU64,
    /// submissions the whole fleet refused (every candidate full/down)
    pub shed: AtomicU64,
    /// in-flight jobs re-dispatched to a ring successor after their worker
    /// was observed Down
    pub failed_over: AtomicU64,
    /// failover attempts that found no live worker to take the job (the
    /// job stays tracked; a later Down transition retries it)
    pub failover_shed: AtomicU64,
}

impl Counters {
    fn json(&self) -> Json {
        let n = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("routed", n(&self.routed)),
            ("routed_home", n(&self.routed_home)),
            ("stolen", n(&self.stolen)),
            ("rerouted", n(&self.rerouted)),
            ("shed", n(&self.shed)),
            ("failed_over", n(&self.failed_over)),
            ("failover_shed", n(&self.failover_shed)),
        ])
    }
}

/// Everything the router must retain to survive losing the worker a job
/// was placed on: the submitted spec (verbatim, idempotency key already
/// injected), the placement key, and whether the job ever reached a
/// terminal status (terminal jobs are never re-dispatched).
#[derive(Clone)]
struct Tracked {
    /// worker index currently owning the job
    wi: usize,
    /// worker-local job id on that worker
    rid: u64,
    /// the forwarded submission body — replayable verbatim on failover
    body: Json,
    /// consistent-hash placement key (session identity)
    key: u64,
    /// last observed terminal state, if any
    done: bool,
}

/// Placement + forwarding state. Shared (behind `Arc`) between the fleet
/// server's connection threads.
pub struct Router {
    pub workers: Vec<Arc<Worker>>,
    ring: Ring,
    steal_budget: usize,
    /// fleet job id → tracked placement (spec retained for failover)
    jobs: Mutex<BTreeMap<u64, Tracked>>,
    next_id: AtomicU64,
    /// sequence for router-generated idempotency keys
    idem_seq: AtomicU64,
    pub counters: Counters,
}

impl Router {
    pub fn new(workers: Vec<Arc<Worker>>, steal_budget: usize) -> Router {
        let names: Vec<String> = workers.iter().map(|w| w.name.clone()).collect();
        Router {
            ring: Ring::new(&names, DEFAULT_VNODES),
            workers,
            steal_budget,
            jobs: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            idem_seq: AtomicU64::new(0),
            counters: Counters::default(),
        }
    }

    /// Router-generated idempotency key: unique per (process, submission)
    /// so retries of one logical job — pool double-submits, failover
    /// re-dispatch landing where the job already ran — dedupe on the
    /// worker, while distinct submissions of the same config never do.
    fn generate_idem_key(&self) -> String {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let seq = self.idem_seq.fetch_add(1, Ordering::Relaxed);
        format!("fleet-{:x}-{:x}-{}", std::process::id(), nanos, seq)
    }

    /// Candidate order for a job: consistent-hash home first, then the
    /// remaining ring successors sorted by observed load (stable sort, so
    /// equal loads keep deterministic ring order).
    fn placement(&self, key: u64) -> Vec<usize> {
        let mut order: Vec<usize> = self.ring.successors(key).collect();
        if order.len() > 1 {
            order[1..].sort_by_key(|&i| self.workers[i].load());
        }
        order
    }

    /// `POST /v1/jobs`: validate, place, forward, and rewrite ids.
    ///
    /// The router parses the body only to validate early (a 400 must not
    /// consume fleet capacity or steal budget) and to derive the affinity
    /// key; the submission forwarded to the worker is the same JSON. The
    /// worker derives its archive fingerprints from the PARSED config,
    /// so routing through the fleet cannot perturb them — the
    /// bit-identical guarantee holds by construction.
    pub fn submit(&self, body: &Json) -> Response {
        let spec = match config::job_from_json(body) {
            Ok(s) => s,
            Err(e) => return Response::error(400, &format!("{e:#}")),
        };
        // every fleet job carries an idempotency key — the client's when
        // supplied, a router-generated one otherwise. Workers dedupe on
        // it, which makes both the keep-alive pool's retry-once and the
        // failover re-dispatch at-most-once-per-worker instead of
        // at-least-once.
        let forwarded = match (&spec.idempotency_key, body) {
            (Some(_), _) => body.clone(),
            (None, Json::Obj(m)) => {
                let mut m = m.clone();
                m.insert(
                    "idempotency_key".to_string(),
                    Json::Str(self.generate_idem_key()),
                );
                Json::Obj(m)
            }
            (None, other) => other.clone(),
        };
        // bits_max=0: the router doesn't resolve the network (that needs
        // the worker's registry); a fixed value keeps the key a pure
        // function of the submission, which is all placement needs
        let key = job_key(&spec.net, env_fingerprint(&spec.net, 0, &spec.cfg.env));
        let order = self.placement(key);
        let home = order.first().copied();

        let mut steal_left = self.steal_budget;
        let mut saw_429 = false;
        let mut last_refusal: Option<Response> = None;
        for &wi in &order {
            let w = &self.workers[wi];
            if !w.routable() {
                continue; // health-aware skip — no request wasted
            }
            match w.call("POST", "/v1/jobs", Some(&forwarded)) {
                Ok((429, b)) => {
                    last_refusal = Some(Response::status(429, b));
                    if steal_left == 0 {
                        break; // stealing budget exhausted — shed
                    }
                    steal_left -= 1;
                    saw_429 = true;
                }
                Ok((503, b)) => {
                    // draining/degraded: fall through to the next worker
                    last_refusal = Some(Response::status(503, b));
                }
                Ok((status, b)) if status == 200 || status == 202 => {
                    return self.placed(status, b, wi, home, saw_429, &forwarded, key);
                }
                Ok((status, b)) => {
                    // 400 and friends are the CLIENT's problem — every
                    // worker would answer the same; forward as-is
                    return Response::status(status, b);
                }
                Err(_) => {
                    // transport failure; `call` already marked it Down
                    last_refusal = Some(Response::error(503, "worker unreachable"));
                }
            }
        }
        self.counters.shed.fetch_add(1, Ordering::Relaxed);
        last_refusal
            .unwrap_or_else(|| Response::error(503, "no healthy workers in the fleet"))
    }

    /// Book-keep a successful placement and rewrite the response: the
    /// worker-local id becomes a fleet id, and the response is annotated
    /// with the worker name (which the access log picks up). The
    /// submission body is retained against the fleet id so the job can be
    /// re-dispatched if this worker dies with it in flight (a 200 is an
    /// archive hit — already terminal, nothing to fail over).
    fn placed(
        &self, status: u16, body: Json, wi: usize, home: Option<usize>, stolen: bool,
        forwarded: &Json, key: u64,
    ) -> Response {
        let w = &self.workers[wi];
        w.routed.fetch_add(1, Ordering::Relaxed);
        self.counters.routed.fetch_add(1, Ordering::Relaxed);
        if Some(wi) == home {
            self.counters.routed_home.fetch_add(1, Ordering::Relaxed);
        } else if stolen {
            self.counters.stolen.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.rerouted.fetch_add(1, Ordering::Relaxed);
        }
        let remote_id = body.get("id").and_then(Json::as_f64).map(|f| f as u64);
        let fleet_id = match remote_id {
            Some(rid) => {
                let fid = self.next_id.fetch_add(1, Ordering::Relaxed);
                let mut jobs = lock_recover(&self.jobs);
                jobs.insert(
                    fid,
                    Tracked { wi, rid, body: forwarded.clone(), key, done: status == 200 },
                );
                while jobs.len() > JOB_TABLE_CAP {
                    let oldest = *jobs.keys().next().unwrap();
                    jobs.remove(&oldest);
                }
                Some(fid)
            }
            None => None,
        };
        Response::status(status, annotate(body, fleet_id, &w.name))
    }

    /// Forward a per-job request (`GET status`, `GET result`,
    /// `POST cancel`) to the worker that owns the job. Observed terminal
    /// statuses are recorded so a later worker death doesn't re-dispatch
    /// a job that already finished.
    pub fn forward_job(&self, fleet_id: &str, method: &str, suffix: &str) -> Response {
        let Ok(fid) = fleet_id.parse::<u64>() else {
            return Response::error(400, "job id must be a number");
        };
        let Some((wi, rid)) =
            lock_recover(&self.jobs).get(&fid).map(|t| (t.wi, t.rid))
        else {
            return Response::error(404, "no such job (finished jobs are retained briefly)");
        };
        let w = &self.workers[wi];
        let path = format!("/v1/jobs/{rid}{suffix}");
        match w.call(method, &path, None) {
            Ok((status, body)) => {
                if let Some(s) = body.get("status").and_then(Json::as_str) {
                    if matches!(s, "done" | "failed" | "cancelled") {
                        if let Some(t) = lock_recover(&self.jobs).get_mut(&fid) {
                            t.done = true;
                        }
                    }
                }
                Response::status(status, annotate(body, Some(fid), &w.name))
            }
            Err(e) => Response::error(503, &format!("worker {} unreachable: {e:#}", w.name)),
        }
    }

    /// Re-dispatch every in-flight job stranded on a dead worker. Called
    /// by the fleet health monitor on an Up→Down transition. Each job's
    /// retained submission replays through normal placement with the dead
    /// worker excluded — the idempotency key makes a replay landing on a
    /// worker that already saw it a dedupe, and checkpoint replication
    /// means the successor resumes from the job's last checkpoint instead
    /// of restarting. Returns the number of jobs successfully re-homed.
    pub fn failover(&self, dead: usize) -> usize {
        let stranded: Vec<(u64, Tracked)> = {
            let jobs = lock_recover(&self.jobs);
            jobs.iter()
                .filter(|(_, t)| t.wi == dead && !t.done)
                .map(|(k, t)| (*k, t.clone()))
                .collect()
        };
        let mut moved = 0usize;
        for (fid, t) in stranded {
            let mut placed = false;
            for wi in self.placement(t.key) {
                if wi == dead || !self.workers[wi].routable() {
                    continue;
                }
                let w = &self.workers[wi];
                match w.call("POST", "/v1/jobs", Some(&t.body)) {
                    Ok((status, b)) if status == 200 || status == 202 => {
                        let rid = b.get("id").and_then(Json::as_f64).map(|f| f as u64);
                        let Some(rid) = rid else { break };
                        {
                            let mut jobs = lock_recover(&self.jobs);
                            if let Some(entry) = jobs.get_mut(&fid) {
                                entry.wi = wi;
                                entry.rid = rid;
                                entry.done = status == 200;
                            }
                        }
                        w.routed.fetch_add(1, Ordering::Relaxed);
                        self.counters.failed_over.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[fleet] job {fid} failed over to {} (worker-local id {rid})",
                            w.name
                        );
                        moved += 1;
                        placed = true;
                        break;
                    }
                    // a refusal (429/503) falls through to the next
                    // candidate; transport errors mark the worker Down
                    Ok(_) | Err(_) => {}
                }
            }
            if !placed {
                self.counters.failover_shed.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "[fleet] job {fid} stranded on dead worker {}: no live worker \
                     accepted it (will retry on the next Down transition)",
                    self.workers[dead].name
                );
            }
        }
        moved
    }

    /// `GET /v1/jobs`: page over the fleet job table (id order), fetching
    /// each job's live summary from its worker. O(limit) pooled-transport
    /// round trips, bounded by the shared `LIST_LIMIT_MAX` clamp.
    pub fn list_jobs(&self, cursor: Option<u64>, limit: usize) -> Response {
        let page: Vec<(u64, (usize, u64))> = {
            let jobs = lock_recover(&self.jobs);
            let start = match cursor {
                Some(c) => std::ops::Bound::Excluded(c),
                None => std::ops::Bound::Unbounded,
            };
            jobs.range((start, std::ops::Bound::Unbounded))
                .take(limit + 1)
                .map(|(k, t)| (*k, (t.wi, t.rid)))
                .collect()
        };
        let next = if page.len() > limit { page.get(limit - 1).map(|(k, _)| *k) } else { None };
        let mut out = Vec::new();
        for &(fid, (wi, rid)) in page.iter().take(limit) {
            let w = &self.workers[wi];
            let row = match w.call("GET", &format!("/v1/jobs/{rid}"), None) {
                Ok((200, body)) => {
                    // summary shape, not the full status: drop the tail
                    let mut m = match annotate(body, Some(fid), &w.name) {
                        Json::Obj(m) => m,
                        other => return Response::error(500, &format!("bad worker body {other:?}")),
                    };
                    m.remove("tail");
                    Json::Obj(m)
                }
                Ok((_, _)) | Err(_) => Json::obj(vec![
                    ("id", Json::Num(fid as f64)),
                    ("worker", Json::Str(w.name.clone())),
                    ("status", Json::Str("unknown".to_string())),
                ]),
            };
            out.push(row);
        }
        Response::ok(Json::obj(vec![
            ("jobs", Json::Arr(out)),
            ("next_cursor", next.map(|n| Json::Str(n.to_string())).unwrap_or(Json::Null)),
        ]))
    }

    /// Aggregate `/v1/stats` across the fleet: router counters + each
    /// worker's own stats body (best-effort; a down worker contributes an
    /// error row instead of stalling the response).
    pub fn stats(&self, extra: Vec<(&'static str, Json)>) -> Json {
        let mut per_worker = BTreeMap::new();
        for w in &self.workers {
            let row = if w.is_up() {
                match w.call_timeout("GET", "/v1/stats", None, PROBE_TIMEOUT) {
                    Ok((200, body)) => body,
                    Ok((status, _)) => Json::obj(vec![(
                        "error",
                        Json::Str(format!("stats answered {status}")),
                    )]),
                    Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
                }
            } else {
                Json::obj(vec![("error", Json::Str("down".to_string()))])
            };
            let mut m = match row {
                Json::Obj(m) => m,
                other => BTreeMap::from([("raw".to_string(), other)]),
            };
            m.insert("addr".to_string(), Json::Str(w.addr.clone()));
            m.insert("health".to_string(), Json::Str(format!("{:?}", w.health())));
            m.insert("routed".to_string(), Json::Num(w.routed.load(Ordering::Relaxed) as f64));
            per_worker.insert(w.name.clone(), Json::Obj(m));
        }
        let mut fields = vec![
            ("router", self.counters.json()),
            ("workers", Json::Obj(per_worker)),
        ];
        fields.extend(extra);
        Json::obj(fields)
    }

    /// Fleet health: 200 while at least one worker is routable.
    pub fn health(&self) -> Response {
        let mut rows = BTreeMap::new();
        let mut routable = 0usize;
        for w in &self.workers {
            if w.routable() {
                routable += 1;
            }
            rows.insert(
                w.name.clone(),
                Json::obj(vec![
                    ("addr", Json::Str(w.addr.clone())),
                    ("health", Json::Str(format!("{:?}", w.health()))),
                    ("load", Json::Num(w.load() as f64)),
                ]),
            );
        }
        let body = Json::obj(vec![
            (
                "status",
                Json::Str(if routable > 0 { "ok" } else { "degraded" }.to_string()),
            ),
            ("routable_workers", Json::Num(routable as f64)),
            ("workers", Json::Obj(rows)),
        ]);
        if routable > 0 {
            Response::ok(body)
        } else {
            Response::status(503, body)
        }
    }

    /// Broadcast a request to every reachable worker (network installs).
    /// 200 only when every reachable worker accepted; per-worker outcomes
    /// in the body either way.
    pub fn broadcast(&self, method: &str, path: &str, body: &Json) -> Response {
        let mut rows = BTreeMap::new();
        let mut failures = 0usize;
        for w in &self.workers {
            let outcome = if !w.is_up() {
                failures += 1;
                Json::obj(vec![("error", Json::Str("down".to_string()))])
            } else {
                match w.call(method, path, Some(body)) {
                    Ok((status, b)) => {
                        if status >= 300 {
                            failures += 1;
                        }
                        Json::obj(vec![("status", Json::Num(status as f64)), ("body", b)])
                    }
                    Err(e) => {
                        failures += 1;
                        Json::obj(vec![("error", Json::Str(format!("{e:#}")))])
                    }
                }
            };
            rows.insert(w.name.clone(), outcome);
        }
        let body = Json::obj(vec![
            ("ok", Json::Bool(failures == 0)),
            ("workers", Json::Obj(rows)),
        ]);
        if failures == 0 {
            Response::ok(body)
        } else {
            Response::status(502, body)
        }
    }
}

/// Rewrite a worker response for the fleet surface: the worker-local `id`
/// (when present) becomes the fleet id, and the routed worker's name is
/// recorded under `worker`. Everything else passes through untouched —
/// the bit-identical guarantee covers every other field.
fn annotate(body: Json, fleet_id: Option<u64>, worker: &str) -> Json {
    let mut m = match body {
        Json::Obj(m) => m,
        other => return other, // non-object bodies pass through verbatim
    };
    if let Some(fid) = fleet_id {
        if m.contains_key("id") {
            m.insert("id".to_string(), Json::Num(fid as f64));
        }
    }
    m.insert("worker".to_string(), Json::Str(worker.to_string()));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn annotate_rewrites_id_and_tags_worker() {
        let body = Json::obj(vec![
            ("id", Json::Num(3.0)),
            ("status", Json::Str("queued".to_string())),
        ]);
        let out = annotate(body, Some(41), "w1");
        assert_eq!(out.u("id"), 41);
        assert_eq!(out.s("worker"), "w1");
        assert_eq!(out.s("status"), "queued");
        // bodies without an id (errors) only get the worker tag
        let out = annotate(Json::obj(vec![("error", Json::Str("x".into()))]), Some(9), "w0");
        assert!(out.get("id").is_none());
        assert_eq!(out.s("worker"), "w0");
    }

    #[test]
    fn worker_health_transitions() {
        let w = Worker::new("w0", "127.0.0.1:1"); // nothing listens on port 1
        assert!(w.routable(), "workers start optimistic");
        assert_eq!(w.probe(), Health::Down);
        assert!(!w.is_up());
        assert!(!w.routable());
    }

    #[test]
    fn counters_serialize() {
        let c = Counters::default();
        c.routed.store(3, Ordering::Relaxed);
        c.stolen.store(1, Ordering::Relaxed);
        let j = c.json();
        assert_eq!(j.u("routed"), 3);
        assert_eq!(j.u("stolen"), 1);
        assert_eq!(j.u("shed"), 0);
        assert_eq!(j.u("failed_over"), 0);
        assert_eq!(j.u("failover_shed"), 0);
    }

    #[test]
    fn generated_idem_keys_are_unique_and_valid() {
        let r = Router::new(vec![Arc::new(Worker::new("w0", "127.0.0.1:1"))], 0);
        let a = r.generate_idem_key();
        let b = r.generate_idem_key();
        assert_ne!(a, b);
        config::validate_idempotency_key(&a).unwrap();
        config::validate_idempotency_key(&b).unwrap();
    }

    #[test]
    fn failover_with_no_live_successor_sheds_and_retains_the_job() {
        // two workers, neither listening: the stranded job can't be
        // re-homed, the shed counter ticks, and the entry stays tracked
        // (a later transition retries it)
        let workers = vec![
            Arc::new(Worker::new("w0", "127.0.0.1:1")),
            Arc::new(Worker::new("w1", "127.0.0.1:1")),
        ];
        let r = Router::new(workers, 1);
        lock_recover(&r.jobs).insert(
            7,
            Tracked {
                wi: 0,
                rid: 3,
                body: Json::obj(vec![("net", Json::Str("lenet_init".into()))]),
                key: 42,
                done: false,
            },
        );
        // a done job on the same dead worker must never be re-dispatched
        lock_recover(&r.jobs).insert(
            8,
            Tracked { wi: 0, rid: 4, body: Json::Null, key: 42, done: true },
        );
        assert_eq!(r.failover(0), 0);
        assert_eq!(r.counters.failover_shed.load(Ordering::Relaxed), 1);
        assert_eq!(r.counters.failed_over.load(Ordering::Relaxed), 0);
        let jobs = lock_recover(&r.jobs);
        assert_eq!(jobs.get(&7).map(|t| t.wi), Some(0), "entry retained");
    }
}
