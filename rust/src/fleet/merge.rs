//! Archive replication: periodic pull-merge between the router and its
//! workers.
//!
//! Every worker accumulates solution records locally (its own archive
//! file); the router holds a fleet-wide merged archive. A merge round is
//! two passes over the reachable workers:
//!
//! 1. **Pull** — page each worker's `GET /v1/archive` (cursor walk,
//!    [`PAGE_LIMIT`] records a page so a response stays far below the
//!    client body cap) and fold every record into the merged archive via
//!    `Archive::merge_record` (content re-keyed, union by fingerprint,
//!    max hit count wins).
//! 2. **Push** — page the merged archive back out to each worker via
//!    `POST /v1/archive/merge` in the same bounded chunks.
//!
//! Because the merge operator is idempotent and commutative, rounds
//! converge: after one full round every reachable worker holds the union,
//! so an exact resubmission is a zero-eval archive hit at ANY entry point
//! — the router, or any worker directly. A worker that is down during a
//! round simply catches up on the next one.

use std::time::Duration;

use crate::serve::{Archive, MergeStats};
use crate::util::json::Json;

use super::router::Worker;

/// Records per pull page / push chunk. A record dumps to well under 1 KiB,
/// so 16 keeps each body a few KiB — far below `http::MAX_BODY`.
pub const PAGE_LIMIT: usize = 16;

/// Per-exchange budget during a merge round; a hung worker costs seconds,
/// not the client default of minutes.
const MERGE_TIMEOUT: Duration = Duration::from_secs(10);

/// Outcome counters for one merge round (surfaced in `/v1/stats`).
#[derive(Debug, Default, Clone)]
pub struct RoundStats {
    /// workers successfully pulled from / pushed to
    pub pulled: usize,
    pub pushed: usize,
    /// workers skipped (down) or that failed mid-transfer
    pub failed: usize,
    /// records newly added or hit-raised in the ROUTER's merged archive
    pub absorbed: usize,
    /// checkpoint installs performed during this round's replication pass
    /// (durable fleets only; always 0 otherwise)
    pub checkpoints_replicated: usize,
}

impl RoundStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pulled", Json::Num(self.pulled as f64)),
            ("pushed", Json::Num(self.pushed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("absorbed", Json::Num(self.absorbed as f64)),
            (
                "checkpoints_replicated",
                Json::Num(self.checkpoints_replicated as f64),
            ),
        ])
    }
}

/// Pull one worker's full archive into `merged`, one page at a time.
/// Returns the number of records added or raised locally.
fn pull_worker(w: &Worker, merged: &Archive) -> anyhow::Result<usize> {
    let mut absorbed = 0usize;
    let mut cursor: Option<String> = None;
    loop {
        let path = match &cursor {
            Some(c) => format!("/v1/archive?limit={PAGE_LIMIT}&cursor={c}"),
            None => format!("/v1/archive?limit={PAGE_LIMIT}"),
        };
        let (status, body) = w.call_timeout("GET", &path, None, MERGE_TIMEOUT)?;
        anyhow::ensure!(status == 200, "{}: GET /v1/archive -> {status}", w.name);
        let stats: MergeStats = merged.merge_json(&body)?;
        absorbed += stats.added + stats.raised;
        match body.get("next_cursor").and_then(Json::as_str) {
            Some(c) => cursor = Some(c.to_string()),
            None => return Ok(absorbed),
        }
    }
}

/// Push the merged archive to one worker, chunk by chunk. The worker's
/// merge endpoint applies the same max-hits union, so re-sending records
/// it already holds is a no-op, not double counting.
fn push_worker(w: &Worker, merged: &Archive) -> anyhow::Result<()> {
    let mut cursor: Option<String> = None;
    loop {
        let (page, next) = merged.page(cursor.as_deref(), PAGE_LIMIT);
        if page.is_empty() {
            return Ok(());
        }
        let records = Json::Obj(page.into_iter().collect());
        let body = Json::obj(vec![("records", records)]);
        let (status, _) =
            w.call_timeout("POST", "/v1/archive/merge", Some(&body), MERGE_TIMEOUT)?;
        anyhow::ensure!(status == 200, "{}: POST /v1/archive/merge -> {status}", w.name);
        match next {
            Some(c) => cursor = Some(c),
            None => return Ok(()),
        }
    }
}

/// One checkpoint replication round (durable fleets). For every
/// checkpoint file any worker holds, the copy with the most episodes done
/// is fetched from its holder and offered to every other reachable worker
/// — the receiving daemon's `POST /v1/checkpoints/{file}` verifies the
/// checksum and installs only when the offered copy is AHEAD of its own,
/// so replication is monotone and corruption-proof by construction. A
/// ring successor that inherits a failed-over job thus resumes it from
/// the dead worker's last replicated checkpoint instead of restarting.
/// Transfers are bounded per round ([`CKPT_TRANSFER_CAP`]); a busy fleet
/// converges over successive rounds. Returns the number of installs.
pub fn checkpoint_round(workers: &[std::sync::Arc<Worker>]) -> usize {
    // per-worker listing: file -> episodes_done (workers without
    // --checkpoint-dir answer 503 and simply don't participate)
    let mut have: Vec<std::collections::BTreeMap<String, f64>> =
        vec![Default::default(); workers.len()];
    let mut reachable: Vec<bool> = vec![false; workers.len()];
    for (i, w) in workers.iter().enumerate() {
        if !w.is_up() {
            continue;
        }
        let Ok((200, body)) = w.call_timeout("GET", "/v1/checkpoints", None, MERGE_TIMEOUT)
        else {
            continue;
        };
        reachable[i] = true;
        let Some(rows) = body.get("checkpoints").and_then(Json::as_arr) else { continue };
        for row in rows {
            let (Some(file), Some(eps)) = (
                row.get("file").and_then(Json::as_str),
                row.get("episodes_done").and_then(Json::as_f64),
            ) else {
                continue;
            };
            have[i].insert(file.to_string(), eps);
        }
    }
    // best holder per file
    let mut best: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
    for (i, files) in have.iter().enumerate() {
        for (file, &eps) in files {
            match best.get(file) {
                Some(&(b, _)) if b >= eps => {}
                _ => {
                    best.insert(file.clone(), (eps, i));
                }
            }
        }
    }
    let mut installed = 0usize;
    let mut transfers = 0usize;
    for (file, (eps, holder)) in best {
        if transfers >= CKPT_TRANSFER_CAP {
            break;
        }
        // anyone behind? (missing the file, or holding fewer episodes)
        let behind: Vec<usize> = (0..workers.len())
            .filter(|&j| {
                j != holder
                    && reachable[j]
                    && have[j].get(&file).copied().unwrap_or(-1.0) < eps
            })
            .collect();
        if behind.is_empty() {
            continue;
        }
        let path = format!("/v1/checkpoints/{file}");
        let doc = match workers[holder].call_timeout("GET", &path, None, MERGE_TIMEOUT) {
            Ok((200, doc)) => doc,
            Ok(_) | Err(_) => continue, // deleted between list and fetch, or flaky
        };
        transfers += 1;
        for j in behind {
            match workers[j].call_timeout("POST", &path, Some(&doc), MERGE_TIMEOUT) {
                Ok((200, resp)) => {
                    if matches!(resp.get("installed"), Some(Json::Bool(true))) {
                        installed += 1;
                    }
                }
                Ok(_) | Err(_) => {}
            }
        }
    }
    installed
}

/// Checkpoint documents fetched per replication round — bounds a round's
/// transfer volume the way [`PAGE_LIMIT`] bounds archive pages.
pub const CKPT_TRANSFER_CAP: usize = 32;

/// One full pull-then-push round over the given workers. Workers marked
/// down are skipped outright (they catch up next round); a worker that
/// fails mid-transfer is counted and the round continues — replication is
/// best-effort per round, convergent across rounds.
pub fn merge_round(workers: &[std::sync::Arc<Worker>], merged: &Archive) -> RoundStats {
    let mut st = RoundStats::default();
    for w in workers {
        if !w.is_up() {
            st.failed += 1;
            continue;
        }
        match pull_worker(w, merged) {
            Ok(n) => {
                st.pulled += 1;
                st.absorbed += n;
            }
            Err(e) => {
                st.failed += 1;
                eprintln!("fleet: pull from {} failed: {e:#}", w.name);
                continue; // don't push stale state over a flaky link
            }
        }
    }
    for w in workers {
        if !w.is_up() {
            continue;
        }
        match push_worker(w, merged) {
            Ok(()) => st.pushed += 1,
            Err(e) => {
                st.failed += 1;
                eprintln!("fleet: push to {} failed: {e:#}", w.name);
            }
        }
    }
    st
}
