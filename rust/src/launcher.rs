//! CLI subcommand implementations (the "launcher" in the system prompt's
//! sense: config resolution -> engine bring-up -> run -> report).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::baselines::{paper_solution, AdmmConfig, AdmmSelector};
use crate::config;
use crate::coordinator::{
    best_replica, run_replicas, Durable, EnvConfig, QuantEnv, SearchCheckpoint, SearchCtl,
    SearchResult, Searcher,
};
use crate::metrics::sparkline;
use crate::parallel;
use crate::pareto;
use crate::runtime::{Engine, Manifest};
use crate::sim::{Stripes, StripesConfig, TvmCpu, TvmCpuConfig};
use crate::util::cli::Args;

/// Shared bring-up: manifest + engine.
pub fn bringup() -> Result<(Manifest, Arc<Engine>)> {
    let dir = crate::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::new(dir)?);
    Ok((manifest, engine))
}

fn out_dir(args: &Args) -> Result<PathBuf> {
    let dir = PathBuf::from(args.str_of("out", "results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}


pub fn cmd_stats(_args: &Args) -> Result<()> {
    let (manifest, _engine) = bringup()?;
    println!("artifacts: {}", manifest.dir.display());
    println!(
        "agent: D={} A={} hidden={} P_lstm={} P_fc={}",
        manifest.agent.state_dim,
        manifest.agent.n_actions,
        manifest.agent.hidden,
        manifest.agent.p_lstm,
        manifest.agent.p_fc
    );
    println!("{:<10} {:>3} {:>8} {:>12} {:>12} dataset", "network", "L", "P", "weights", "MACs");
    for net in &manifest.networks {
        println!(
            "{:<10} {:>3} {:>8} {:>12} {:>12} {}",
            net.name,
            net.l,
            net.p,
            net.total_weights(),
            net.total_macs(),
            net.dataset
        );
    }
    Ok(())
}

pub fn cmd_pretrain(args: &Args) -> Result<()> {
    let net_name = args.str_of("net", "lenet");
    let (manifest, engine) = bringup()?;
    let net = manifest.network(&net_name)?;
    let mut env_cfg = EnvConfig::default();
    env_cfg.pretrain_steps = args.usize_of("steps", config::preset(&net_name).env.pretrain_steps);
    env_cfg.lr = args.f64_of("lr", env_cfg.lr as f64) as f32;
    env_cfg.seed = args.u64_of("seed", env_cfg.seed);
    let t0 = std::time::Instant::now();
    let env = QuantEnv::new(engine, net, manifest.bits_max, manifest.fp_bits, env_cfg)?;
    println!(
        "{net_name}: pretrained {} steps in {:.1}s, full-precision val accuracy {:.4}",
        env.cfg.pretrain_steps,
        t0.elapsed().as_secs_f64(),
        env.acc_fullp
    );
    // quantization-sensitivity sweep: uniform k for k in 8..=2
    println!("uniform-bitwidth sensitivity (short retrain {} steps):", env.cfg.retrain_steps);
    for b in (2..=8).rev() {
        let bits = vec![b; net.l];
        let acc = env.accuracy(&bits)?;
        println!("  {b} bits: acc {:.4} (state_acc {:.3}, state_q {:.3})",
                 acc, acc / env.acc_fullp, env.state_q(&bits));
    }
    Ok(())
}

pub fn report_search(r: &SearchResult, verbose: bool) {
    println!("network             : {}", r.net);
    println!("episodes run        : {}", r.episodes_run);
    if verbose {
        println!("reward curve        : {}", sparkline(&r.log.rewards(), 60));
        println!("state-of-acc curve  : {}", sparkline(&r.log.state_accs(), 60));
        println!("state-of-quant curve: {}", sparkline(&r.log.state_qs(), 60));
    }
    println!("bitwidths           : {:?}", r.bits);
    println!("average bitwidth    : {:.2}", r.avg_bits);
    println!("state_q             : {:.3}", r.state_q);
    println!(
        "accuracy            : fp {:.4} -> quantized {:.4} (loss {:.2}%)",
        r.acc_fullp, r.acc_final, r.acc_loss_pct
    );
}

pub fn cmd_search(args: &Args) -> Result<()> {
    let net_name = args.str_of("net", "lenet");
    let (manifest, engine) = bringup()?;
    let net = manifest.network(&net_name)?;
    let cfg = config::resolve(&net_name, args)?;
    // grow the engine's device pool before any residency is built so every
    // placement decision below sees the full pool (grow-only; devices=1 is
    // the pre-pool single-engine path, byte-for-byte)
    engine.ensure_devices(cfg.devices)?;
    if engine.n_devices() > 1 {
        println!("device pool: {} devices", engine.n_devices());
    }
    let replicas = args.usize_of("replicas", 1);
    let t0 = std::time::Instant::now();

    // multi-seed replica mode: fan independent searches across shard threads
    // (seeds base, base+1, ...) and report the best solution found
    if replicas > 1 {
        let seeds: Vec<u64> = (0..replicas as u64).map(|i| cfg.seed + i).collect();
        println!("{net_name}: running {replicas} search replicas, seeds {seeds:?}...");
        let results = run_replicas(&engine, &manifest, net, &cfg, &seeds)?;
        for (r, seed) in results.iter().zip(&seeds) {
            println!(
                "seed {seed}: bits {:?} (avg {:.2}), acc {:.4} (loss {:.2}%), state_q {:.3}",
                r.bits, r.avg_bits, r.acc_final, r.acc_loss_pct, r.state_q
            );
        }
        let best = best_replica(&results).expect("replicas > 1");
        println!("-- best replica: seed {} --", seeds[best]);
        report_search(&results[best], true);
        println!("wall time           : {:.1}s", t0.elapsed().as_secs_f64());
        let dir = out_dir(args)?;
        results[best]
            .log
            .write_csv(&dir.join(format!("search_{net_name}.csv")))?;
        results[best]
            .log
            .write_json(&dir.join(format!("search_{net_name}.json")))?;
        println!("logs (best replica): {}/search_{net_name}.{{csv,json}}", dir.display());
        return Ok(());
    }

    // --checkpoint <path>: durable search. Checkpoints are written at PPO
    // update boundaries; an interrupted run re-invoked with the same flags
    // resumes bit-identically from the last checkpoint.
    let checkpoint = args.opt_str("checkpoint").map(PathBuf::from);
    let checkpoint_every = args.usize_of("checkpoint-every", 8);
    let search_fp = crate::serve::search_fingerprint(&net_name, manifest.bits_max, &cfg);

    let mut searcher = Searcher::new(engine.clone(), &manifest, net, cfg)?;
    println!("{net_name}: pretrained, Acc_FullP = {:.4}; searching...", searcher.env.acc_fullp);
    let mut durable = match checkpoint {
        Some(path) => {
            let mut d = Durable::new(path, checkpoint_every, &net_name, search_fp)?;
            match SearchCheckpoint::load(&d.path) {
                Ok(Some(ck)) => match searcher.restore(ck, &mut d) {
                    Ok(()) => println!(
                        "resuming from checkpoint {} at episode {}",
                        d.path.display(),
                        d.resumed_from.unwrap_or(0)
                    ),
                    Err(e) => println!("checkpoint rejected ({e:#}); starting fresh"),
                },
                Ok(None) => println!(
                    "checkpointing to {} every {} episode(s)",
                    d.path.display(),
                    d.every
                ),
                Err(e) => println!("checkpoint unreadable ({e:#}); starting fresh"),
            }
            Some(d)
        }
        None => None,
    };
    let result = searcher.run_durable(&SearchCtl::default(), durable.as_mut());
    if let Some(d) = &durable {
        if result.is_err() && d.saves > 0 {
            println!(
                "interrupted: checkpoint retained at {} (re-run the same command to resume)",
                d.path.display()
            );
        }
    }
    let result = result?;
    if let Some(d) = &mut durable {
        d.complete();
    }
    report_search(&result, true);
    println!("wall time           : {:.1}s", t0.elapsed().as_secs_f64());
    let stats = searcher.env.stats();
    println!(
        "env: {} evals, {} cache hits, {} train execs, {} eval execs \
         ({} batched execs scoring {} candidates, {} pad lanes); \
         agent: {} acts, {} batched acts, {} param uploads",
        stats.evals,
        stats.cache_hits,
        stats.train_execs,
        stats.eval_execs,
        stats.eval_batch_execs,
        stats.batched_candidates,
        stats.pad_lanes,
        searcher.agent.act_calls,
        searcher.agent.act_batch_calls,
        searcher.agent.param_uploads
    );
    if searcher.cfg.pipeline > 0 {
        println!(
            "pipeline (depth {}): {} speculated, {} hits, {} wasted",
            searcher.cfg.pipeline, stats.spec_submitted, stats.spec_hits, stats.spec_wasted
        );
    }
    // per-(artifact, device) timing, device-exec vs result-download split
    // (the attribution the pipelined driver's wins are measured against);
    // on a 1-device pool every row is device 0
    println!(
        "{:<28} {:>6} {:>8} {:>12} {:>12}",
        "artifact", "device", "execs", "exec ms", "download ms"
    );
    for s in engine.exec_stats() {
        println!(
            "{:<28} {:>6} {:>8} {:>12.3} {:>12.3}",
            s.name, s.device, s.execs, s.mean_exec_ms, s.mean_download_ms
        );
    }
    let dir = out_dir(args)?;
    result.log.write_csv(&dir.join(format!("search_{net_name}.csv")))?;
    result.log.write_json(&dir.join(format!("search_{net_name}.json")))?;
    println!("logs: {}/search_{net_name}.{{csv,json}}", dir.display());
    Ok(())
}

pub fn cmd_pareto(args: &Args) -> Result<()> {
    let net_name = args.str_of("net", "lenet");
    let (manifest, engine) = bringup()?;
    let net = manifest.network(&net_name)?;
    let mut env_cfg = EnvConfig::default();
    env_cfg.pretrain_steps = config::preset(&net_name).env.pretrain_steps;
    let mut ecfg = pareto::EnumConfig::default();
    ecfg.max_points = args.usize_of("samples", ecfg.max_points);
    ecfg.seed = args.u64_of("seed", ecfg.seed);
    let shards = args.usize_of("shards", parallel::default_shards(ecfg.max_points));
    let space = pareto::space_size(&ecfg, net.l);
    println!(
        "{net_name}: design space {space} points; evaluating up to {} on {shards} shard(s)",
        ecfg.max_points
    );
    let t0 = std::time::Instant::now();
    // one shared-core env: all shards query the same pretrained snapshot
    let env = QuantEnv::new(engine, net, manifest.bits_max, manifest.fp_bits, env_cfg)?;
    println!(
        "pretrained once ({} train execs) in {:.1}s; enumerating...",
        env.stats().train_execs,
        t0.elapsed().as_secs_f64()
    );
    let (points, exhaustive) = pareto::enumerate_sharded(&env, &ecfg, shards)?;
    let frontier = pareto::pareto_frontier(&points);
    println!(
        "evaluated {} points ({}) in {:.1}s; frontier has {} points:",
        points.len(),
        if exhaustive { "exhaustive" } else { "sampled" },
        t0.elapsed().as_secs_f64(),
        frontier.len()
    );
    println!("{:>8} {:>9} bits", "state_q", "state_acc");
    for &i in &frontier {
        println!("{:>8.3} {:>9.3} {:?}", points[i].state_q, points[i].state_acc, points[i].bits);
    }
    let dir = out_dir(args)?;
    let path = dir.join(format!("pareto_{net_name}.csv"));
    let mut csv = String::from("state_q,state_acc,on_frontier,bits\n");
    for (i, p) in points.iter().enumerate() {
        let on = frontier.contains(&i);
        csv.push_str(&format!(
            "{:.6},{:.6},{},{}\n",
            p.state_q,
            p.state_acc,
            on as u8,
            p.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(" ")
        ));
    }
    std::fs::write(&path, csv)?;
    println!("points: {}", path.display());
    Ok(())
}

pub fn cmd_hw_eval(args: &Args) -> Result<()> {
    let net_name = args.str_of("net", "lenet");
    let (manifest, _engine) = bringup()?;
    let net = manifest.network(&net_name)?;
    let bits = match args.opt_str("bits") {
        // the shared validated parser (config layer) — same gate as the
        // TOML and serve job-JSON bits paths
        Some(s) => config::parse_bits(&s).context("--bits")?,
        None => crate::baselines::paper_releq_solution(&net_name)
            .with_context(|| format!("no --bits and no stored solution for {net_name}"))?,
    };
    anyhow::ensure!(bits.len() == net.l, "need {} bitwidths, got {}", net.l, bits.len());
    let stripes = Stripes::new(StripesConfig::default());
    let (sp, en) = stripes.speedup_energy(net, &bits);
    let tvm = TvmCpu::new(TvmCpuConfig::default());
    let cpu_sp = tvm.speedup(net, &bits);
    println!("{net_name} bits {:?}", bits);
    println!("Stripes  : {sp:.2}x speedup, {en:.2}x energy reduction (vs 8-bit)");
    println!("CPU (bit-serial): {cpu_sp:.2}x speedup (vs 8-bit)");
    Ok(())
}

/// `releq serve`: the quantization-as-a-service daemon. Blocks until a
/// `POST /v1/shutdown` completes its drain.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = config::serve_config(args)?;
    let (manifest, engine) = bringup()?;
    let workers = cfg.workers;
    let archive = cfg.archive.clone();
    let registry_dir = cfg.registry_dir.clone();
    let wal = cfg.wal.clone();
    let ckpt_dir = cfg.checkpoint_dir.clone();
    let ckpt_every = cfg.checkpoint_every;
    let server = crate::serve::Server::bind(cfg, manifest, engine)?;
    println!("releq serve: listening on http://{}", server.local_addr());
    println!("  workers: {workers}, archive: {}", archive.display());
    match &registry_dir {
        Some(d) => println!("  registry: {} (POST /v1/networks accepts installs)", d.display()),
        None => println!("  registry: disabled (start with --registry-dir to enable POST /v1/networks)"),
    }
    match (&wal, &ckpt_dir) {
        (None, None) => println!("  durability: off (--wal journals jobs, --checkpoint-dir checkpoints searches)"),
        (w, c) => {
            let wal_s = w.as_ref().map(|p| p.display().to_string()).unwrap_or_else(|| "off".into());
            let ck_s = c.as_ref()
                .map(|p| format!("{} (every {ckpt_every} episodes)", p.display()))
                .unwrap_or_else(|| "off".into());
            println!("  durability: wal {wal_s}, checkpoints {ck_s}");
        }
    }
    println!("  POST /v1/jobs | GET /v1/jobs/<id>[/result] | POST /v1/jobs/<id>/cancel");
    println!("  POST /v1/networks | GET /v1/stats | GET /v1/health | POST /v1/shutdown (drains + persists)");
    server.run()?;
    println!("releq serve: drained and stopped");
    Ok(())
}

/// `releq fleet`: front-end router over N `releq serve` workers —
/// consistent-hash placement, health-aware fallback, work stealing, and
/// archive pull-merge replication. The router holds no engine or
/// artifacts; spawned workers do their own bring-up. Blocks until a
/// `POST /v1/shutdown` has merged archives and drained every worker.
pub fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = config::fleet_config(args)?;
    let spawn = cfg.spawn_workers;
    let joins = cfg.worker_addrs.len();
    let archive = cfg.archive.clone();
    let merge_ms = cfg.merge_interval_ms;
    let steal = cfg.steal_budget;
    let durable = cfg.durable;
    let server = crate::fleet::FleetServer::bind(cfg)?;
    println!("releq fleet: listening on http://{}", server.local_addr());
    println!(
        "  workers: {spawn} spawned + {joins} joined, steal budget {steal}, merged archive: {}",
        archive.display()
    );
    if durable {
        println!(
            "  durable: per-worker WALs + checkpoint dirs; checkpoints replicate each \
             merge round; in-flight jobs fail over on worker death"
        );
    }
    match merge_ms {
        0 => println!("  archive merge: on demand (POST /v1/fleet/merge) and at shutdown"),
        ms => println!("  archive merge: every {ms} ms (+ POST /v1/fleet/merge on demand)"),
    }
    println!("  POST /v1/jobs | GET /v1/jobs[/<id>[/result]] | POST /v1/jobs/<id>/cancel");
    println!("  GET /v1/archive | POST /v1/fleet/merge | GET /v1/stats | GET /v1/health | POST /v1/shutdown");
    server.run()?;
    println!("releq fleet: drained and stopped");
    Ok(())
}

pub fn cmd_admm(args: &Args) -> Result<()> {
    let net_name = args.str_of("net", "lenet");
    let (manifest, engine) = bringup()?;
    let net = manifest.network(&net_name)?;
    let mut env_cfg = EnvConfig::default();
    env_cfg.pretrain_steps = config::preset(&net_name).env.pretrain_steps;
    let env = QuantEnv::new(engine, net, manifest.bits_max, manifest.fp_bits, env_cfg)?;
    let target = args.f64_of("target-bits", 5.0);
    let sel = AdmmSelector::new(AdmmConfig::default());
    let bits = sel.select(net, &env.pretrained, target);
    let acc = env.retrain_and_eval(&bits, env.cfg.long_retrain_steps)?;
    println!("{net_name}: ADMM-selected bits {:?} (target avg {target})", bits);
    println!("accuracy {:.4} (fp {:.4}), state_q {:.3}", acc, env.acc_fullp, env.state_q(&bits));
    if let Some(paper) = paper_solution(&net_name) {
        println!("paper's published ADMM bits: {:?}", paper);
    }
    Ok(())
}
