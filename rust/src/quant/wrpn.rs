//! WRPN mid-tread weight quantizer (paper §4.2, eq. 1) — Rust mirror of the
//! Pallas kernel's in-tile quantization, used by the ADMM baseline, the
//! Pareto cost model and the parity tests.

/// Bitwidths >= FP_BITS select the full-precision (identity) path,
/// matching `python/compile/quant.py`.
pub const FP_BITS: f32 = 9.0;

/// Number of positive quantization levels for bitwidth `k` (one sign bit).
#[inline]
pub fn levels(k: f32) -> f32 {
    (k - 1.0).exp2() - 1.0
}

/// Mid-tread fake quantization: zero IS a representable level (paper eq. 1).
#[inline]
pub fn quantize_mid_tread(w: f32, k: f32) -> f32 {
    if k >= FP_BITS {
        return w;
    }
    let l = levels(k);
    let wc = w.clamp(-1.0, 1.0);
    // jnp.round lowers to round-half-even; round_ties_even matches exactly.
    (l * wc).round_ties_even() / l
}

/// Mid-rise variant (levels shifted half a step; zero excluded). The paper
/// uses mid-tread; this exists for the quantization-style comparison.
#[inline]
pub fn quantize_mid_rise(w: f32, k: f32) -> f32 {
    if k >= FP_BITS {
        return w;
    }
    let l = levels(k);
    let wc = w.clamp(-1.0, 1.0);
    ((l * wc).floor() + 0.5) / l
}

/// Quantize a slice (one layer's weights) in place-free form.
pub fn quantize_slice(w: &[f32], k: f32) -> Vec<f32> {
    w.iter().map(|&x| quantize_mid_tread(x, k)).collect()
}

/// Total square quantization error of a layer at bitwidth `k`
/// (the objective ADMM's bitwidth search minimizes, paper §4.6 / [46]).
pub fn sq_error(w: &[f32], k: f32) -> f64 {
    w.iter()
        .map(|&x| {
            let d = (quantize_mid_tread(x, k) - x) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_fp_bits() {
        for &w in &[-1.7f32, -0.3, 0.0, 0.9, 2.4] {
            assert_eq!(quantize_mid_tread(w, 9.0), w);
            assert_eq!(quantize_mid_tread(w, 16.0), w);
        }
    }

    #[test]
    fn binary_is_sign_times_unit() {
        // k=1 -> levels = 0 -> degenerate; k=2 -> levels = 1 -> {-1, 0, 1}
        assert_eq!(quantize_mid_tread(0.9, 2.0), 1.0);
        assert_eq!(quantize_mid_tread(-0.9, 2.0), -1.0);
        assert_eq!(quantize_mid_tread(0.2, 2.0), 0.0);
    }

    #[test]
    fn clips_to_unit_range() {
        assert_eq!(quantize_mid_tread(5.0, 3.0), 1.0);
        assert_eq!(quantize_mid_tread(-5.0, 3.0), -1.0);
    }

    #[test]
    fn idempotent() {
        for k in 2..=8 {
            for i in -10..=10 {
                let w = i as f32 / 10.0;
                let q = quantize_mid_tread(w, k as f32);
                assert_eq!(quantize_mid_tread(q, k as f32), q, "k={k} w={w}");
            }
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let w: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.618).sin() * 0.8).collect();
        let mut last = f64::INFINITY;
        for k in 2..=8 {
            let e = sq_error(&w, k as f32);
            assert!(e < last, "k={k}: {e} !< {last}");
            last = e;
        }
        assert_eq!(sq_error(&w, 9.0), 0.0);
    }

    #[test]
    fn mid_rise_excludes_zero() {
        let q = quantize_mid_rise(0.0, 3.0);
        assert!(q != 0.0);
        assert!((q.abs() - 0.5 / levels(3.0)).abs() < 1e-6);
    }

    #[test]
    fn values_are_on_grid() {
        for k in 2..=8 {
            let l = levels(k as f32);
            for i in -20..=20 {
                let w = i as f32 / 20.0 * 1.4;
                let q = quantize_mid_tread(w, k as f32);
                let steps = q * l;
                assert!(
                    (steps - steps.round()).abs() < 1e-5,
                    "k={k} w={w} q={q} not on grid"
                );
            }
        }
    }
}
