//! The paper's State-of-Quantization cost model (§2.4):
//!
//! ```text
//!             Σ_l (n_w(l) · E_mem/E_mac + n_mac(l)) · bits(l)
//! State_Q = ─────────────────────────────────────────────────────
//!             Σ_l (n_w(l) · E_mem/E_mac + n_mac(l)) · bits_max
//! ```
//!
//! with E_mem/E_mac ≈ 120 (TETRIS [16]). This single scalar drives the reward
//! (together with State-of-Relative-Accuracy), the Pareto x-axis (Fig 6), and
//! the average-bitwidth reporting of Table 2.

use crate::runtime::NetworkMeta;

/// Memory-access energy over MAC energy (paper §2.4, citing TETRIS).
pub const E_MEM_OVER_E_MAC: f64 = 120.0;

/// Per-network cost model with per-layer precomputed weights.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// per-layer (n_w * ratio + n_mac) — the bracketed term of State_Q
    pub layer_cost: Vec<f64>,
    pub bits_max: f64,
    pub total_cost: f64,
}

impl CostModel {
    pub fn new(net: &NetworkMeta, bits_max: u32) -> CostModel {
        let layer_cost: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.w_len as f64 * E_MEM_OVER_E_MAC + l.n_macs as f64)
            .collect();
        let total_cost = layer_cost.iter().sum();
        CostModel { layer_cost, bits_max: bits_max as f64, total_cost }
    }

    /// State_Q for a bitwidth assignment (1.0 == every layer at bits_max).
    pub fn state_q(&self, bits: &[u32]) -> f64 {
        assert_eq!(bits.len(), self.layer_cost.len());
        let num: f64 = self
            .layer_cost
            .iter()
            .zip(bits)
            .map(|(c, &b)| c * b as f64)
            .sum();
        num / (self.total_cost * self.bits_max)
    }

    /// Cost-weighted average bitwidth (what Table 2's "Average Bitwidth"
    /// reports is the plain mean; both are exposed).
    pub fn weighted_avg_bits(&self, bits: &[u32]) -> f64 {
        self.state_q(bits) * self.bits_max
    }

    pub fn mean_bits(bits: &[u32]) -> f64 {
        bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64
    }
}

/// Test-support constructors shared by coordinator/pareto/sim unit tests.
#[cfg(test)]
pub mod tests_support {
    use crate::runtime::{LayerMeta, NetworkMeta};

    /// Build a toy network from per-layer (weight-count, MAC-count) pairs.
    pub fn toy_net(costs: &[(usize, u64)]) -> NetworkMeta {
        let layers = costs
            .iter()
            .enumerate()
            .map(|(i, &(w, m))| LayerMeta {
                name: format!("l{i}"),
                kind: "dense".into(),
                w_shape: vec![w],
                w_offset: 0,
                w_len: w,
                b_offset: 0,
                b_len: 0,
                n_macs: m,
                in_dim: 1,
                out_dim: 1,
            })
            .collect();
        NetworkMeta {
            name: "toy".into(),
            l: costs.len(),
            p: 0,
            input: [1, 1, 1],
            classes: 10,
            train_batch: 1,
            eval_batch: 1,
            fused_k: 4,
            eval_batch_k: 0,
            train_size: 64,
            dataset: "none".into(),
            layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::toy_net;
    use super::*;

    #[test]
    fn uniform_max_bits_is_one() {
        let net = toy_net(&[(100, 1000), (200, 500)]);
        let cm = CostModel::new(&net, 8);
        assert!((cm.state_q(&[8, 8]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_in_bits() {
        let net = toy_net(&[(100, 1000), (200, 500)]);
        let cm = CostModel::new(&net, 8);
        assert!((cm.state_q(&[4, 4]) - 0.5).abs() < 1e-12);
        assert!((cm.state_q(&[2, 2]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn weighting_follows_layer_cost() {
        // layer 0 dominates cost; lowering its bits moves State_Q much more
        let net = toy_net(&[(10_000, 1_000_000), (10, 100)]);
        let cm = CostModel::new(&net, 8);
        let drop0 = cm.state_q(&[8, 8]) - cm.state_q(&[2, 8]);
        let drop1 = cm.state_q(&[8, 8]) - cm.state_q(&[8, 2]);
        assert!(drop0 > 100.0 * drop1, "{drop0} vs {drop1}");
    }

    #[test]
    fn memory_ratio_weights_weight_heavy_layers() {
        // same MACs, one layer has far more weights -> higher cost share
        let net = toy_net(&[(100_000, 1000), (10, 1000)]);
        let cm = CostModel::new(&net, 8);
        assert!(cm.layer_cost[0] > 1000.0 * cm.layer_cost[1]);
    }
}
