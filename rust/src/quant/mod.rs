//! Quantization math mirrored on the Rust side: the WRPN fake-quantizer
//! (paper §4.2, eq. 1) and the State-of-Quantization cost model (paper §2.4).
//!
//! The quantizer here must agree bit-for-bit with the Layer-1 Pallas kernel
//! (`python/compile/kernels/qmatmul.py`); the integration test
//! `rust/tests/artifact_parity.rs` checks that against the AOT artifacts.

pub mod cost;
pub mod wrpn;

pub use cost::{CostModel, E_MEM_OVER_E_MAC};
pub use wrpn::{quantize_mid_rise, quantize_mid_tread, quantize_slice, sq_error, FP_BITS};
