//! Versioned artifact registry: content-addressed, digest-verified network
//! installs into a *running* daemon.
//!
//! The base `artifacts/manifest.json` is read once at startup; without this
//! module a long-running `releq serve` can never gain a network (or pick up a
//! recompiled one) short of a restart. The registry adds:
//!
//! * **Per-network registry manifests** — `registry.json` in a source dir, or
//!   the inline body of `POST /v1/networks`: schema version, the network's
//!   metadata (the same shape as a `manifest.json` `networks.<name>` entry,
//!   parsed by the shared [`NetworkMeta::from_json`]), a monotonically
//!   increasing `version`, and per-artifact-file sha256 digests.
//! * **Digest-verified, atomic installs** — every artifact file is verified
//!   against its stamped sha256 while being staged ([`crate::util::sha256`],
//!   dependency-free), then the staging dir is `rename`d into a
//!   content-addressed cache slot keyed by the manifest digest (the archive's
//!   tmp + rename idiom: an injected mid-install failure leaves no partial
//!   final state). Manifests without digests are **legacy**: accepted, checks
//!   skipped, counted in the `legacy_manifests` stat — the `eval_batch_k: 0`
//!   degradation pattern.
//! * **Version isolation through qualified names** — an installed version's
//!   [`NetworkMeta.name`] is `<net>@<digest12>`. Every artifact execution in
//!   the coordinator derives names from `net.name` (`<name>_train`, ...),
//!   while data generation keys on the separate `net.dataset` field, so a
//!   qualified name routes all artifact lookups through per-version
//!   [`Engine::alias`] entries (and per-version compile-cache keys, and
//!   per-version `exec_stats` rows) with zero changes to the env/searcher —
//!   and bit-identical data.
//! * **Pinned sessions across upgrades** — serve sessions are keyed by
//!   `(net, manifest_version, env fingerprint)`; a job in flight when an
//!   upgrade lands keeps its pinned [`NetVersion`] (its aliases and compiled
//!   executables stay valid through the `Arc`), and a retired version's
//!   aliases are evicted only when its last session drops
//!   ([`Registry::unpin`]).
//!
//! The registry works without an engine (stub tier: install/verify/version
//! bookkeeping only) and without a base manifest (`bind_with` stub daemons).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::config::validate_net_name;
use crate::runtime::faults::FaultPlan;
use crate::runtime::manifest::MANIFEST_SCHEMA_VERSION;
use crate::runtime::{Engine, Manifest, NetworkMeta};
use crate::util::json::Json;
use crate::util::sha256;
use crate::util::{read_recover, write_recover};

/// Hex prefix length of the manifest digest used for install-dir names and
/// qualified artifact names. 48 bits of content address is plenty for the
/// handful of versions a daemon holds, and keeps artifact names readable in
/// `exec_stats` rows.
const DIGEST12: usize = 12;

/// Fault-injection artifact name for the atomic-install seam: the plan hook
/// fires after staging (files fetched, verified, written) and before the
/// final rename — the window an atomicity bug would leave partial state in.
pub const INSTALL_FAULT: &str = "registry_install";

/// Why a registration was refused, typed so the HTTP route can map it:
/// `Invalid` → 400, `Conflict` → 409, `Internal` → 500.
#[derive(Debug)]
pub enum RegisterError {
    /// malformed manifest, bad name, or a digest mismatch
    Invalid(String),
    /// version not monotonically increasing (or digest clash on a version)
    Conflict(String),
    /// I/O or injected failure during install
    Internal(anyhow::Error),
}

impl std::fmt::Display for RegisterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegisterError::Invalid(m) => write!(f, "invalid registration: {m}"),
            RegisterError::Conflict(m) => write!(f, "version conflict: {m}"),
            RegisterError::Internal(e) => write!(f, "install failed: {e:#}"),
        }
    }
}

impl std::error::Error for RegisterError {}

/// One installed (or baseline) version of a network.
///
/// `digest` is empty for **baseline** versions — networks resolved straight
/// from the startup manifest, whose artifacts live unqualified in the base
/// artifacts dir. Installed versions carry the manifest digest, the
/// content-addressed install dir, and a digest-qualified `meta.name`.
pub struct NetVersion {
    /// the client-facing network name (`lenet2`)
    pub logical: String,
    /// metadata handed to sessions; `name` is `<logical>@<digest12>` for
    /// installed versions, `logical` for baseline ones
    pub meta: NetworkMeta,
    pub version: u64,
    /// full manifest sha256 (empty = baseline)
    pub digest: String,
    /// where the artifact files live
    pub dir: PathBuf,
    /// sessions currently pinned to this version
    refs: AtomicU64,
}

impl NetVersion {
    pub fn refs(&self) -> u64 {
        self.refs.load(Ordering::Relaxed)
    }

    /// Installed via the registry (as opposed to baseline-from-startup)?
    pub fn is_installed(&self) -> bool {
        !self.digest.is_empty()
    }

    fn qualified_prefix(&self) -> String {
        format!("{}@{}", self.logical, &self.digest[..DIGEST12.min(self.digest.len())])
    }
}

/// Successful registration summary (the `POST /v1/networks` response body).
#[derive(Debug)]
pub struct Installed {
    pub name: String,
    pub version: u64,
    pub digest: String,
    /// false when the exact same manifest (same digest) was already
    /// installed — idempotent re-registration
    pub installed: bool,
}

/// Parsed + validated registry manifest (`registry.json` / inline body).
struct RegManifest {
    schema_version: u32,
    name: String,
    version: u64,
    /// raw `networks.<name>`-shaped entry (validated before NetworkMeta
    /// parsing, which panics on missing keys by design for the trusted base
    /// manifest)
    network: Json,
    sha256: BTreeMap<String, String>,
}

impl RegManifest {
    fn parse(j: &Json) -> Result<RegManifest, RegisterError> {
        let inv = |m: String| RegisterError::Invalid(m);
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| inv("manifest needs a string `name`".into()))?
            .to_string();
        validate_net_name(&name).map_err(|e| inv(format!("{e:#}")))?;
        let version = j
            .get("version")
            .and_then(|v| v.as_f64())
            .filter(|v| *v >= 1.0 && v.fract() == 0.0)
            .ok_or_else(|| inv("manifest needs an integer `version` >= 1".into()))?
            as u64;
        let schema_version = j
            .get("schema_version")
            .and_then(|v| v.as_usize())
            .unwrap_or(0) as u32;
        if schema_version > MANIFEST_SCHEMA_VERSION {
            return Err(inv(format!(
                "manifest schema_version {schema_version} is newer than this daemon \
                 supports ({MANIFEST_SCHEMA_VERSION})"
            )));
        }
        let network = j
            .get("network")
            .cloned()
            .ok_or_else(|| inv("manifest needs a `network` object".into()))?;
        validate_network_body(&network).map_err(inv)?;
        let mut sha = BTreeMap::new();
        if let Some(sj) = j.get("sha256") {
            let m = sj
                .as_obj()
                .ok_or_else(|| inv("`sha256` must be an object".into()))?;
            for (file, hexj) in m {
                let hex = hexj
                    .as_str()
                    .ok_or_else(|| inv(format!("sha256[{file}] must be a hex string")))?;
                validate_artifact_file(&name, file).map_err(inv)?;
                sha.insert(file.clone(), hex.to_ascii_lowercase());
            }
        }
        Ok(RegManifest { schema_version, name, version, network, sha256: sha })
    }

    /// Canonical serialization hashed into the manifest digest. `Json::Obj`
    /// is a `BTreeMap`, so `dump()` is already key-sorted and deterministic.
    /// Inline `files` payloads are excluded: content identity is the digest
    /// map (legacy inline uploads — no digests — are addressed by metadata
    /// alone, which is as strong as legacy gets).
    fn canonical(&self) -> String {
        let sha = Json::Obj(
            self.sha256.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("name", Json::Str(self.name.clone())),
            ("version", Json::Num(self.version as f64)),
            ("network", self.network.clone()),
            ("sha256", sha),
        ])
        .dump()
    }

    /// The artifact files this manifest ships: the digest map's keys, or —
    /// legacy — the standard AOT layout derived from the network metadata.
    fn files(&self) -> Vec<String> {
        if !self.sha256.is_empty() {
            return self.sha256.keys().cloned().collect();
        }
        let fused = self.network.get("fused_k").and_then(|v| v.as_usize()).unwrap_or(0);
        let ebk = self.network.get("eval_batch_k").and_then(|v| v.as_usize()).unwrap_or(0);
        expected_files(&self.name, fused, ebk)
    }
}

/// The standard artifact-file layout the AOT emitter writes for a network.
pub fn expected_files(name: &str, fused_k: usize, eval_batch_k: usize) -> Vec<String> {
    let mut v = vec![
        format!("{name}_init.hlo.txt"),
        format!("{name}_train.hlo.txt"),
        format!("{name}_eval.hlo.txt"),
    ];
    if fused_k > 0 {
        v.push(format!("{name}_retrain_eval.hlo.txt"));
    }
    if eval_batch_k > 0 {
        v.push(format!("{name}_retrain_eval_batch.hlo.txt"));
    }
    v
}

/// An artifact filename in a manifest must be `<net>_<suffix>.hlo.txt` with a
/// plain-identifier suffix — path traversal through a crafted filename is
/// structurally impossible.
fn validate_artifact_file(net: &str, file: &str) -> Result<(), String> {
    let rest = file
        .strip_prefix(net)
        .and_then(|r| r.strip_prefix('_'))
        .and_then(|r| r.strip_suffix(".hlo.txt"))
        .ok_or_else(|| format!("artifact file `{file}` is not `{net}_<suffix>.hlo.txt`"))?;
    if rest.is_empty() || !rest.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_') {
        return Err(format!("artifact file `{file}` has a non-identifier suffix"));
    }
    Ok(())
}

/// Pre-validate a `networks.<name>`-shaped entry so the shared
/// [`NetworkMeta::from_json`] (which `panic!`s on missing keys, fine for the
/// trusted startup manifest) is safe to call on an HTTP-supplied body.
fn validate_network_body(nj: &Json) -> Result<(), String> {
    let obj = nj.as_obj().ok_or("`network` must be an object")?;
    for key in ["l", "p", "classes", "train_batch", "eval_batch", "fused_k", "train_size"] {
        obj.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("network needs numeric `{key}`"))?;
    }
    obj.get("dataset")
        .and_then(|v| v.as_str())
        .ok_or("network needs string `dataset`")?;
    let input = obj.get("input").and_then(|v| v.as_arr()).ok_or("network needs `input` array")?;
    if input.len() != 3 || input.iter().any(|v| v.as_usize().is_none()) {
        return Err("`input` must be [H, W, C]".into());
    }
    let layers =
        obj.get("layers").and_then(|v| v.as_arr()).ok_or("network needs `layers` array")?;
    if layers.is_empty() {
        return Err("`layers` must be non-empty".into());
    }
    for (i, lj) in layers.iter().enumerate() {
        let lo = lj.as_obj().ok_or_else(|| format!("layers[{i}] must be an object"))?;
        for key in ["name", "kind"] {
            lo.get(key)
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("layers[{i}] needs string `{key}`"))?;
        }
        for key in ["w_offset", "w_len", "b_offset", "b_len", "n_macs", "in_dim", "out_dim"] {
            lo.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("layers[{i}] needs numeric `{key}`"))?;
        }
        let ws = lo
            .get("w_shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| format!("layers[{i}] needs `w_shape` array"))?;
        if ws.iter().any(|v| v.as_usize().is_none()) {
            return Err(format!("layers[{i}].w_shape must be numeric"));
        }
    }
    Ok(())
}

/// Where an install fetches artifact bytes from.
enum Fetch<'a> {
    /// files sit next to `registry.json` in a source directory
    Dir(&'a Path),
    /// `files: {filename -> text}` shipped inline in the POST body
    Inline(&'a BTreeMap<String, Json>),
}

impl Fetch<'_> {
    fn read(&self, file: &str) -> Result<Vec<u8>> {
        match self {
            Fetch::Dir(d) => {
                let p = d.join(file);
                std::fs::read(&p).with_context(|| format!("reading artifact {p:?}"))
            }
            Fetch::Inline(m) => {
                let v = m
                    .get(file)
                    .and_then(|v| v.as_str())
                    .with_context(|| format!("inline upload missing `files.{file}`"))?;
                Ok(v.as_bytes().to_vec())
            }
        }
    }
}

/// The registry: versioned network installs layered over a startup manifest.
///
/// * `base`    — the startup manifest; baseline resolution target (optional:
///   stub daemons run without one).
/// * `engine`  — alias target for installed artifacts (optional: the stub
///   tier exercises install/verify/version logic without PJRT).
/// * `cache_dir` — the content-addressed install cache; `None` disables
///   installation (`POST /v1/networks` → 503) but resolution still serves
///   the base manifest.
pub struct Registry {
    base: Option<Manifest>,
    engine: Option<Arc<Engine>>,
    cache_dir: Option<PathBuf>,
    /// per-network installed versions, oldest→newest
    nets: RwLock<BTreeMap<String, Vec<Arc<NetVersion>>>>,
    installs: AtomicU64,
    digest_rejects: AtomicU64,
    legacy_manifests: AtomicU64,
    evictions: AtomicU64,
    staging_seq: AtomicU64,
    faults: Option<Arc<FaultPlan>>,
}

impl Registry {
    /// Engine-less registry (stub daemons, tests): installs verify and
    /// version-track but alias nothing.
    pub fn new(base: Option<Manifest>, cache_dir: Option<PathBuf>) -> Result<Registry> {
        Ok(Registry::with_faults(base, cache_dir, None, FaultPlan::from_env()?))
    }

    /// The real daemon's registry: installed artifacts are aliased into the
    /// engine's compile path under digest-qualified names.
    pub fn with_engine(
        base: Manifest,
        cache_dir: Option<PathBuf>,
        engine: Arc<Engine>,
    ) -> Result<Registry> {
        Ok(Registry::with_faults(Some(base), cache_dir, Some(engine), FaultPlan::from_env()?))
    }

    /// Full-control constructor (fault-injection tests pass an explicit
    /// plan instead of racing on the process environment).
    pub fn with_faults(
        base: Option<Manifest>,
        cache_dir: Option<PathBuf>,
        engine: Option<Arc<Engine>>,
        faults: Option<Arc<FaultPlan>>,
    ) -> Registry {
        Registry {
            base,
            engine,
            cache_dir,
            nets: RwLock::new(BTreeMap::new()),
            installs: AtomicU64::new(0),
            digest_rejects: AtomicU64::new(0),
            legacy_manifests: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            staging_seq: AtomicU64::new(0),
            faults: faults.filter(|f| !f.is_empty()),
        }
    }

    /// Can this registry install networks? (`--registry-dir` given)
    pub fn enabled(&self) -> bool {
        self.cache_dir.is_some()
    }

    /// Register from a `POST /v1/networks` body: either
    /// `{"source": "/path/to/dir"}` (reads `<dir>/registry.json`, fetches
    /// artifacts from the dir) or a full inline manifest (artifact text
    /// under `files`, practical only for networks fitting the HTTP body
    /// cap).
    pub fn register_json(&self, body: &Json) -> Result<Installed, RegisterError> {
        if let Some(src) = body.get("source").and_then(|v| v.as_str()) {
            return self.register_dir(Path::new(src));
        }
        let man = RegManifest::parse(body)?;
        let empty = BTreeMap::new();
        let files = body.get("files").and_then(|v| v.as_obj()).unwrap_or(&empty);
        self.install(man, Fetch::Inline(files))
    }

    /// Register from a source directory containing `registry.json` plus the
    /// artifact files it names.
    pub fn register_dir(&self, dir: &Path) -> Result<Installed, RegisterError> {
        let p = dir.join("registry.json");
        let text = std::fs::read_to_string(&p)
            .map_err(|e| RegisterError::Invalid(format!("reading {p:?}: {e}")))?;
        let j = Json::parse(&text)
            .map_err(|e| RegisterError::Invalid(format!("parsing {p:?}: {e:#}")))?;
        let man = RegManifest::parse(&j)?;
        self.install(man, Fetch::Dir(dir))
    }

    /// The newest version the daemon knows for `name` (installed or base).
    fn current_version(&self, name: &str) -> Option<u64> {
        if let Some(v) = read_recover(&self.nets).get(name).and_then(|v| v.last().cloned()) {
            return Some(v.version);
        }
        self.base
            .as_ref()
            .and_then(|b| b.networks.iter().find(|n| n.name == name))
            .map(|n| n.version)
    }

    fn install(&self, man: RegManifest, fetch: Fetch<'_>) -> Result<Installed, RegisterError> {
        let Some(cache_dir) = &self.cache_dir else {
            return Err(RegisterError::Internal(anyhow::anyhow!(
                "registry disabled — start the daemon with --registry-dir"
            )));
        };
        let digest = sha256::digest_hex(man.canonical().as_bytes());
        let d12 = &digest[..DIGEST12];

        // Monotonicity gate (early, before any I/O). Idempotent re-install
        // of the exact same manifest is OK-but-a-no-op.
        if let Some(existing) = read_recover(&self.nets)
            .get(&man.name)
            .and_then(|vs| vs.iter().find(|v| v.version == man.version).cloned())
        {
            if existing.digest == digest {
                return Ok(Installed {
                    name: man.name,
                    version: man.version,
                    digest,
                    installed: false,
                });
            }
            return Err(RegisterError::Conflict(format!(
                "{} version {} already installed with a different digest",
                man.name, man.version
            )));
        }
        if let Some(cur) = self.current_version(&man.name) {
            if man.version <= cur {
                return Err(RegisterError::Conflict(format!(
                    "{} version {} is not newer than the current version {cur}",
                    man.name, man.version
                )));
            }
        }

        let legacy = man.sha256.is_empty();
        let files = man.files();
        if !legacy {
            // the digest map must cover the standard layout for this
            // metadata — a manifest claiming fused_k > 0 but shipping no
            // fused artifact would fail at first use instead of at install
            let fused = man.network.get("fused_k").and_then(|v| v.as_usize()).unwrap_or(0);
            let ebk = man.network.get("eval_batch_k").and_then(|v| v.as_usize()).unwrap_or(0);
            for need in expected_files(&man.name, fused, ebk) {
                if !man.sha256.contains_key(&need) {
                    return Err(RegisterError::Invalid(format!(
                        "sha256 map is missing required artifact `{need}`"
                    )));
                }
            }
        }

        // Stage: fetch + verify + write every file into a tmp dir, then one
        // atomic rename publishes the install (the archive's persistence
        // idiom). Any failure from here on removes the staging dir; the
        // final content-addressed slot either fully exists or not at all.
        let seq = self.staging_seq.fetch_add(1, Ordering::Relaxed);
        let staging = cache_dir.join(format!("tmp-{d12}-{}-{seq}", std::process::id()));
        let final_dir = cache_dir.join(d12);
        let stage = || -> Result<(), RegisterError> {
            std::fs::create_dir_all(&staging)
                .with_context(|| format!("creating staging dir {staging:?}"))
                .map_err(RegisterError::Internal)?;
            for file in &files {
                let bytes = fetch.read(file).map_err(|e| RegisterError::Invalid(format!("{e:#}")))?;
                if let Some(want) = man.sha256.get(file) {
                    let got = sha256::digest_hex(&bytes);
                    if got != *want {
                        self.digest_rejects.fetch_add(1, Ordering::Relaxed);
                        return Err(RegisterError::Invalid(format!(
                            "digest mismatch for `{file}`: manifest says {want}, content is {got}"
                        )));
                    }
                }
                std::fs::write(staging.join(file), &bytes)
                    .with_context(|| format!("staging `{file}`"))
                    .map_err(RegisterError::Internal)?;
            }
            // provenance: the manifest travels with its artifacts
            std::fs::write(staging.join("registry.json"), man.canonical())
                .context("staging registry.json")
                .map_err(RegisterError::Internal)?;
            // fault seam: the injected failure window between staging and
            // publication — the atomicity property under test
            if let Some(f) = &self.faults {
                f.on_exec(INSTALL_FAULT).map_err(RegisterError::Internal)?;
            }
            if !final_dir.exists() {
                std::fs::rename(&staging, &final_dir)
                    .with_context(|| format!("publishing install to {final_dir:?}"))
                    .map_err(RegisterError::Internal)?;
            }
            Ok(())
        };
        let staged = stage();
        if staging.exists() {
            let _ = std::fs::remove_dir_all(&staging);
        }
        staged?;

        if legacy {
            self.legacy_manifests.fetch_add(1, Ordering::Relaxed);
        }

        // Build the digest-qualified metadata. The name charset forbids `@`,
        // so qualified names can't collide with client-facing ones.
        let qualified = format!("{}@{d12}", man.name);
        let mut meta = NetworkMeta::from_json(&qualified, &man.network)
            .map_err(|e| RegisterError::Invalid(format!("{e:#}")))?;
        meta.version = man.version;
        meta.sha256 = man.sha256.clone();

        // Alias every shipped artifact into the engine's compile path under
        // its qualified name — compile-on-first-use lands in per-version
        // cache entries pointing at the content-addressed install.
        if let Some(engine) = &self.engine {
            for file in &files {
                // files() output is validate_artifact_file-clean by
                // construction, so the strips always succeed
                if let Some(suffix) = file
                    .strip_prefix(&man.name)
                    .and_then(|r| r.strip_prefix('_'))
                    .and_then(|r| r.strip_suffix(".hlo.txt"))
                {
                    engine.alias(&format!("{qualified}_{suffix}"), final_dir.join(file));
                }
            }
        }

        let nv = Arc::new(NetVersion {
            logical: man.name.clone(),
            meta,
            version: man.version,
            digest: digest.clone(),
            dir: final_dir,
            refs: AtomicU64::new(0),
        });

        // Activate under the write lock, re-checking monotonicity against a
        // racing install that won the gate in between.
        let mut retired: Vec<Arc<NetVersion>> = Vec::new();
        {
            let mut nets = write_recover(&self.nets);
            let vs = nets.entry(man.name.clone()).or_default();
            if let Some(last) = vs.last() {
                if last.version >= man.version {
                    drop(nets);
                    if let Some(engine) = &self.engine {
                        engine.unalias_prefix(&nv.qualified_prefix());
                    }
                    return Err(RegisterError::Conflict(format!(
                        "{} version {} raced a newer install",
                        man.name, man.version
                    )));
                }
            }
            vs.push(nv);
            // retire superseded versions nothing is pinned to; versions with
            // live sessions stay until their last session drops (unpin)
            let mut i = 0;
            while i + 1 < vs.len() {
                if vs[i].refs() == 0 {
                    retired.push(vs.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        for old in retired {
            self.evict(&old);
        }
        self.installs.fetch_add(1, Ordering::Relaxed);
        Ok(Installed { name: man.name, version: man.version, digest, installed: true })
    }

    fn evict(&self, v: &Arc<NetVersion>) {
        if let Some(engine) = &self.engine {
            engine.unalias_prefix(&v.qualified_prefix());
        }
        // the content-addressed dir stays on disk (it's a cache: re-installs
        // of the same digest reuse it); only the live aliases/compiled
        // executables are dropped
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Resolve a client-facing network name to the version new sessions
    /// should use: the newest installed version, else the base manifest's
    /// entry as a baseline version (unqualified name — baseline env
    /// fingerprints stay byte-identical to the pre-registry daemon).
    pub fn resolve(&self, net: &str) -> Result<Arc<NetVersion>> {
        if let Some(v) = read_recover(&self.nets).get(net).and_then(|vs| vs.last().cloned()) {
            return Ok(v);
        }
        if let Some(base) = &self.base {
            let meta = base.network(net)?;
            return Ok(Arc::new(NetVersion {
                logical: net.to_string(),
                meta: meta.clone(),
                version: meta.version,
                digest: String::new(),
                dir: base.dir.clone(),
                refs: AtomicU64::new(0),
            }));
        }
        let installed: Vec<String> = read_recover(&self.nets).keys().cloned().collect();
        anyhow::bail!("unknown network `{net}` (registry has: {})", installed.join(", "))
    }

    /// A session pinned itself to this version (serve's prepare path).
    pub fn pin(&self, v: &Arc<NetVersion>) {
        v.refs.fetch_add(1, Ordering::Relaxed);
    }

    /// A session pinned to this version dropped. A superseded installed
    /// version whose last pin just released is evicted here — "old versions
    /// evicted only when their last session drops".
    pub fn unpin(&self, v: &Arc<NetVersion>) {
        let before = v.refs.fetch_sub(1, Ordering::Relaxed);
        debug_assert!(before > 0, "unpin without a matching pin");
        if !v.is_installed() || before != 1 {
            return;
        }
        let mut evict = false;
        {
            let mut nets = write_recover(&self.nets);
            if let Some(vs) = nets.get_mut(&v.logical) {
                let is_latest = vs.last().map(|l| Arc::ptr_eq(l, v)).unwrap_or(false);
                if !is_latest && v.refs() == 0 {
                    vs.retain(|x| !Arc::ptr_eq(x, v));
                    evict = true;
                }
            }
        }
        if evict {
            self.evict(v);
        }
    }

    /// `GET /v1/stats` registry fragment.
    pub fn stats_json(&self) -> Json {
        let (networks, versions) = {
            let nets = read_recover(&self.nets);
            (nets.len(), nets.values().map(|v| v.len()).sum::<usize>())
        };
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled())),
            ("networks", Json::Num(networks as f64)),
            ("versions", Json::Num(versions as f64)),
            ("installs", Json::Num(self.installs.load(Ordering::Relaxed) as f64)),
            (
                "digest_rejects",
                Json::Num(self.digest_rejects.load(Ordering::Relaxed) as f64),
            ),
            (
                "legacy_manifests",
                Json::Num(self.legacy_manifests.load(Ordering::Relaxed) as f64),
            ),
            ("evictions", Json::Num(self.evictions.load(Ordering::Relaxed) as f64)),
        ])
    }

    /// Installed-version snapshot for tests and the CLI.
    pub fn versions(&self, net: &str) -> Vec<Arc<NetVersion>> {
        read_recover(&self.nets).get(net).cloned().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_file_validation() {
        assert!(validate_artifact_file("lenet", "lenet_train.hlo.txt").is_ok());
        assert!(validate_artifact_file("lenet", "lenet_retrain_eval_batch.hlo.txt").is_ok());
        assert!(validate_artifact_file("lenet", "other_train.hlo.txt").is_err());
        assert!(validate_artifact_file("lenet", "lenet_.hlo.txt").is_err());
        assert!(validate_artifact_file("lenet", "lenet_../evil.hlo.txt").is_err());
        assert!(validate_artifact_file("lenet", "lenet_train.txt").is_err());
    }

    #[test]
    fn expected_files_follow_metadata() {
        assert_eq!(expected_files("n", 0, 0).len(), 3);
        assert_eq!(expected_files("n", 3, 0).len(), 4);
        assert_eq!(expected_files("n", 3, 8).len(), 5);
    }
}
