//! The ReLeQ coordinator — the paper's contribution, Layer 3.
//!
//! * [`embedding`] — state-space embedding (paper §2.4, Table 1)
//! * [`env`] — the quantization environment: quantized short-retrain +
//!   accuracy evaluation through the AOT artifacts
//! * [`reward`] — asymmetric reward shaping + the two ablation forms (§2.6)
//! * [`ppo`] — PPO driver: trajectories, GAE, updates through HLO (§2.7)
//! * [`search`] — the episode loop, convergence detection, final solution

pub mod embedding;
pub mod env;
pub mod ppo;
pub mod reward;
pub mod search;

pub use embedding::{embed, StaticFeatures, STATE_DIM};
pub use env::{EnvConfig, EnvStats, QuantEnv};
pub use ppo::{AgentKind, PpoAgent, PpoConfig, StepRecord, UpdateStats};
pub use reward::{RewardKind, RewardParams};
pub use search::{
    best_replica, run_replicas, ActionSpace, SearchConfig, SearchResult, Searcher,
};
