//! The ReLeQ coordinator — the paper's contribution, Layer 3.
//!
//! * [`embedding`] — state-space embedding (paper §2.4, Table 1)
//! * [`env`] — the quantization environment: quantized short-retrain +
//!   accuracy evaluation through the AOT artifacts
//! * [`reward`] — asymmetric reward shaping + the two ablation forms (§2.6)
//! * [`ppo`] — PPO driver: trajectories, GAE, updates through HLO (§2.7)
//! * [`prefetch`] — speculative accuracy memo-warming on the dispatcher
//! * [`rollout`] — lockstep batched rollouts over the shared env core,
//!   optionally pipelined over a `runtime::Dispatcher`
//! * [`search`] — the episode loop, convergence detection, final solution
//! * [`checkpoint`] — durable, checksummed search checkpoints written at
//!   PPO update boundaries; resumed runs continue bit-identically

pub mod checkpoint;
pub mod embedding;
pub mod env;
pub mod ppo;
pub mod prefetch;
pub mod reward;
pub mod rollout;
pub mod search;

pub use checkpoint::{
    AgentSnapshot, Durable, SearchCheckpoint, CHECKPOINT_FAULT, CHECKPOINT_SCHEMA_VERSION,
};
pub use embedding::{embed, StaticFeatures, STATE_DIM};
pub use env::{EnvConfig, EnvCore, EnvStats, QuantEnv};
pub use ppo::{AgentKind, PpoAgent, PpoConfig, StepRecord, UpdateStats};
pub use prefetch::Prefetcher;
pub use reward::{RewardKind, RewardParams};
pub use rollout::LaneRollout;
pub use search::{
    best_replica, run_replicas, ActionSpace, Cancelled, RolloutMode, SearchConfig, SearchCtl,
    SearchResult, Searcher,
};
