//! Reward shaping (paper §2.6, Fig 3).
//!
//! Three formulations, compared in the paper's Fig 10 ablation:
//!
//! * **Proposed** (Fig 3a) — asymmetric, accuracy-dominant, with a hard
//!   threshold below which quantization states are unacceptable. The paper
//!   gives the parameters (a = 0.2, b = 0.4, th = 0.4) and the qualitative
//!   shape but not the closed form; DESIGN.md §7 documents the
//!   reconstruction used here:
//!
//!   ```text
//!   State_A < th :  R = -1
//!   otherwise    :  R = State_A^(1/a) * (b + (1-b) * (1 - State_Q))
//!   ```
//!
//!   `State_A^(1/a) = State_A^5` makes the reward steeply sensitive to
//!   accuracy near 1.0 (the 2-D gradient of Fig 3a), while `b` guarantees a
//!   floor of reward for accuracy alone so the agent never profits from
//!   trashing accuracy to gain quantization.
//!
//! * **Ratio** (Fig 3b) — `R = State_A / State_Q`.
//! * **Diff**  (Fig 3c) — `R = State_A - State_Q`.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RewardKind {
    Proposed,
    Ratio,
    Diff,
}

impl RewardKind {
    pub fn parse(s: &str) -> anyhow::Result<RewardKind> {
        match s {
            "proposed" => Ok(RewardKind::Proposed),
            "ratio" => Ok(RewardKind::Ratio),
            "diff" => Ok(RewardKind::Diff),
            other => anyhow::bail!("unknown reward kind `{other}` (expected proposed|ratio|diff)"),
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct RewardParams {
    pub kind: RewardKind,
    /// accuracy-emphasis exponent parameter (paper: a = 0.2 -> exponent 1/a = 5)
    pub a: f64,
    /// accuracy floor weight (paper: b = 0.4)
    pub b: f64,
    /// relative-accuracy threshold below which solutions are unacceptable
    /// (paper: th = 0.4)
    pub th: f64,
}

impl Default for RewardParams {
    fn default() -> Self {
        RewardParams { kind: RewardKind::Proposed, a: 0.2, b: 0.4, th: 0.4 }
    }
}

impl RewardParams {
    pub fn with_kind(kind: RewardKind) -> Self {
        RewardParams { kind, ..Default::default() }
    }

    /// Reward for a (State_of_Relative_Accuracy, State_of_Quantization) pair.
    pub fn reward(&self, state_acc: f64, state_q: f64) -> f64 {
        match self.kind {
            RewardKind::Proposed => {
                if state_acc < self.th {
                    return -1.0;
                }
                let acc_term = state_acc.min(1.0).powf(1.0 / self.a);
                let quality = 1.0 - state_q.clamp(0.0, 1.0);
                acc_term * (self.b + (1.0 - self.b) * quality)
            }
            RewardKind::Ratio => state_acc / state_q.max(1e-6),
            RewardKind::Diff => state_acc - state_q,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-12;

    #[test]
    fn threshold_cliff() {
        let r = RewardParams::default();
        assert_eq!(r.reward(0.39, 0.3), -1.0);
        assert!(r.reward(0.41, 0.3) > -1.0);
    }

    #[test]
    fn monotone_in_accuracy() {
        let r = RewardParams::default();
        let mut last = -1.0;
        for i in 0..=20 {
            let acc = 0.4 + 0.6 * i as f64 / 20.0;
            let rew = r.reward(acc, 0.5);
            assert!(rew >= last - EPS, "acc {acc}: {rew} < {last}");
            last = rew;
        }
    }

    #[test]
    fn monotone_in_quantization_benefit() {
        let r = RewardParams::default();
        // lower State_Q (more quantized) must never decrease reward
        let mut last = -1.0;
        for i in (0..=10).rev() {
            let q = i as f64 / 10.0;
            let rew = r.reward(0.95, q);
            assert!(rew >= last - EPS);
            last = rew;
        }
    }

    #[test]
    fn asymmetry_accuracy_dominates() {
        let r = RewardParams::default();
        // losing 30% accuracy hurts far more than gaining 30% quantization helps
        let base = r.reward(1.0, 0.5);
        let acc_loss = base - r.reward(0.7, 0.5);
        let quant_gain = r.reward(1.0, 0.2) - base;
        assert!(
            acc_loss > 2.0 * quant_gain,
            "acc_loss {acc_loss} quant_gain {quant_gain}"
        );
    }

    #[test]
    fn accuracy_floor_b() {
        // even at State_Q = 1 (no quantization benefit) full accuracy earns b
        let r = RewardParams::default();
        assert!((r.reward(1.0, 1.0) - r.b).abs() < 1e-9);
    }

    #[test]
    fn ratio_and_diff_forms() {
        let rr = RewardParams::with_kind(RewardKind::Ratio);
        assert!((rr.reward(0.9, 0.45) - 2.0).abs() < 1e-9);
        let rd = RewardParams::with_kind(RewardKind::Diff);
        assert!((rd.reward(0.9, 0.45) - 0.45).abs() < 1e-9);
    }

    #[test]
    fn proposed_bounded() {
        let r = RewardParams::default();
        for ai in 0..=20 {
            for qi in 0..=20 {
                let rew = r.reward(ai as f64 / 20.0, qi as f64 / 20.0);
                assert!((-1.0..=1.0).contains(&rew));
            }
        }
    }
}
