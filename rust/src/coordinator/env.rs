//! The quantization environment (paper §3, Fig 4): owns the pretrained
//! network, steps through its layers, applies the agent's bitwidth choices,
//! short-retrains the quantized network via the AOT train artifact, and
//! evaluates validation accuracy via the eval artifact.
//!
//! The paper works around retraining cost by rewarding with "an estimated
//! validation accuracy after retraining for a shortened amount of epochs";
//! here that is `retrain_steps` SGD steps from the pretrained snapshot, plus
//! an accuracy memo-cache keyed by the bitwidth vector (identical bitwidth
//! patterns recur constantly as the policy converges, so the cache removes
//! most PJRT executions late in the search — see EXPERIMENTS.md §Perf).
//!
//! # Shared core
//!
//! All post-pretrain state lives in an immutable [`EnvCore`]; [`QuantEnv`]
//! is a cheaply cloneable `Arc` handle onto it. `accuracy`/`state_acc` work
//! from `&self`, counters are atomics, and the accuracy memo is a
//! single-flight [`AccMemo`] — so one pretrained env is shared by every
//! shard of `pareto::enumerate_sharded`, every replica of
//! `coordinator::run_replicas`, and every lane of the lockstep batched
//! rollout, paying the data-generation + pretraining bring-up **once**
//! instead of once per consumer.
//!
//! # Megabatch accuracy evaluation
//!
//! [`EnvCore::accuracy_batch`] scores up to `eval_batch_k` candidate bits
//! vectors with **one** PJRT execution of the vmapped
//! `<net>_retrain_eval_batch` artifact (per-lane bits + cursor uploaded as
//! one staged literal, all large operands shared and device-resident).
//! Batches flow through [`AccMemo::get_or_compute_batch`]: cache hits and
//! another thread's in-flight keys shrink the batch, a short final chunk
//! pads by repeating the last candidate (pad lanes are discarded and
//! counted in `EnvStats::pad_lanes`), and a lone miss takes the scalar
//! fused path — so a step with `m` uncached candidates costs exactly
//! `ceil(m / K)` retrain_eval-family executions (`rust/tests/
//! eval_batch_parity.rs`).
//!
//! # Determinism
//!
//! Accuracy queries derive their retrain start-batch from the queried bits
//! vector itself (`bits_cursor`, an FNV-1a hash) instead of a shared mutable
//! cursor. That makes `accuracy(bits)` a pure function of the core: the
//! memoized value for a vector is identical no matter which shard, lane, or
//! schedule computed it, so sharded enumeration and batched search are
//! bit-reproducible at any concurrency (EXPERIMENTS.md §Determinism). The
//! batch artifact preserves this: each lane is `jax.vmap` of exactly the
//! scalar fused function and lanes never interact, so a value computed as
//! lane `i` of a K-batch is bit-identical to the scalar path's — pinned by
//! `python/tests/test_aot.py` (numeric lane parity) and
//! `rust/tests/eval_batch_parity.rs` (compiled-artifact parity at any K,
//! including pad lanes).
//!
//! # Device striping
//!
//! When the engine's pool holds more than one device, `compute_misses`
//! stripes megabatch chunks across it: chunk `i` always runs on device
//! `i % N` (a pure function of the miss list, not of pool load), each
//! device lazily builds its own replica of the fused residency ([`DevRes`])
//! on the first chunk placed there, and results merge back in chunk order.
//! Because accuracy is a pure function of the bits vector, striping — like
//! batching — is purely a throughput lever: values are bit-identical at any
//! device count, and a 1-device pool takes the exact pre-pool serial path
//! (`rust/tests/device_pool_parity.rs`). Threads pinned to a device
//! (`run_replicas`, Pareto shards) keep all their chunks on their own
//! device instead.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{Context, Result};
use xla::Literal;

use crate::data::{self, Split};
use crate::parallel::{self, AccMemo, SpecLedger};
use crate::quant::CostModel;
use crate::runtime::{
    lit_f32, lit_scalar, to_f32, to_vec_f32, DeviceBuf, Engine, Exe, HostLit, NetworkMeta, Stage,
};

#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// SGD steps of full-precision pretraining
    pub pretrain_steps: usize,
    /// quantized short-retrain steps per accuracy evaluation
    pub retrain_steps: usize,
    /// final long-retrain steps on the converged solution
    pub long_retrain_steps: usize,
    pub lr: f32,
    pub train_size: usize,
    pub seed: u64,
    /// bound on finished accuracy-memo entries (0 = unbounded). The default
    /// is far above what a one-shot search touches; it exists so a
    /// long-running `releq serve` session cannot grow without limit
    /// (coarse-LRU eviction, see [`AccMemo`]).
    pub memo_cap: usize,
    /// candidate lanes per batched accuracy execution: 0 = the artifact's
    /// baked width (`eval_batch_k`), 1 = disable batching (scalar fused
    /// path only), 2..=K = narrower effective batches (the K-sweep knob —
    /// narrower batches still pad to the artifact's fixed shape, so this
    /// trades pad-lane compute for scheduling granularity; `bench_env`).
    /// Purely a performance knob: accuracy values are identical at any
    /// setting, so it is excluded from the serve env fingerprint like
    /// `memo_cap`.
    pub eval_batch: usize,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            pretrain_steps: 300,
            retrain_steps: 4,
            long_retrain_steps: 120,
            lr: 0.01,
            train_size: 2048,
            seed: 17,
            memo_cap: 65_536,
            eval_batch: 0,
        }
    }
}

/// Counters the environment accumulates (perf + cache instrumentation).
/// A point-in-time snapshot of the core's atomic counters — see
/// [`EnvCore::stats`]. The `memo_*` fields mirror the shared [`AccMemo`]'s
/// own counters so one snapshot carries everything `/v1/stats` reports.
#[derive(Debug, Default, Clone, Copy)]
pub struct EnvStats {
    pub evals: u64,
    pub cache_hits: u64,
    pub train_execs: u64,
    pub eval_execs: u64,
    /// executions of the vmapped `<net>_retrain_eval_batch` artifact (each
    /// replaces up to `eval_batch_k` scalar retrain_eval executions — the
    /// batch-amortization mirror of `act_batch_calls`)
    pub eval_batch_execs: u64,
    /// real (non-pad) candidate lanes scored by those executions;
    /// `batched_candidates / eval_batch_execs` is the realized batch width
    pub batched_candidates: u64,
    /// pad lanes executed and discarded (short final chunks repeat their
    /// last candidate to fill the artifact's fixed K)
    pub pad_lanes: u64,
    /// finished entries currently resident in the accuracy memo
    pub memo_len: usize,
    /// memo-global hit/miss/eviction counters (shared by every env clone)
    pub memo_hits: u64,
    pub memo_misses: u64,
    pub memo_evictions: u64,
    /// candidate vectors the pipelined driver prefetched speculatively
    /// (memo-warming only; see [`SpecLedger`]). Always
    /// `spec_hits <= spec_submitted`; after a search has finished,
    /// `spec_hits + spec_wasted == spec_submitted`.
    pub spec_submitted: u64,
    /// speculated vectors a rollout step subsequently evaluated for real
    pub spec_hits: u64,
    /// speculated vectors no consumer ever asked for
    pub spec_wasted: u64,
}

/// Atomic backing store for [`EnvStats`]: the counters are bumped from
/// `&self` on the concurrent hot paths.
#[derive(Debug, Default)]
struct EnvStatsAtomic {
    evals: AtomicU64,
    cache_hits: AtomicU64,
    train_execs: AtomicU64,
    eval_execs: AtomicU64,
    eval_batch_execs: AtomicU64,
    batched_candidates: AtomicU64,
    pad_lanes: AtomicU64,
}

impl EnvStatsAtomic {
    fn snapshot(&self) -> EnvStats {
        EnvStats {
            evals: self.evals.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            train_execs: self.train_execs.load(Ordering::Relaxed),
            eval_execs: self.eval_execs.load(Ordering::Relaxed),
            eval_batch_execs: self.eval_batch_execs.load(Ordering::Relaxed),
            batched_candidates: self.batched_candidates.load(Ordering::Relaxed),
            pad_lanes: self.pad_lanes.load(Ordering::Relaxed),
            ..EnvStats::default()
        }
    }
}

/// A cheaply cloneable handle onto a shared, immutable [`EnvCore`]. Clones
/// share the pretrained snapshot, device buffers, memo-cache and counters.
#[derive(Clone)]
pub struct QuantEnv {
    core: Arc<EnvCore>,
}

impl std::ops::Deref for QuantEnv {
    type Target = EnvCore;

    fn deref(&self) -> &EnvCore {
        &self.core
    }
}

/// The immutable post-pretrain environment state. `Send + Sync`: every
/// method on the query path takes `&self`; the only mutation is through
/// atomics and the concurrent memo.
pub struct EnvCore {
    pub net: NetworkMeta,
    pub cost: CostModel,
    pub cfg: EnvConfig,
    engine: Arc<Engine>,
    train_exe: Arc<Exe>,
    eval_exe: Arc<Exe>,
    /// fused retrain(k)+eval artifact — the accuracy-query hot path for
    /// shallow networks (None where the per-step path is faster)
    fused_exe: Option<Arc<Exe>>,
    /// vmapped K-lane retrain(k)+eval artifact — the megabatch evaluator
    /// (rides the fused family: present iff `net.eval_batch_k > 0`)
    batch_exe: Option<Arc<Exe>>,
    train: Split,
    /// pretrained full-precision snapshot (the search always retrains from it)
    pub pretrained: Vec<f32>,
    /// full-precision validation accuracy (Acc_FullP)
    pub acc_fullp: f64,
    /// protocol-matched State_A denominator: max(Acc_FullP, accuracy of the
    /// uniform-bits_max assignment under the same short-retrain protocol).
    /// With only a few retrain steps, even 8-bit networks sit slightly below
    /// Acc_FullP; normalizing by the protocol ceiling keeps State_A ~ 1.0
    /// reachable so the asymmetric reward's accuracy term does not drown the
    /// quantization signal in evaluation noise (EXPERIMENTS.md, deviations).
    pub acc_ref: f64,
    /// bits-vector -> validation accuracy; single-flight, shared by every
    /// clone of the env handle
    memo: Arc<AccMemo>,
    /// speculative-prefetch bookkeeping (pipelined driver; shared by every
    /// clone like the memo — counters surface through [`EnvStats`])
    spec: SpecLedger,
    stats: EnvStatsAtomic,
    /// fp-bits sentinel from the manifest (>= this disables quantization)
    fp_bits: f32,
    pub bits_max: u32,
    // prebuilt literals for the fixed validation set (unfused path); shared
    // read-only across threads
    val_x_lit: HostLit,
    val_y_lit: HostLit,
    // device-resident operands for the fused hot path (uploaded once;
    // EXPERIMENTS.md §Perf): snapshot params, zero momentum, the whole
    // training set, the validation set, and the learning rate.
    fused_bufs: Option<FusedBuffers>,
    /// retained validation split: devices > 0 rebuild their resident
    /// operand replicas from this host data on first use
    val: Split,
    /// per-device replicas of the fused hot path (executables + resident
    /// operands), built lazily by [`EnvCore::dev_res`]. Device 0 is NOT in
    /// this map — it lives in the plain fields above, untouched, which is
    /// what keeps `--devices 1` byte-identical to the pre-pool env.
    replicas: RwLock<HashMap<usize, Arc<DevRes>>>,
    /// reusable host staging for the per-execution batch operands (the
    /// K×L bits matrix and K cursors) — see [`Stage`]
    stage: Mutex<Stage>,
}

struct FusedBuffers {
    params: DeviceBuf,
    mom: DeviceBuf,
    train_x: DeviceBuf,
    train_y: DeviceBuf,
    val_x: DeviceBuf,
    val_y: DeviceBuf,
    lr: DeviceBuf,
}

/// Device-`d` replica of the fused accuracy path (`d > 0`): the fused and
/// batch executables compiled for that device plus the resident operand set
/// uploaded to it. Built on the first megabatch chunk striped to the device
/// and cached for the env's lifetime.
struct DevRes {
    fused_exe: Arc<Exe>,
    batch_exe: Option<Arc<Exe>>,
    bufs: FusedBuffers,
}

impl QuantEnv {
    /// Build the environment: generate synthetic data, pretrain the network
    /// in full precision, snapshot the weights, record Acc_FullP.
    pub fn new(engine: Arc<Engine>, net: &NetworkMeta, bits_max: u32, fp_bits: f32,
               cfg: EnvConfig) -> Result<QuantEnv> {
        let [h, _, _] = net.input;
        let (train, val) =
            data::train_val(&net.dataset, cfg.seed, cfg.train_size, net.eval_batch, h,
                            net.classes);
        Self::with_data(engine, net, bits_max, fp_bits, cfg, train, val)
    }

    pub fn with_data(engine: Arc<Engine>, net: &NetworkMeta, bits_max: u32, fp_bits: f32,
                     cfg: EnvConfig, train: Split, val: Split) -> Result<QuantEnv> {
        let train_exe = engine.exe(&format!("{}_train", net.name))?;
        let eval_exe = engine.exe(&format!("{}_eval", net.name))?;
        // fused artifact exists only where it wins (manifest fused_k > 0)
        let fused_exe = if net.fused_k > 0 {
            Some(engine.exe(&format!("{}_retrain_eval", net.name))?)
        } else {
            None
        };
        // the megabatch evaluator rides the fused family; eval_batch_k = 0
        // (no artifact, or a manifest predating it) degrades to the scalar
        // paths without demanding a missing file
        let batch_exe = if net.eval_batch_k > 0 {
            Some(engine.exe(&format!("{}_retrain_eval_batch", net.name))?)
        } else {
            None
        };
        let init_exe = engine.exe(&format!("{}_init", net.name))?;

        anyhow::ensure!(
            val.n == net.eval_batch,
            "val split ({}) must match the eval artifact's batch ({})",
            val.n,
            net.eval_batch
        );
        let val_x_lit = HostLit::new(lit_f32(
            &val.images,
            &[net.eval_batch as i64, val.h as i64, val.w as i64, val.c as i64],
        )?);
        let val_y_lit = HostLit::new(lit_f32(&val.labels, &[net.eval_batch as i64])?);

        let out = init_exe.run(&[lit_scalar(cfg.seed as f32)])?;
        let params = to_vec_f32(&out[0])?;
        anyhow::ensure!(params.len() == net.p, "init params {} != P {}", params.len(), net.p);

        let memo_cap = cfg.memo_cap;
        // the core is mutable only here, before it is wrapped in the Arc
        let mut core = EnvCore {
            net: net.clone(),
            cost: CostModel::new(net, bits_max),
            cfg,
            engine,
            train_exe,
            eval_exe,
            fused_exe,
            batch_exe,
            train,
            pretrained: params,
            acc_fullp: 0.0,
            acc_ref: 0.0,
            memo: Arc::new(AccMemo::with_capacity(memo_cap)),
            spec: SpecLedger::new(),
            stats: EnvStatsAtomic::default(),
            fp_bits,
            bits_max,
            val_x_lit,
            val_y_lit,
            fused_bufs: None,
            val,
            replicas: RwLock::new(HashMap::new()),
            stage: Mutex::new(Stage::new()),
        };
        core.pretrain()?;
        core.upload_fused_operands()?;
        let base = core.accuracy(&vec![bits_max; core.net.l])?;
        core.acc_ref = core.acc_fullp.max(base);
        Ok(QuantEnv { core: Arc::new(core) })
    }

}

impl EnvCore {
    /// The execution engine backing this env (shared by all handle clones).
    /// Drivers use it to reach the engine's health flag and retry counters
    /// when wiring watchdogs around dispatched accuracy queries.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// The memo-cache this env reads/writes (shared by all handle clones).
    pub fn memo(&self) -> &Arc<AccMemo> {
        &self.memo
    }

    /// The speculative-prefetch ledger (shared by all handle clones).
    pub fn spec(&self) -> &SpecLedger {
        &self.spec
    }

    /// Snapshot of the perf/cache counters (shared across all clones),
    /// merged with the accuracy memo's occupancy and hit/miss/eviction
    /// counters and the speculation ledger's accounting.
    pub fn stats(&self) -> EnvStats {
        let mut s = self.stats.snapshot();
        s.memo_len = self.memo.len();
        s.memo_hits = self.memo.hits();
        s.memo_misses = self.memo.misses();
        s.memo_evictions = self.memo.evictions();
        s.spec_submitted = self.spec.submitted();
        s.spec_hits = self.spec.hits();
        s.spec_wasted = self.spec.wasted();
        s
    }

    fn bits_literal(&self, bits: &[u32]) -> Result<Literal> {
        let v: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
        lit_f32(&v, &[self.net.l as i64])
    }

    fn n_batches(&self) -> usize {
        (self.train.n / self.net.train_batch).max(1)
    }

    /// Deterministic retrain start-batch for a bitwidth vector (word-wise
    /// FNV-1a over the bits — `util::fnv`, bit-identical to the inline
    /// loop this shipped with). See the module docs: deriving the cursor
    /// from the query instead of shared mutable state is what makes
    /// `accuracy` pure and every concurrent driver bit-reproducible.
    fn bits_cursor(&self, bits: &[u32]) -> usize {
        let h = crate::util::fnv::Fnv::new().write_u32_words(bits).finish();
        (h % self.n_batches() as u64) as usize
    }

    /// Full-precision pretraining (bits = FP sentinel), establishing the
    /// Acc_FullP reference and the snapshot every evaluation retrains from.
    /// Runs before the core is shared; the step index doubles as the
    /// sequential train-batch cursor (post-pretrain accuracy queries use
    /// the bits-derived `bits_cursor` instead, so the shared core holds no
    /// mutable cursor at all).
    fn pretrain(&mut self) -> Result<()> {
        let fp = vec![self.fp_bits as u32; self.net.l];
        let bits_lit = self.bits_literal(&fp)?;
        let mut params = std::mem::take(&mut self.pretrained);
        let mut mom = vec![0.0f32; self.net.p];
        for step in 0..self.cfg.pretrain_steps {
            let (p2, m2, _, _) = self.train_once(&params, &mom, &bits_lit, step)?;
            params = p2;
            mom = m2;
        }
        self.pretrained = params;
        self.acc_fullp = self.eval_with(&self.pretrained, &fp)?;
        Ok(())
    }

    fn train_once(&self, params: &[f32], mom: &[f32], bits_lit: &Literal, cursor: usize)
                  -> Result<(Vec<f32>, Vec<f32>, f32, f32)> {
        let b = self.net.train_batch;
        let [h, w, c] = self.net.input;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        self.train.fill_batch(cursor, b, &mut xs, &mut ys);
        let params_lit = lit_f32(params, &[self.net.p as i64])?;
        let mom_lit = lit_f32(mom, &[self.net.p as i64])?;
        let x_lit = lit_f32(&xs, &[b as i64, h as i64, w as i64, c as i64])?;
        let y_lit = lit_f32(&ys, &[b as i64])?;
        let lr_lit = lit_scalar(self.cfg.lr);
        let args = [&params_lit, &mom_lit, &x_lit, &y_lit, bits_lit, &lr_lit];
        let out = self.train_exe.run(&args).context("train step")?;
        self.stats.train_execs.fetch_add(1, Ordering::Relaxed);
        Ok((
            to_vec_f32(&out[0])?,
            to_vec_f32(&out[1])?,
            to_f32(&out[2])?,
            to_f32(&out[3])?,
        ))
    }

    fn eval_with(&self, params: &[f32], bits: &[u32]) -> Result<f64> {
        let params_lit = lit_f32(params, &[self.net.p as i64])?;
        let bits_lit = self.bits_literal(bits)?;
        let args = [&params_lit, self.val_x_lit.raw(), self.val_y_lit.raw(), &bits_lit];
        let out = self.eval_exe.run(&args).context("eval")?;
        self.stats.eval_execs.fetch_add(1, Ordering::Relaxed);
        let ncorrect = to_f32(&out[1])? as f64;
        Ok(ncorrect / self.net.eval_batch as f64)
    }

    /// Upload the persistent operands of the fused artifact (called once
    /// after pretraining; the snapshot never changes during a search).
    fn upload_fused_operands(&mut self) -> Result<()> {
        if self.fused_exe.is_none() || self.train.n != self.net.train_size {
            // training split doesn't match the AOT-baked resident set; the
            // unfused fallback still works, so just skip the fast path.
            self.fused_bufs = None;
            return Ok(());
        }
        self.fused_bufs = Some(self.build_fused_bufs(0)?);
        Ok(())
    }

    /// Upload the fused-path resident operand set to pool device `dev` from
    /// the retained host data — device 0 at bring-up, devices > 0 lazily on
    /// their first striped chunk. The upload order matches the original
    /// single-device bring-up exactly.
    fn build_fused_bufs(&self, dev: usize) -> Result<FusedBuffers> {
        let [h, w, c] = self.net.input;
        let e = &self.engine;
        Ok(FusedBuffers {
            params: e.buffer_f32_on(&self.pretrained, &[self.net.p], dev)?,
            mom: e.buffer_f32_on(&vec![0.0; self.net.p], &[self.net.p], dev)?,
            train_x: e.buffer_f32_on(&self.train.images, &[self.train.n, h, w, c], dev)?,
            train_y: e.buffer_f32_on(&self.train.labels, &[self.train.n], dev)?,
            val_x: e.buffer_f32_on(&self.val.images, &[self.net.eval_batch, h, w, c], dev)?,
            val_y: e.buffer_f32_on(&self.val.labels, &[self.net.eval_batch], dev)?,
            lr: e.buffer_scalar_on(self.cfg.lr, dev)?,
        })
    }

    /// Fetch (building on first use) the device-`dev` replica of the fused
    /// accuracy path. Only for `dev > 0` — device 0's residency is the env
    /// core's own fields. Requires the fused path to be live (striped
    /// callers guarantee it: chunks only fan out when
    /// `eval_batch_width() > 1`).
    fn dev_res(&self, dev: usize) -> Result<Arc<DevRes>> {
        anyhow::ensure!(dev > 0, "device 0 residency lives in the env core fields");
        if let Some(r) = self.replicas.read().unwrap().get(&dev) {
            return Ok(r.clone());
        }
        anyhow::ensure!(
            self.fused_bufs.is_some(),
            "per-device residency requires the fused path (resident training set)"
        );
        // build outside the lock (compilation + uploads are slow); a racing
        // thread may build the same replica — the first insert wins, same
        // protocol as the engine's compile cache
        let fused_exe = self.engine.exe_on(&format!("{}_retrain_eval", self.net.name), dev)?;
        let batch_exe = if self.net.eval_batch_k > 0 {
            Some(self.engine.exe_on(&format!("{}_retrain_eval_batch", self.net.name), dev)?)
        } else {
            None
        };
        let bufs = self.build_fused_bufs(dev)?;
        let res = Arc::new(DevRes { fused_exe, batch_exe, bufs });
        Ok(self.replicas.write().unwrap().entry(dev).or_insert(res).clone())
    }

    /// Fused accuracy query: one PJRT execution covering the k-step quantized
    /// retrain and the validation eval, with all large operands resident on
    /// the device. Per query only the bits vector, cursor and lr transfer.
    /// Runs on pool device `dev` (device 0 uses the core's own residency;
    /// devices > 0 use their lazily built replica).
    fn accuracy_fused_on(&self, bits: &[u32], cursor: usize, dev: usize) -> Result<Option<f64>> {
        if self.cfg.retrain_steps != self.net.fused_k {
            return Ok(None);
        }
        if self.fused_bufs.is_none() || self.fused_exe.is_none() {
            return Ok(None);
        }
        let res; // keeps the dev > 0 replica alive across the execution
        let (bufs, fused_exe): (&FusedBuffers, Arc<Exe>) = if dev == 0 {
            (
                self.fused_bufs.as_ref().expect("checked above"),
                self.fused_exe.clone().expect("checked above"),
            )
        } else {
            res = self.dev_res(dev)?;
            (&res.bufs, res.fused_exe.clone())
        };
        let bits_v: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
        let e = &self.engine;
        let cursor_buf = e.buffer_scalar_on(cursor as f32, dev)?;
        let bits_buf = e.buffer_f32_on(&bits_v, &[self.net.l], dev)?;
        let args = [
            bufs.params.raw(),
            bufs.mom.raw(),
            bufs.train_x.raw(),
            bufs.train_y.raw(),
            cursor_buf.raw(),
            bits_buf.raw(),
            bufs.lr.raw(),
            bufs.val_x.raw(),
            bufs.val_y.raw(),
        ];
        let out = fused_exe.run_b(&args).context("fused retrain_eval")?;
        self.stats.train_execs.fetch_add(self.net.fused_k as u64, Ordering::Relaxed);
        self.stats.eval_execs.fetch_add(1, Ordering::Relaxed);
        let ncorrect = to_f32(&out[1])? as f64;
        Ok(Some(ncorrect / self.net.eval_batch as f64))
    }

    /// Raw single-candidate accuracy compute — no memo interaction, so it
    /// is safe to call under a claimed in-flight key (the batch leader's
    /// fallback and the scalar miss path both land here). Fused when
    /// available, per-step literals otherwise.
    fn compute_one(&self, bits: &[u32]) -> Result<f64> {
        self.compute_one_on(bits, 0)
    }

    /// [`EnvCore::compute_one`] on pool device `dev`. The unfused fallback
    /// stays on device 0 (per-step literal path); striped callers only pick
    /// `dev > 0` when the fused path is live, so it never triggers there.
    fn compute_one_on(&self, bits: &[u32], dev: usize) -> Result<f64> {
        match self.accuracy_fused_on(bits, self.bits_cursor(bits), dev)? {
            Some(acc) => Ok(acc),
            None => self.retrain_and_eval(bits, self.cfg.retrain_steps),
        }
    }

    /// Width of one batched accuracy execution on this env: the artifact's
    /// baked lane count, optionally narrowed by the `eval_batch` config
    /// knob (0 = artifact width, 1 = batching disabled). 1 whenever the
    /// batch artifact is unavailable or the fused preconditions (resident
    /// training set, `retrain_steps == fused_k`) don't hold — callers can
    /// treat "width 1" as "this env evaluates serially".
    pub fn eval_batch_width(&self) -> usize {
        self.eval_batch_width_for(self.cfg.eval_batch)
    }

    /// Resolve an `eval_batch` knob value against THIS env's artifact and
    /// fused preconditions — what [`EnvCore::eval_batch_width`] would be if
    /// the env had been built with that knob. The serve session layer uses
    /// it to tell a genuinely differing request apart from one that
    /// resolves to the session's effective width anyway.
    pub fn eval_batch_width_for(&self, eval_batch: usize) -> usize {
        if self.batch_exe.is_none()
            || self.fused_bufs.is_none()
            || self.cfg.retrain_steps != self.net.fused_k
        {
            return 1;
        }
        match eval_batch {
            0 => self.net.eval_batch_k,
            n => n.min(self.net.eval_batch_k),
        }
    }

    /// One execution of the vmapped batch artifact over `chunk` (1..=K real
    /// candidates). Short chunks pad by repeating the last candidate; pad
    /// lanes run on the device but their outputs are discarded here and
    /// they count into `pad_lanes`, not into `train_execs`/`eval_execs`
    /// (those track *accuracy work*, one fused_k-step retrain + one eval
    /// per real lane — the same accounting as the scalar paths, so the
    /// exec-count invariants in `rollout_parity.rs` hold verbatim under
    /// batching). Runs on pool device `dev`: the megabatch chunk executes
    /// against that device's residency replica, staging its per-execution
    /// operands to the same device.
    fn accuracy_lanes_on(&self, chunk: &[Vec<u32>], dev: usize) -> Result<Vec<f64>> {
        let k = self.net.eval_batch_k;
        let l = self.net.l;
        anyhow::ensure!(
            !chunk.is_empty() && chunk.len() <= k,
            "batch chunk of {} exceeds the artifact's {k} lanes",
            chunk.len()
        );
        let res; // keeps the dev > 0 replica alive across the execution
        let (bufs, exe): (&FusedBuffers, Arc<Exe>) = if dev == 0 {
            (
                self.fused_bufs.as_ref().expect("eval_batch_width checked"),
                self.batch_exe.clone().expect("eval_batch_width checked"),
            )
        } else {
            res = self.dev_res(dev)?;
            let batch = res.batch_exe.clone().expect("eval_batch_width checked");
            (&res.bufs, batch)
        };
        let pads = k - chunk.len();
        let last = chunk.last().expect("non-empty");
        let e = &self.engine;
        // stage bits [K, L] then cursors [K] through the reusable buffer
        // (one upload each; the cursor is bits-derived per lane, so pad
        // lanes recompute their repeated candidate — and must produce the
        // identical value, though it is discarded anyway). try_lock: the
        // common single-driver case reuses the allocation across thousands
        // of executions; concurrent callers (racing shards, serve jobs)
        // fall back to a fresh local stage instead of serializing their
        // uploads on the mutex.
        let mut local = Stage::new();
        let mut guard = self.stage.try_lock();
        let stage: &mut Stage = match guard {
            Ok(ref mut g) => g,
            Err(_) => &mut local,
        };
        let (bits_buf, cursor_buf) = {
            let buf = stage.start();
            for bits in chunk.iter().chain(std::iter::repeat(last).take(pads)) {
                buf.extend(bits.iter().map(|&b| b as f32));
            }
            let bits_buf = stage.upload_on(e, &[k, l], dev)?;
            let buf = stage.start();
            for bits in chunk.iter().chain(std::iter::repeat(last).take(pads)) {
                buf.push(self.bits_cursor(bits) as f32);
            }
            (bits_buf, stage.upload_on(e, &[k], dev)?)
        };
        let args = [
            bufs.params.raw(),
            bufs.mom.raw(),
            bufs.train_x.raw(),
            bufs.train_y.raw(),
            cursor_buf.raw(),
            bits_buf.raw(),
            bufs.lr.raw(),
            bufs.val_x.raw(),
            bufs.val_y.raw(),
        ];
        let out = exe.run_b(&args).context("batched retrain_eval")?;
        let ncorrect = to_vec_f32(&out[1])?;
        anyhow::ensure!(
            ncorrect.len() == k,
            "batch artifact returned {} lanes, expected {k}",
            ncorrect.len()
        );
        let real = chunk.len() as u64;
        self.stats.eval_batch_execs.fetch_add(1, Ordering::Relaxed);
        self.stats.batched_candidates.fetch_add(real, Ordering::Relaxed);
        self.stats.pad_lanes.fetch_add(pads as u64, Ordering::Relaxed);
        self.stats.train_execs.fetch_add(self.net.fused_k as u64 * real, Ordering::Relaxed);
        self.stats.eval_execs.fetch_add(real, Ordering::Relaxed);
        Ok(ncorrect[..chunk.len()]
            .iter()
            .map(|&n| n as f64 / self.net.eval_batch as f64)
            .collect())
    }

    /// Compute accuracies for a batch of claimed misses (the
    /// `get_or_compute_batch` leader body — keys are already in flight, so
    /// everything below stays off the memo). Batch-capable envs chunk the
    /// misses at `eval_batch_width()` — a lone remainder takes the scalar
    /// fused path (one execution either way, without K-1 pad lanes of
    /// compute), so `m` misses cost exactly `ceil(m / width)`
    /// retrain_eval-family executions *regardless of device count*. Envs
    /// without the artifact keep the pre-megabatch behavior: misses fan out
    /// across shard threads.
    ///
    /// Device placement: on a multi-device pool, an unpinned caller stripes
    /// the chunks — chunk `i` on device `i % N`, one lane thread per device,
    /// merged back in chunk order (deterministic at any pool size). A
    /// pinned caller (replica / Pareto shard) keeps every chunk on its own
    /// device; a 1-device pool is the pre-pool serial loop, byte for byte.
    fn compute_misses(&self, misses: &[Vec<u32>]) -> Result<Vec<f64>> {
        let width = self.eval_batch_width();
        if width > 1 {
            let n_dev = self.engine.n_devices();
            let pin = crate::runtime::thread_pin();
            if n_dev > 1 && pin.is_none() && misses.len() > width {
                let chunks: Vec<Vec<Vec<u32>>> =
                    misses.chunks(width).map(|c| c.to_vec()).collect();
                let lanes = parallel::stripe_evenly(chunks, n_dev);
                let per = parallel::run_sharded(lanes, |_, lane| {
                    lane.into_iter()
                        .map(|(i, chunk)| {
                            let dev = self.engine.place_chunk(i);
                            let vals = if chunk.len() == 1 {
                                vec![self.compute_one_on(&chunk[0], dev)?]
                            } else {
                                self.accuracy_lanes_on(&chunk, dev)?
                            };
                            Ok((i, vals))
                        })
                        .collect::<Result<Vec<(usize, Vec<f64>)>>>()
                })?;
                let mut indexed: Vec<(usize, Vec<f64>)> = per.into_iter().flatten().collect();
                indexed.sort_by_key(|&(i, _)| i);
                return Ok(indexed.into_iter().flat_map(|(_, v)| v).collect());
            }
            let dev = pin.filter(|&d| d < n_dev).unwrap_or(0);
            let mut out = Vec::with_capacity(misses.len());
            for chunk in misses.chunks(width) {
                if chunk.len() == 1 {
                    out.push(self.compute_one_on(&chunk[0], dev)?);
                } else {
                    out.extend(self.accuracy_lanes_on(chunk, dev)?);
                }
            }
            return Ok(out);
        }
        if misses.len() > 1 {
            let shards = parallel::default_shards(misses.len());
            let chunks = parallel::chunk_evenly(misses.to_vec(), shards);
            let per = parallel::run_sharded(chunks, |_, chunk| {
                chunk.iter().map(|b| self.compute_one(b)).collect::<Result<Vec<f64>>>()
            })?;
            return Ok(per.into_iter().flatten().collect());
        }
        misses.iter().map(|b| self.compute_one(b)).collect()
    }

    /// Validation accuracy for a bitwidth assignment after a short quantized
    /// retrain from the pretrained snapshot. Memoized and **single-flight**:
    /// concurrent callers for the same uncached vector coalesce onto one
    /// PJRT evaluation. Takes the fused single-execution path when
    /// available.
    pub fn accuracy(&self, bits: &[u32]) -> Result<f64> {
        self.stats.evals.fetch_add(1, Ordering::Relaxed);
        let (acc, cached) = self.memo.get_or_compute(bits, || self.compute_one(bits))?;
        if cached {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(acc)
    }

    /// Validation accuracies for a whole slate of candidate bitwidth
    /// vectors — the megabatch accuracy evaluator. Cache hits and keys
    /// another thread already has in flight shrink the batch ([`AccMemo::
    /// get_or_compute_batch`]); the remaining misses run `ceil(m / K)`
    /// device executions via the vmapped `<net>_retrain_eval_batch`
    /// artifact (one staged upload of K bits vectors + cursors per
    /// execution, pad lanes discarded). Values are bit-identical to
    /// [`EnvCore::accuracy`] on the same vectors (see the module docs), so
    /// batching is purely a throughput lever.
    pub fn accuracy_batch(&self, cands: &[Vec<u32>]) -> Result<Vec<f64>> {
        if cands.is_empty() {
            return Ok(Vec::new());
        }
        self.stats.evals.fetch_add(cands.len() as u64, Ordering::Relaxed);
        let res = self.memo.get_or_compute_batch(cands, |misses| self.compute_misses(misses))?;
        let hits = res.iter().filter(|&&(_, cached)| cached).count() as u64;
        if hits > 0 {
            self.stats.cache_hits.fetch_add(hits, Ordering::Relaxed);
        }
        Ok(res.into_iter().map(|(v, _)| v).collect())
    }

    /// Force the unfused (step-by-step literal) path — used by the perf
    /// benches to measure the before/after of the fused optimization.
    ///
    /// Memoized like `accuracy` (PR 4): the old documented read+write
    /// bypass was tolerable when one driver owned the env, but with
    /// rollouts, Pareto shards and serve jobs all sharing one core, an
    /// unmemoized entry point meant concurrent identical queries silently
    /// duplicated PJRT work and never coalesced with in-flight leaders.
    /// Benches keep their timings honest by iterating over *distinct* bits
    /// vectors (disjoint key windows per case — see `bench_env`), so every
    /// timed iteration still misses and pays the real retrain+eval. The
    /// published value is valid for every other caller because the final
    /// accuracy is an argmax-match *count* divided by a constant, which the
    /// per-step and fused programs agree on exactly — pinned by
    /// `python/tests/test_aot.py::test_fused_retrain_eval_matches_per_step_path`
    /// (runs in CI) and by the artifact-gated
    /// `eval_batch_parity::unfused_path_matches_fused_bit_identical`, the
    /// tripwires for the memo-poisoning hazard the old bypass guarded
    /// against.
    pub fn accuracy_unfused(&self, bits: &[u32]) -> Result<f64> {
        self.stats.evals.fetch_add(1, Ordering::Relaxed);
        let (acc, cached) = self
            .memo
            .get_or_compute(bits, || self.retrain_and_eval(bits, self.cfg.retrain_steps))?;
        if cached {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(acc)
    }

    /// Quantized (re)training from the snapshot for `steps` SGD steps, then
    /// evaluate on the validation split. Used both for the per-step reward
    /// estimate (short) and the final long retrain of the converged solution.
    /// The start batch is bits-derived (see `bits_cursor`), so the result is
    /// a pure function of (bits, steps).
    pub fn retrain_and_eval(&self, bits: &[u32], steps: usize) -> Result<f64> {
        let bits_lit = self.bits_literal(bits)?;
        let start = self.bits_cursor(bits);
        let mut params = self.pretrained.clone();
        let mut mom = vec![0.0f32; self.net.p];
        for i in 0..steps {
            let (p2, m2, _, _) = self.train_once(&params, &mom, &bits_lit, start + i)?;
            params = p2;
            mom = m2;
        }
        self.eval_with(&params, bits)
    }

    /// State-of-Relative-Accuracy (paper §2.4): Acc_curr over the reference
    /// (see `acc_ref`).
    pub fn state_acc(&self, bits: &[u32]) -> Result<f64> {
        Ok(self.state_acc_of(self.accuracy(bits)?))
    }

    /// Normalize an already-obtained accuracy (e.g. one lane of an
    /// [`EnvCore::accuracy_batch`] result) into State_A without a second
    /// memo round-trip.
    pub fn state_acc_of(&self, acc: f64) -> f64 {
        acc / self.acc_ref.max(1e-9)
    }

    /// State-of-Quantization (paper §2.4).
    pub fn state_q(&self, bits: &[u32]) -> f64 {
        self.cost.state_q(bits)
    }

    pub fn cache_len(&self) -> usize {
        self.memo.len()
    }
}
