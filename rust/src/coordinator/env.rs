//! The quantization environment (paper §3, Fig 4): owns the pretrained
//! network, steps through its layers, applies the agent's bitwidth choices,
//! short-retrains the quantized network via the AOT train artifact, and
//! evaluates validation accuracy via the eval artifact.
//!
//! The paper works around retraining cost by rewarding with "an estimated
//! validation accuracy after retraining for a shortened amount of epochs";
//! here that is `retrain_steps` SGD steps from the pretrained snapshot, plus
//! an accuracy memo-cache keyed by the bitwidth vector (identical bitwidth
//! patterns recur constantly as the policy converges, so the cache removes
//! most PJRT executions late in the search — see EXPERIMENTS.md §Perf).
//!
//! The memo-cache is an [`AccMemo`] behind an `Arc`: a lone env owns a
//! private one, and the sharded drivers (`crate::parallel`) hand the same
//! instance to every shard so an assignment evaluated by one shard is a
//! cache hit for all the others.

use std::sync::Arc;

use anyhow::{Context, Result};
use xla::Literal;

use crate::data::{self, Split};
use crate::parallel::AccMemo;
use crate::quant::CostModel;
use crate::runtime::{lit_f32, lit_scalar, to_f32, to_vec_f32, DeviceBuf, Engine, Exe, NetworkMeta};

#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// SGD steps of full-precision pretraining
    pub pretrain_steps: usize,
    /// quantized short-retrain steps per accuracy evaluation
    pub retrain_steps: usize,
    /// final long-retrain steps on the converged solution
    pub long_retrain_steps: usize,
    pub lr: f32,
    pub train_size: usize,
    pub seed: u64,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            pretrain_steps: 300,
            retrain_steps: 4,
            long_retrain_steps: 120,
            lr: 0.01,
            train_size: 2048,
            seed: 17,
        }
    }
}

/// Counters the environment accumulates (perf + cache instrumentation).
#[derive(Debug, Default, Clone, Copy)]
pub struct EnvStats {
    pub evals: u64,
    pub cache_hits: u64,
    pub train_execs: u64,
    pub eval_execs: u64,
}

pub struct QuantEnv {
    pub net: NetworkMeta,
    pub cost: CostModel,
    pub cfg: EnvConfig,
    engine: Arc<Engine>,
    train_exe: Arc<Exe>,
    eval_exe: Arc<Exe>,
    /// fused retrain(k)+eval artifact — the accuracy-query hot path for
    /// shallow networks (None where the per-step path is faster)
    fused_exe: Option<Arc<Exe>>,
    train: Split,
    /// pretrained full-precision snapshot (the search always retrains from it)
    pub pretrained: Vec<f32>,
    /// full-precision validation accuracy (Acc_FullP)
    pub acc_fullp: f64,
    /// protocol-matched State_A denominator: max(Acc_FullP, accuracy of the
    /// uniform-bits_max assignment under the same short-retrain protocol).
    /// With only a few retrain steps, even 8-bit networks sit slightly below
    /// Acc_FullP; normalizing by the protocol ceiling keeps State_A ~ 1.0
    /// reachable so the asymmetric reward's accuracy term does not drown the
    /// quantization signal in evaluation noise (EXPERIMENTS.md, deviations).
    pub acc_ref: f64,
    /// bits-vector -> validation accuracy; private by default, shared across
    /// shards via [`QuantEnv::share_memo`]
    memo: Arc<AccMemo>,
    pub stats: EnvStats,
    /// fp-bits sentinel from the manifest (>= this disables quantization)
    fp_bits: f32,
    pub bits_max: u32,
    // prebuilt literals for the fixed validation set (unfused path)
    val_x_lit: Literal,
    val_y_lit: Literal,
    batch_cursor: usize,
    xs_buf: Vec<f32>,
    ys_buf: Vec<f32>,
    val_images_cache: Vec<f32>,
    val_labels_cache: Vec<f32>,
    // device-resident operands for the fused hot path (uploaded once;
    // EXPERIMENTS.md §Perf): snapshot params, zero momentum, the whole
    // training set, and the validation set.
    fused_bufs: Option<FusedBuffers>,
}

struct FusedBuffers {
    params: DeviceBuf,
    mom: DeviceBuf,
    train_x: DeviceBuf,
    train_y: DeviceBuf,
    val_x: DeviceBuf,
    val_y: DeviceBuf,
}

impl QuantEnv {
    /// Build the environment: generate synthetic data, pretrain the network
    /// in full precision, snapshot the weights, record Acc_FullP.
    pub fn new(engine: Arc<Engine>, net: &NetworkMeta, bits_max: u32, fp_bits: f32,
               cfg: EnvConfig) -> Result<QuantEnv> {
        let [h, _, _] = net.input;
        let (train, val) =
            data::train_val(&net.dataset, cfg.seed, cfg.train_size, net.eval_batch, h,
                            net.classes);
        Self::with_data(engine, net, bits_max, fp_bits, cfg, train, val)
    }

    pub fn with_data(engine: Arc<Engine>, net: &NetworkMeta, bits_max: u32, fp_bits: f32,
                     cfg: EnvConfig, train: Split, val: Split) -> Result<QuantEnv> {
        let train_exe = engine.exe(&format!("{}_train", net.name))?;
        let eval_exe = engine.exe(&format!("{}_eval", net.name))?;
        // fused artifact exists only where it wins (manifest fused_k > 0)
        let fused_exe = if net.fused_k > 0 {
            Some(engine.exe(&format!("{}_retrain_eval", net.name))?)
        } else {
            None
        };
        let init_exe = engine.exe(&format!("{}_init", net.name))?;

        anyhow::ensure!(
            val.n == net.eval_batch,
            "val split ({}) must match the eval artifact's batch ({})",
            val.n,
            net.eval_batch
        );
        let val_x_lit = lit_f32(
            &val.images,
            &[net.eval_batch as i64, val.h as i64, val.w as i64, val.c as i64],
        )?;
        let val_y_lit = lit_f32(&val.labels, &[net.eval_batch as i64])?;
        let val_images_cache = val.images.clone();
        let val_labels_cache = val.labels.clone();

        let out = init_exe.run(&[lit_scalar(cfg.seed as f32)])?;
        let params = to_vec_f32(&out[0])?;
        anyhow::ensure!(params.len() == net.p, "init params {} != P {}", params.len(), net.p);

        let mut env = QuantEnv {
            net: net.clone(),
            cost: CostModel::new(net, bits_max),
            cfg,
            engine,
            train_exe,
            eval_exe,
            fused_exe,
            train,
            pretrained: params,
            acc_fullp: 0.0,
            acc_ref: 0.0,
            memo: Arc::new(AccMemo::new()),
            stats: EnvStats::default(),
            fp_bits,
            bits_max,
            val_x_lit,
            val_y_lit,
            batch_cursor: 0,
            xs_buf: Vec::new(),
            ys_buf: Vec::new(),
            val_images_cache,
            val_labels_cache,
            fused_bufs: None,
        };
        env.pretrain()?;
        env.upload_fused_operands()?;
        let base = env.accuracy(&vec![bits_max; env.net.l])?;
        env.acc_ref = env.acc_fullp.max(base);
        Ok(env)
    }

    /// Switch this env onto a shared memo-cache (sharded drivers call this
    /// right after construction). Entries already memoized privately — e.g.
    /// the uniform-bits_max probe from bring-up — are carried over.
    pub fn share_memo(&mut self, memo: Arc<AccMemo>) {
        if !Arc::ptr_eq(&self.memo, &memo) {
            memo.extend(self.memo.entries());
            self.memo = memo;
        }
    }

    /// The memo-cache this env reads/writes (private unless shared).
    pub fn memo(&self) -> &Arc<AccMemo> {
        &self.memo
    }

    fn bits_literal(&self, bits: &[u32]) -> Result<Literal> {
        let v: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
        lit_f32(&v, &[self.net.l as i64])
    }

    /// Full-precision pretraining (bits = FP sentinel), establishing the
    /// Acc_FullP reference and the snapshot every evaluation retrains from.
    fn pretrain(&mut self) -> Result<()> {
        let fp = vec![self.fp_bits as u32; self.net.l];
        let bits_lit = self.bits_literal(&fp)?;
        let mut params = std::mem::take(&mut self.pretrained);
        let mut mom = vec![0.0f32; self.net.p];
        for _ in 0..self.cfg.pretrain_steps {
            let (p2, m2, _, _) = self.train_once(&params, &mom, &bits_lit)?;
            params = p2;
            mom = m2;
        }
        self.pretrained = params;
        self.acc_fullp = self.eval_with(&self.pretrained.clone(), &fp)?;
        Ok(())
    }

    fn train_once(&mut self, params: &[f32], mom: &[f32], bits_lit: &Literal)
                  -> Result<(Vec<f32>, Vec<f32>, f32, f32)> {
        let b = self.net.train_batch;
        let [h, w, c] = self.net.input;
        let cursor = self.batch_cursor;
        self.batch_cursor += 1;
        // split borrows: temporarily move the buffers out
        let mut xs = std::mem::take(&mut self.xs_buf);
        let mut ys = std::mem::take(&mut self.ys_buf);
        self.train.fill_batch(cursor, b, &mut xs, &mut ys);
        let params_lit = lit_f32(params, &[self.net.p as i64])?;
        let mom_lit = lit_f32(mom, &[self.net.p as i64])?;
        let x_lit = lit_f32(&xs, &[b as i64, h as i64, w as i64, c as i64])?;
        let y_lit = lit_f32(&ys, &[b as i64])?;
        let lr_lit = lit_scalar(self.cfg.lr);
        self.xs_buf = xs;
        self.ys_buf = ys;
        let args = [&params_lit, &mom_lit, &x_lit, &y_lit, bits_lit, &lr_lit];
        let out = self.train_exe.run(&args).context("train step")?;
        self.stats.train_execs += 1;
        Ok((
            to_vec_f32(&out[0])?,
            to_vec_f32(&out[1])?,
            to_f32(&out[2])?,
            to_f32(&out[3])?,
        ))
    }

    fn eval_with(&mut self, params: &[f32], bits: &[u32]) -> Result<f64> {
        let params_lit = lit_f32(params, &[self.net.p as i64])?;
        let bits_lit = self.bits_literal(bits)?;
        let args = [&params_lit, &self.val_x_lit, &self.val_y_lit, &bits_lit];
        let out = self.eval_exe.run(&args).context("eval")?;
        self.stats.eval_execs += 1;
        let ncorrect = to_f32(&out[1])? as f64;
        Ok(ncorrect / self.net.eval_batch as f64)
    }

    /// Upload the persistent operands of the fused artifact (called once
    /// after pretraining; the snapshot never changes during a search).
    fn upload_fused_operands(&mut self) -> Result<()> {
        if self.fused_exe.is_none() || self.train.n != self.net.train_size {
            // training split doesn't match the AOT-baked resident set; the
            // unfused fallback still works, so just skip the fast path.
            self.fused_bufs = None;
            return Ok(());
        }
        let [h, w, c] = self.net.input;
        let e = &self.engine;
        self.fused_bufs = Some(FusedBuffers {
            params: e.buffer_f32(&self.pretrained, &[self.net.p])?,
            mom: e.buffer_f32(&vec![0.0; self.net.p], &[self.net.p])?,
            train_x: e.buffer_f32(&self.train.images, &[self.train.n, h, w, c])?,
            train_y: e.buffer_f32(&self.train.labels, &[self.train.n])?,
            val_x: e.buffer_f32(
                &self.val_images_cache,
                &[self.net.eval_batch, h, w, c],
            )?,
            val_y: e.buffer_f32(&self.val_labels_cache, &[self.net.eval_batch])?,
        });
        Ok(())
    }

    /// Fused accuracy query: one PJRT execution covering the k-step quantized
    /// retrain and the validation eval, with all large operands resident on
    /// the device. Per query only the bits vector, cursor and lr transfer.
    fn accuracy_fused(&mut self, bits: &[u32]) -> Result<Option<f64>> {
        if self.cfg.retrain_steps != self.net.fused_k {
            return Ok(None);
        }
        let Some(bufs) = &self.fused_bufs else { return Ok(None) };
        let Some(fused_exe) = self.fused_exe.clone() else { return Ok(None) };
        let n_batches = self.train.n / self.net.train_batch;
        let cursor = (self.batch_cursor % n_batches) as f32;
        self.batch_cursor += self.net.fused_k;
        let bits_v: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
        let e = &self.engine;
        let cursor_buf = e.buffer_scalar(cursor)?;
        let bits_buf = e.buffer_f32(&bits_v, &[self.net.l])?;
        let lr_buf = e.buffer_scalar(self.cfg.lr)?;
        let args = [
            bufs.params.raw(),
            bufs.mom.raw(),
            bufs.train_x.raw(),
            bufs.train_y.raw(),
            cursor_buf.raw(),
            bits_buf.raw(),
            lr_buf.raw(),
            bufs.val_x.raw(),
            bufs.val_y.raw(),
        ];
        let out = fused_exe.run_b(&args).context("fused retrain_eval")?;
        self.stats.train_execs += self.net.fused_k as u64;
        self.stats.eval_execs += 1;
        let ncorrect = to_f32(&out[1])? as f64;
        Ok(Some(ncorrect / self.net.eval_batch as f64))
    }

    /// Validation accuracy for a bitwidth assignment after a short quantized
    /// retrain from the pretrained snapshot (memoized). Takes the fused
    /// single-execution path when available.
    pub fn accuracy(&mut self, bits: &[u32]) -> Result<f64> {
        self.stats.evals += 1;
        if let Some(acc) = self.memo.get(bits) {
            self.stats.cache_hits += 1;
            return Ok(acc);
        }
        let acc = match self.accuracy_fused(bits)? {
            Some(acc) => acc,
            None => self.retrain_and_eval(bits, self.cfg.retrain_steps)?,
        };
        self.memo.insert(bits, acc);
        Ok(acc)
    }

    /// Force the unfused (step-by-step literal) path — used by the perf
    /// benches to measure the before/after of the fused optimization.
    ///
    /// Deliberately bypasses the memo-cache on both read and write: the bench
    /// must time the real retrain+eval every iteration, and a stale write
    /// would poison `accuracy()` callers whose fused path is live. It still
    /// counts as an eval in `EnvStats` so bench runs are not under-reported.
    pub fn accuracy_unfused(&mut self, bits: &[u32]) -> Result<f64> {
        self.stats.evals += 1;
        self.retrain_and_eval(bits, self.cfg.retrain_steps)
    }

    /// Quantized (re)training from the snapshot for `steps` SGD steps, then
    /// evaluate on the validation split. Used both for the per-step reward
    /// estimate (short) and the final long retrain of the converged solution.
    pub fn retrain_and_eval(&mut self, bits: &[u32], steps: usize) -> Result<f64> {
        let bits_lit = self.bits_literal(bits)?;
        let mut params = self.pretrained.clone();
        let mut mom = vec![0.0f32; self.net.p];
        for _ in 0..steps {
            let (p2, m2, _, _) = self.train_once(&params, &mom, &bits_lit)?;
            params = p2;
            mom = m2;
        }
        self.eval_with(&params, bits)
    }

    /// State-of-Relative-Accuracy (paper §2.4): Acc_curr over the reference
    /// (see `acc_ref`).
    pub fn state_acc(&mut self, bits: &[u32]) -> Result<f64> {
        Ok(self.accuracy(bits)? / self.acc_ref.max(1e-9))
    }

    /// State-of-Quantization (paper §2.4).
    pub fn state_q(&self, bits: &[u32]) -> f64 {
        self.cost.state_q(bits)
    }

    pub fn cache_len(&self) -> usize {
        self.memo.len()
    }
}
