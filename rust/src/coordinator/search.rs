//! The ReLeQ search loop (paper §3, Fig 4): episodes over layers, stochastic
//! bitwidth actions, reward at each step, PPO updates every B episodes, and
//! convergence detection — then a greedy rollout + long retrain produces the
//! final Table-2-style solution.
//!
//! Episodes roll out either serially (one agent `act` per layer per episode)
//! or in lockstep batches (`RolloutMode::Batched`, `coordinator::rollout`):
//! the whole PPO batch advances layer-by-layer with one `act_batch`
//! execution per layer. Action sampling draws from independent per-episode
//! PCG streams (`episode_rng`), so both modes sample identical actions for
//! episode `ep` under the same seed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{EpisodeLog, SearchLog};
use crate::parallel;
use crate::runtime::{Engine, Manifest, NetworkMeta};
use crate::util::rng::Pcg32;

use super::checkpoint::{Durable, ResumeState, SearchCheckpoint};
use super::embedding::{embed, StaticFeatures, STATE_DIM};
use super::env::{EnvConfig, QuantEnv};
use super::ppo::{AgentKind, PpoAgent, PpoConfig, StepRecord};
use super::reward::RewardParams;

/// Action space style (paper §2.5, Fig 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActionSpace {
    /// Fig 2a: any bitwidth -> any bitwidth (the one ReLeQ uses)
    Flexible,
    /// Fig 2b ablation: moves restricted to {-1, 0, +1} of the current bits;
    /// sampled targets outside that window are clamped to the nearest edge.
    Restricted,
}

impl ActionSpace {
    pub fn parse(s: &str) -> Result<ActionSpace> {
        match s {
            "flexible" => Ok(ActionSpace::Flexible),
            "restricted" => Ok(ActionSpace::Restricted),
            other => anyhow::bail!("unknown action space `{other}` (expected flexible|restricted)"),
        }
    }
}

/// How episodes roll out (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutMode {
    /// one agent `act` dispatch per (layer, episode)
    Serial,
    /// lockstep lanes: one `act_batch` dispatch per layer for a whole PPO
    /// batch, accuracy misses deduped + fanned across shard threads
    Batched,
}

impl RolloutMode {
    pub fn parse(s: &str) -> Result<RolloutMode> {
        match s {
            "serial" => Ok(RolloutMode::Serial),
            "batched" => Ok(RolloutMode::Batched),
            other => anyhow::bail!("unknown rollout mode `{other}` (expected batched|serial)"),
        }
    }
}

/// Typed marker for cooperative cancellation: a search interrupted through
/// [`SearchCtl`] fails with this error, so a driver (the serve scheduler)
/// can tell "cancelled"/"deadline exceeded" apart from a genuine failure
/// via `err.downcast_ref::<Cancelled>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cancelled(pub &'static str);

impl std::fmt::Display for Cancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "search stopped: {}", self.0)
    }
}

impl std::error::Error for Cancelled {}

/// Cooperative run control for a search: a cancellation flag, an optional
/// wall-clock deadline, and a per-episode progress hook. Built by the
/// driving side (e.g. the `releq serve` scheduler), shared with the
/// controller via `Arc`, and checked by both rollout drivers at every
/// episode boundary — a search never dies mid-PJRT-execution, it stops at
/// the next episode with a typed [`Cancelled`] error.
#[derive(Default)]
pub struct SearchCtl {
    cancelled: AtomicBool,
    /// the cancellation is a process shutdown, not a user cancel — the
    /// scheduler journals the job as "interrupted" (recoverable) instead of
    /// terminally cancelled
    shutdown: AtomicBool,
    deadline: Option<Instant>,
    progress: Option<Box<dyn Fn(&EpisodeLog) + Send + Sync>>,
}

impl SearchCtl {
    pub fn new() -> SearchCtl {
        SearchCtl::default()
    }

    /// Cancel the search once `d` has elapsed from now (the scheduler
    /// starts the clock at job submission, so queue wait counts).
    pub fn with_deadline(mut self, d: Duration) -> SearchCtl {
        self.deadline = Some(Instant::now() + d);
        self
    }

    /// Invoke `f` for every finished training episode (greedy convergence
    /// probes are not reported). Called on the search thread — keep it
    /// cheap; the serve scheduler just appends to a bounded tail buffer.
    pub fn with_progress(mut self, f: impl Fn(&EpisodeLog) + Send + Sync + 'static) -> SearchCtl {
        self.progress = Some(Box::new(f));
        self
    }

    /// Request cancellation; the search stops at the next episode boundary.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Cancel because the process is shutting down (SIGTERM/SIGINT drain).
    /// The search stops with `Cancelled("shutdown")`, which the serve
    /// scheduler journals as a *recoverable* interruption — the job is
    /// re-enqueued on the next daemon start and resumes from its last
    /// checkpoint — where a plain [`SearchCtl::cancel`] is terminal.
    pub fn cancel_for_shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.cancelled.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
            || self.deadline.map_or(false, |d| Instant::now() >= d)
    }

    /// Bail with the typed [`Cancelled`] error if cancellation or the
    /// deadline fired. The rollout drivers call this at episode boundaries.
    pub fn check(&self) -> Result<()> {
        if self.cancelled.load(Ordering::Relaxed) {
            if self.shutdown.load(Ordering::Relaxed) {
                return Err(Cancelled("shutdown").into());
            }
            return Err(Cancelled("cancelled").into());
        }
        if self.deadline.map_or(false, |d| Instant::now() >= d) {
            return Err(Cancelled("deadline exceeded").into());
        }
        Ok(())
    }

    /// Report a finished episode to the progress hook (if any).
    pub fn notify(&self, ep: &EpisodeLog) {
        if let Some(f) = &self.progress {
            f(ep);
        }
    }
}

#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub episodes: usize,
    pub env: EnvConfig,
    pub ppo: PpoConfig,
    pub reward: RewardParams,
    pub agent_kind: AgentKind,
    pub action_space: ActionSpace,
    /// rollout driver; `Batched` needs the `agent_*_act_batch` artifact
    pub rollout: RolloutMode,
    /// lockstep lanes per batch (0 = episodes_per_update). 1 replays the
    /// serial trajectory exactly; values that divide episodes_per_update
    /// keep PPO updates on the same episode boundaries as the serial driver.
    pub lanes: usize,
    /// async pipeline depth for the batched driver (0 = off: the fully
    /// synchronous path, no dispatcher). N > 0 double-buffers lockstep
    /// chunks through a `runtime::Dispatcher` (the next chunk's first-layer
    /// act_batch executes while this chunk's PPO update / logging run on
    /// the host), speculatively warms the accuracy memo with the top-N
    /// most-probable next-chunk candidates, and caps each artifact at N
    /// in-flight dispatches. Purely a throughput lever: results are
    /// bit-identical at any depth (`rust/tests/pipeline_parity.rs`).
    pub pipeline: usize,
    /// per-execution wall-clock budget (ms) for the pipelined driver's
    /// dispatcher (0 = no watchdog). A dispatched execution that exceeds the
    /// budget fails fast with a transient `watchdog` error and flips the
    /// engine's health flag instead of wedging the worker pool; the next
    /// completed execution clears it. Only the `pipeline > 0` driver
    /// dispatches to worker threads, so the knob is inert elsewhere.
    pub watchdog_ms: u64,
    /// evaluate accuracy (and reward) at every layer step; when false, only
    /// the terminal step is evaluated (paper §3: "for deeper networks ... we
    /// perform this phase after all the bitwidths are selected")
    pub eval_every_step: bool,
    /// minimum bitwidth the agent may choose (2 keeps sign+1 level; the paper
    /// explores {1..8} in Fig 2 but Table 2 solutions never go below 2)
    pub min_bits: u32,
    pub seed: u64,
    /// stop early when the greedy policy is stable this many updates in a row
    /// (0 disables early stopping)
    pub patience: usize,
    /// PJRT device-pool size the search should have available (grow-only:
    /// the launcher/serve session calls `Engine::ensure_devices` with it
    /// before the search starts). On CPU each device is its own forced
    /// host client, so N > 1 is testable anywhere. Purely a throughput
    /// lever — results are bit-identical at any count, and 1 (the default)
    /// replays the single-engine path byte for byte — so like `memo_cap`
    /// and `eval_batch` it is excluded from the serve env fingerprint.
    pub devices: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            episodes: 400,
            env: EnvConfig::default(),
            ppo: PpoConfig::default(),
            reward: RewardParams::default(),
            agent_kind: AgentKind::Lstm,
            action_space: ActionSpace::Flexible,
            rollout: RolloutMode::Serial,
            lanes: 0,
            pipeline: 0,
            watchdog_ms: 0,
            eval_every_step: true,
            min_bits: 2,
            seed: 23,
            patience: 12,
            devices: 1,
        }
    }
}

/// Search outcome: the quantization solution and the full learning history.
pub struct SearchResult {
    pub net: String,
    /// converged per-layer bitwidths (greedy rollout of the final policy)
    pub bits: Vec<u32>,
    /// plain mean of bits (Table 2 "Average Bitwidth")
    pub avg_bits: f64,
    /// full-precision reference accuracy
    pub acc_fullp: f64,
    /// accuracy after the final long retrain at `bits`
    pub acc_final: f64,
    /// Acc loss (%) as Table 2 reports it
    pub acc_loss_pct: f64,
    pub state_q: f64,
    pub log: SearchLog,
    /// episodes actually run (early stopping may cut `episodes`)
    pub episodes_run: usize,
    /// greedy (argmax) per-layer probabilities at convergence
    pub final_probs: Vec<Vec<f32>>,
}

pub struct Searcher {
    pub env: QuantEnv,
    pub agent: PpoAgent,
    pub cfg: SearchConfig,
    pub(super) statics: StaticFeatures,
    /// seed anchor for the per-episode sampling streams (never advanced)
    base_rng: Pcg32,
    pub(super) bits_max: u32,
}

impl Searcher {
    pub fn new(engine: Arc<Engine>, manifest: &Manifest, net: &NetworkMeta,
               cfg: SearchConfig) -> Result<Searcher> {
        let env = QuantEnv::new(
            engine.clone(),
            net,
            manifest.bits_max,
            manifest.fp_bits,
            cfg.env.clone(),
        )?;
        Self::with_env(env, engine, manifest, cfg)
    }

    /// Build a searcher over an existing — possibly shared-core — env, so
    /// multiple searchers (e.g. [`run_replicas`] shards) reuse one
    /// pretrained snapshot and one accuracy memo instead of each paying the
    /// full env bring-up. The env's own `EnvConfig` governs evaluation;
    /// `cfg.env` is ignored (pretraining already happened).
    pub fn with_env(env: QuantEnv, engine: Arc<Engine>, manifest: &Manifest,
                    cfg: SearchConfig) -> Result<Searcher> {
        let agent = PpoAgent::new(
            engine,
            manifest,
            cfg.agent_kind,
            env.net.l,
            cfg.seed ^ 0xa9e27,
            cfg.ppo.clone(),
        )?;
        let statics = StaticFeatures::new(&env.net, &env.pretrained);
        let base_rng = Pcg32::new(cfg.seed);
        let bits_max = manifest.bits_max;
        Ok(Searcher { env, agent, cfg, statics, base_rng, bits_max })
    }

    /// Independent action-sampling stream for episode `ep`. Serial and
    /// lockstep rollouts both draw episode `ep` from this stream, which is
    /// what makes a lanes=1 batched run replay the serial trajectory exactly
    /// and a lanes=B run sample the same actions the serial driver would.
    pub(super) fn episode_rng(&self, ep: usize) -> Pcg32 {
        self.base_rng.derive(ep as u64)
    }

    /// Map a sampled action index to a bitwidth, honoring the action space.
    pub(super) fn action_to_bits(&self, action: usize, current: u32) -> u32 {
        let target = (action as u32 + 1).clamp(self.cfg.min_bits, self.bits_max);
        match self.cfg.action_space {
            ActionSpace::Flexible => target,
            ActionSpace::Restricted => {
                target.clamp(current.saturating_sub(1).max(self.cfg.min_bits),
                             (current + 1).min(self.bits_max))
            }
        }
    }

    /// Run one serial episode. `rng = None` takes greedy (argmax) actions
    /// and skips recording. Returns (bits, per-step probs, episode records).
    pub(super) fn rollout(&mut self, mut rng: Option<&mut Pcg32>)
                          -> Result<(Vec<u32>, Vec<Vec<f32>>, Vec<StepRecord>)> {
        let greedy = rng.is_none();
        let l_total = self.env.net.l;
        // onset of exploration: all layers start at bits_max (paper §5.1)
        let mut bits = vec![self.bits_max; l_total];
        let (mut h, mut c) = self.agent.initial_hidden();
        let mut state_acc = 1.0f64;
        let mut state_q = self.env.state_q(&bits);
        let mut probs_hist = Vec::with_capacity(l_total);
        let mut records = Vec::with_capacity(l_total);
        let mut s = [0.0f32; STATE_DIM];

        for l in 0..l_total {
            embed(&self.statics, l, &bits, self.bits_max, state_acc, state_q, &mut s);
            let (probs, value, h2, c2) = self.agent.act(&s, &h, &c)?;
            h = h2;
            c = c2;
            let action = match rng.as_mut() {
                None => {
                    // total_cmp instead of partial_cmp().unwrap(): no panic on
                    // NaN — but total_cmp ranks NaN above +inf, so a diverged
                    // policy would silently "win" the argmax; surface it as a
                    // proper error instead of reporting a garbage solution
                    let (i, &p) = probs
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .expect("non-empty action probabilities");
                    anyhow::ensure!(
                        !p.is_nan(),
                        "policy diverged: NaN action probability at layer {l}"
                    );
                    i
                }
                Some(r) => PpoAgent::sample(&probs, *r),
            };
            bits[l] = self.action_to_bits(action, bits[l]);
            state_q = self.env.state_q(&bits);

            let last = l + 1 == l_total;
            let reward = if self.cfg.eval_every_step || last {
                state_acc = self.env.state_acc(&bits)?;
                self.cfg.reward.reward(state_acc, state_q) as f32
            } else {
                0.0
            };
            probs_hist.push(probs.clone());
            if !greedy {
                records.push(StepRecord {
                    state: s,
                    action,
                    logp: probs[action].max(1e-8).ln(),
                    value,
                    reward,
                });
            }
        }
        Ok((bits, probs_hist, records))
    }

    /// Convergence check after a PPO update: greedy policy stability.
    /// Returns true once the greedy rollout has been stable for
    /// `cfg.patience` consecutive updates.
    pub(super) fn greedy_converged(&mut self, last_greedy: &mut Option<Vec<u32>>,
                                   stable_updates: &mut usize) -> Result<bool> {
        let (gbits, _, _) = self.rollout(None)?;
        if last_greedy.as_ref() == Some(&gbits) {
            *stable_updates += 1;
            Ok(*stable_updates >= self.cfg.patience)
        } else {
            *stable_updates = 0;
            *last_greedy = Some(gbits);
            Ok(false)
        }
    }

    /// Final solution: greedy rollout of the converged policy + long retrain.
    pub(super) fn finalize(&mut self, log: SearchLog, episodes_run: usize)
                           -> Result<SearchResult> {
        let (bits, final_probs, _) = self.rollout(None)?;
        let state_q = self.env.state_q(&bits);
        let acc_final = self
            .env
            .retrain_and_eval(&bits, self.env.cfg.long_retrain_steps)?;
        let acc_fullp = self.env.acc_fullp;
        let acc_loss_pct = ((acc_fullp - acc_final) * 100.0).max(0.0);
        Ok(SearchResult {
            net: self.env.net.name.clone(),
            avg_bits: bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64,
            bits,
            acc_fullp,
            acc_final,
            acc_loss_pct,
            state_q,
            log,
            episodes_run,
            final_probs,
        })
    }

    /// Full search: episodes + PPO updates + convergence detection, then the
    /// greedy rollout and final long retrain. Dispatches on
    /// `cfg.rollout` — the batched driver lives in `coordinator::rollout`.
    pub fn run(&mut self) -> Result<SearchResult> {
        self.run_ctl(&SearchCtl::default())
    }

    /// [`Searcher::run`] under external control: `ctl` is checked at every
    /// episode boundary (cancellation / deadline surface as the typed
    /// [`Cancelled`] error) and receives every finished episode through its
    /// progress hook. `run()` is `run_ctl` with an inert control.
    pub fn run_ctl(&mut self, ctl: &SearchCtl) -> Result<SearchResult> {
        self.run_durable(ctl, None)
    }

    /// [`Searcher::run_ctl`] with optional durability: when `durable` is
    /// given, a [`SearchCheckpoint`] is captured at every PPO update
    /// boundary (the one point where the agent holds no pending
    /// trajectories) and persisted per the driver's interval; an error exit
    /// — including cooperative cancellation — flushes the newest unsaved
    /// boundary first, so a drained job leaves a final checkpoint behind.
    ///
    /// If `durable` carries resume state (a checkpoint restored via
    /// [`Searcher::restore`]), the episode loop continues from the
    /// checkpointed episode and the final result is **bit-identical** to an
    /// uninterrupted run: per-episode PCG streams derive from the episode
    /// index alone, accuracy is a pure function of the bits vector (and
    /// memo-warmed, so pre-checkpoint evaluations do not re-execute), and
    /// the restored agent state replays the exact act/update sequence.
    pub fn run_durable(&mut self, ctl: &SearchCtl,
                       mut durable: Option<&mut Durable>) -> Result<SearchResult> {
        let out = match self.cfg.rollout {
            RolloutMode::Serial => self.run_serial(ctl, durable.as_deref_mut()),
            RolloutMode::Batched => self.run_batched(ctl, durable.as_deref_mut()),
        };
        if out.is_err() {
            if let Some(d) = durable {
                d.flush();
            }
        }
        out
    }

    /// Capture a resumable checkpoint at an update boundary: `episodes_done`
    /// episodes complete, `log` covering exactly those episodes, and the
    /// convergence-detector state. The full memo export rides along so the
    /// resumed run re-executes only post-checkpoint episodes.
    pub(super) fn checkpoint_at(&self, d: &Durable, episodes_done: usize, log: &SearchLog,
                                last_greedy: &Option<Vec<u32>>, stable_updates: usize)
                                -> SearchCheckpoint {
        SearchCheckpoint {
            net: d.net.clone(),
            search_fp: d.search_fp,
            episodes_done,
            log: log.episodes.clone(),
            agent: self.agent.snapshot(),
            last_greedy: last_greedy.clone(),
            stable_updates,
            memo: self.env.memo().entries(),
        }
    }

    /// Restore a loaded checkpoint into this searcher and arm `durable`
    /// with the resume state consumed by the next [`Searcher::run_durable`]
    /// call. Rejects checkpoints from a different search spec (fingerprint
    /// mismatch) or an incompatible agent — callers treat a rejection as
    /// "start fresh", never as a job failure.
    pub fn restore(&mut self, ck: SearchCheckpoint, durable: &mut Durable) -> Result<()> {
        anyhow::ensure!(
            ck.search_fp == durable.search_fp,
            "checkpoint fingerprint {:016x} != this search's {:016x}",
            ck.search_fp,
            durable.search_fp
        );
        anyhow::ensure!(
            ck.episodes_done <= self.cfg.episodes,
            "checkpoint at episode {} exceeds configured episodes {}",
            ck.episodes_done,
            self.cfg.episodes
        );
        anyhow::ensure!(
            ck.log.len() == ck.episodes_done,
            "checkpoint log covers {} episodes, expected {}",
            ck.log.len(),
            ck.episodes_done
        );
        self.agent.restore(&ck.agent)?;
        if !ck.memo.is_empty() {
            self.env.memo().extend(ck.memo);
        }
        durable.resumed_from = Some(ck.episodes_done);
        durable.last_saved = ck.episodes_done;
        durable.resume = Some(ResumeState {
            start: ck.episodes_done,
            episodes: ck.log,
            last_greedy: ck.last_greedy,
            stable_updates: ck.stable_updates,
        });
        Ok(())
    }

    fn run_serial(&mut self, ctl: &SearchCtl,
                  mut durable: Option<&mut Durable>) -> Result<SearchResult> {
        let mut log = SearchLog::default();
        let mut stable_updates = 0usize;
        let mut last_greedy: Option<Vec<u32>> = None;
        let mut start = 0usize;
        if let Some(d) = durable.as_deref_mut() {
            if let Some(rs) = d.resume.take() {
                start = rs.start;
                log.episodes = rs.episodes;
                last_greedy = rs.last_greedy;
                stable_updates = rs.stable_updates;
            }
        }
        let mut episodes_run = start;

        for ep in start..self.cfg.episodes {
            ctl.check()?;
            let mut rng = self.episode_rng(ep);
            let (bits, probs, records) = self.rollout(Some(&mut rng))?;
            episodes_run = ep + 1;
            let reward_sum: f64 = records.iter().map(|r| r.reward as f64).sum();
            let state_acc = self.env.state_acc(&bits)?;
            let state_q = self.env.state_q(&bits);
            let entry = EpisodeLog {
                episode: ep,
                reward: reward_sum,
                state_acc,
                state_q,
                bits: bits.clone(),
                probs,
            };
            ctl.notify(&entry);
            log.push(entry);
            let updated = self.agent.finish_episode(records)?.is_some();

            if updated
                && self.cfg.patience > 0
                && self.greedy_converged(&mut last_greedy, &mut stable_updates)?
            {
                break;
            }
            if updated {
                if let Some(d) = durable.as_deref_mut() {
                    let ck = self.checkpoint_at(d, ep + 1, &log, &last_greedy, stable_updates);
                    d.on_boundary(ck);
                }
            }
        }

        ctl.check()?;
        self.finalize(log, episodes_run)
    }
}

/// Run independent search replicas — `base` with each seed substituted — in
/// parallel, one `Searcher` per shard thread over a **shared pretrained env
/// core**: the env bring-up (data generation + full-precision pretraining)
/// runs exactly once, and every accuracy a replica evaluates memoizes for
/// all the others. Sharing changes no result — `EnvCore::accuracy` is a pure
/// function of the bits vector — and results come back in seed order
/// (deterministic merge), so `run_replicas(e, m, n, cfg, &[s])` reproduces a
/// sequential `Searcher::new(..).run()` with `cfg.seed = s` exactly.
pub fn run_replicas(engine: &Arc<Engine>, manifest: &Manifest, net: &NetworkMeta,
                    base: &SearchConfig, seeds: &[u64]) -> Result<Vec<SearchResult>> {
    let env = QuantEnv::new(
        engine.clone(),
        net,
        manifest.bits_max,
        manifest.fp_bits,
        base.env.clone(),
    )?;
    let cfgs: Vec<SearchConfig> = seeds
        .iter()
        .map(|&s| {
            let mut c = base.clone();
            c.seed = s;
            c
        })
        .collect();
    parallel::run_sharded(cfgs, |i, cfg| {
        // one replica per pool device (round-robin beyond the pool size):
        // the pin routes this replica's agent residency AND all its striped
        // accuracy chunks to its own device for the whole search. At
        // `devices == 1` every pin is Some(0) — byte-identical to the
        // unpinned single-engine run.
        let _pin = engine.pin_thread(i);
        let mut searcher = Searcher::with_env(env.clone(), engine.clone(), manifest, cfg)?;
        searcher.run()
    })
}

/// Pick the best replica: highest final accuracy, ties broken by lower
/// State_Q (cheaper solution), then by index (deterministic). A diverged
/// replica (NaN accuracy) always loses — `total_cmp` alone would rank NaN
/// above +inf and hand the win to the one broken run.
pub fn best_replica(results: &[SearchResult]) -> Option<usize> {
    let acc_key = |i: usize| {
        let a = results[i].acc_final;
        if a.is_nan() {
            f64::NEG_INFINITY
        } else {
            a
        }
    };
    (0..results.len()).min_by(|&a, &b| {
        acc_key(b)
            .total_cmp(&acc_key(a))
            .then(results[a].state_q.total_cmp(&results[b].state_q))
            .then(a.cmp(&b))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(acc_final: f64, state_q: f64) -> SearchResult {
        SearchResult {
            net: "test".to_string(),
            bits: vec![4, 4],
            avg_bits: 4.0,
            acc_fullp: 1.0,
            acc_final,
            acc_loss_pct: 0.0,
            state_q,
            log: SearchLog::default(),
            episodes_run: 0,
            final_probs: vec![],
        }
    }

    #[test]
    fn best_replica_picks_highest_acc_then_cheapest() {
        let rs = vec![result(0.90, 0.5), result(0.95, 0.6), result(0.95, 0.4)];
        assert_eq!(best_replica(&rs), Some(2));
        assert_eq!(best_replica(&rs[..1]), Some(0));
        assert_eq!(best_replica(&[]), None);
    }

    #[test]
    fn best_replica_never_picks_nan() {
        // total_cmp alone would rank NaN above +inf; a diverged replica
        // must lose to any finite one
        let rs = vec![result(f64::NAN, 0.1), result(0.6, 0.9)];
        assert_eq!(best_replica(&rs), Some(1));
        // all-NaN still returns deterministically
        let all_nan = vec![result(f64::NAN, 0.2), result(f64::NAN, 0.1)];
        assert_eq!(best_replica(&all_nan), Some(1));
    }

    #[test]
    fn search_ctl_cancel_and_deadline_are_typed() {
        let ctl = SearchCtl::new();
        assert!(!ctl.is_cancelled());
        assert!(ctl.check().is_ok());
        ctl.cancel();
        assert!(ctl.is_cancelled());
        let err = ctl.check().unwrap_err();
        assert_eq!(err.downcast_ref::<Cancelled>(), Some(&Cancelled("cancelled")));

        // an already-expired deadline fires immediately
        let ctl = SearchCtl::new().with_deadline(Duration::from_secs(0));
        let err = ctl.check().unwrap_err();
        assert_eq!(err.downcast_ref::<Cancelled>(), Some(&Cancelled("deadline exceeded")));

        // a far-future deadline does not
        let ctl = SearchCtl::new().with_deadline(Duration::from_secs(3600));
        assert!(ctl.check().is_ok());
    }

    #[test]
    fn search_ctl_progress_hook_fires() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let seen = Arc::new(AtomicUsize::new(0));
        let seen2 = seen.clone();
        let ctl = SearchCtl::new().with_progress(move |ep| {
            seen2.fetch_add(ep.episode + 1, Ordering::Relaxed);
        });
        let entry = EpisodeLog {
            episode: 4,
            reward: 0.0,
            state_acc: 1.0,
            state_q: 0.5,
            bits: vec![8, 8],
            probs: vec![],
        };
        ctl.notify(&entry);
        assert_eq!(seen.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn parsers_reject_unknown_values() {
        assert!(ActionSpace::parse("flexible").is_ok());
        assert!(ActionSpace::parse("sideways").is_err());
        assert_eq!(RolloutMode::parse("batched").unwrap(), RolloutMode::Batched);
        assert_eq!(RolloutMode::parse("serial").unwrap(), RolloutMode::Serial);
        assert!(RolloutMode::parse("vectorized").is_err());
    }
}
