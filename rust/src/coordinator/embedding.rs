//! State-space embedding (paper §2.4, Table 1).
//!
//! Per agent step the environment emits an 8-dim vector combining
//! layer-specific static features (index, size, weight statistics),
//! layer-specific dynamic features (current bitwidth) and network-specific
//! dynamic features (State-of-Quantization, State-of-Relative-Accuracy).
//! `STATE_DIM` must equal `compile.agent.STATE_DIM` on the Python side —
//! checked against the manifest at load time.

use crate::runtime::NetworkMeta;

pub const STATE_DIM: usize = 8;

/// Static per-layer features, precomputed once per search from the manifest
/// and the pretrained weights.
#[derive(Debug, Clone)]
pub struct StaticFeatures {
    /// layer index normalized to [0, 1]
    pub idx_norm: Vec<f32>,
    /// log10 weight count, normalized
    pub logw: Vec<f32>,
    /// log10 MAC count, normalized
    pub logm: Vec<f32>,
    /// weight standard deviation of the pretrained layer (Table 1:
    /// "Weight Statistics (standard deviation)")
    pub wstd: Vec<f32>,
}

impl StaticFeatures {
    pub fn new(net: &NetworkMeta, pretrained: &[f32]) -> StaticFeatures {
        let l = net.l.max(2);
        let idx_norm = (0..net.l).map(|i| i as f32 / (l - 1) as f32).collect();
        let logw = net
            .layers
            .iter()
            .map(|m| ((m.w_len as f32 + 1.0).log10() / 6.0).min(1.0))
            .collect();
        let logm = net
            .layers
            .iter()
            .map(|m| ((m.n_macs as f32 + 1.0).log10() / 8.0).min(1.0))
            .collect();
        let wstd = net
            .layers
            .iter()
            .map(|m| {
                let w = &pretrained[m.w_offset..m.w_offset + m.w_len];
                let mean = w.iter().sum::<f32>() / w.len() as f32;
                let var =
                    w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.len() as f32;
                (var.sqrt() / 2.0).min(1.0)
            })
            .collect();
        StaticFeatures { idx_norm, logw, logm, wstd }
    }
}

/// Assemble the embedding for the step that will choose layer `l`'s bitwidth.
pub fn embed(
    st: &StaticFeatures,
    l: usize,
    bits: &[u32],
    bits_max: u32,
    state_acc: f64,
    state_q: f64,
    out: &mut [f32; STATE_DIM],
) {
    let n = bits.len() as f32;
    out[0] = st.idx_norm[l];
    out[1] = st.logw[l];
    out[2] = st.logm[l];
    out[3] = st.wstd[l];
    out[4] = bits[l] as f32 / bits_max as f32;
    out[5] = (state_acc as f32).clamp(0.0, 1.25) / 1.25;
    out[6] = (state_q as f32).clamp(0.0, 1.0);
    out[7] = l as f32 / n;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::cost::tests_support::toy_net;

    #[test]
    fn features_in_unit_range() {
        let net = toy_net(&[(1000, 50_000), (250_000, 2_000_000), (10, 100)]);
        let params = vec![0.1f32; 250_010 + 10];
        let st = StaticFeatures::new(&net, &params);
        let mut s = [0f32; STATE_DIM];
        for l in 0..3 {
            embed(&st, l, &[8, 8, 8], 8, 1.0, 1.0, &mut s);
            for (i, v) in s.iter().enumerate() {
                assert!((0.0..=1.0).contains(v), "feat {i} = {v}");
            }
        }
    }

    #[test]
    fn distinguishes_layers() {
        let net = toy_net(&[(1000, 50_000), (250_000, 2_000_000)]);
        let params = vec![0.05f32; 251_000];
        let st = StaticFeatures::new(&net, &params);
        let mut s0 = [0f32; STATE_DIM];
        let mut s1 = [0f32; STATE_DIM];
        embed(&st, 0, &[8, 8], 8, 1.0, 1.0, &mut s0);
        embed(&st, 1, &[8, 8], 8, 1.0, 1.0, &mut s1);
        assert_ne!(s0, s1);
        assert!(s1[1] > s0[1], "bigger layer has bigger logw");
    }

    #[test]
    fn reflects_dynamic_state() {
        let net = toy_net(&[(1000, 50_000)]);
        let st = StaticFeatures::new(&net, &vec![0.0f32; 1000]);
        let mut a = [0f32; STATE_DIM];
        let mut b = [0f32; STATE_DIM];
        embed(&st, 0, &[8], 8, 1.0, 1.0, &mut a);
        embed(&st, 0, &[2], 8, 0.5, 0.25, &mut b);
        assert!(b[4] < a[4]); // bits feature
        assert!(b[5] < a[5]); // acc feature
        assert!(b[6] < a[6]); // quant feature
    }
}
