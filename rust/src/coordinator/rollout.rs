//! Lockstep batched rollouts (EXPERIMENTS.md §Perf): advance a whole PPO
//! batch of episodes layer-by-layer instead of episode-by-episode.
//!
//! Per layer the driver pays
//!
//! 1. **one** `agent_*_act_batch` PJRT execution for all B lanes (the serial
//!    driver pays B scalar `act` executions), then
//! 2. `ceil(misses / K)` accuracy executions for the lanes' **distinct
//!    uncached** bits vectors: the candidate slate goes through
//!    `EnvCore::accuracy_batch`, whose batch single-flight protocol shrinks
//!    it by cache hits and scores the misses K lanes at a time via the
//!    vmapped `<net>_retrain_eval_batch` artifact (envs without that
//!    artifact fall back to fanning misses across shard threads inside the
//!    same call).
//!
//! # Pipelining (`SearchConfig::pipeline` > 0)
//!
//! The synchronous driver leaves the device idle during every PPO update,
//! greedy-convergence probe and episode-logging pass. The pipelined driver
//! overlaps them through a `runtime::Dispatcher`:
//!
//! * **double-buffered chunks** — a fresh chunk's first-layer act_batch
//!   operands are a pure function of the agent params (every lane starts at
//!   uniform `bits_max`, `State_A = 1`, zero hidden state), so as soon as
//!   the current chunk's *last* PPO update has run, the next chunk's
//!   first-layer forward is submitted to the dispatcher and executes while
//!   the host finishes logging and the greedy-convergence probe;
//! * **speculative accuracy prefetch** — the current chunk's first-layer
//!   policy probabilities nominate the top-`pipeline` most probable
//!   first-step candidate vectors for the next chunk; a `Prefetcher`
//!   enqueues them as one `accuracy_batch` memo-warming call (budgeted by
//!   the dispatcher's in-flight cap, accounted in
//!   `EnvStats::spec_{submitted,hits,wasted}`).
//!
//! Both are result-invariant: the act_batch is the same program on the same
//! operands, and accuracy is a pure function of the bits vector published
//! through the single-flight memo — so `pipeline = N` is bit-identical to
//! `pipeline = 0` (enforced by `rust/tests/pipeline_parity.rs`), and
//! `pipeline = 0` bypasses the dispatcher entirely.
//!
//! Equivalence with the serial driver: every episode samples from its own
//! per-episode PCG stream (`Searcher::episode_rng`) and `EnvCore::accuracy`
//! is a pure function of the bits vector, so a lanes=1 run replays the
//! serial trajectory bit-for-bit (it even dispatches through the scalar
//! `act` artifact), and a lanes=B run draws the same actions the serial
//! driver would whenever B divides `episodes_per_update` — PPO updates then
//! land on the same episode boundaries — up to the vmapped act_batch
//! artifact agreeing numerically with the scalar act (XLA guarantees this
//! only to float-rounding level; python/tests/test_agent.py pins it at
//! ~1e-5, so parity tests compare converged solutions, not raw
//! trajectories: `rust/tests/rollout_parity.rs`).

use std::collections::HashMap;

use anyhow::Result;

use crate::metrics::{EpisodeLog, SearchLog};
use crate::runtime::{Dispatcher, HostLit, Pending};
use crate::util::rng::Pcg32;

use super::checkpoint::Durable;
use super::embedding::{embed, STATE_DIM};
use super::ppo::{PpoAgent, StepRecord};
use super::prefetch::Prefetcher;
use super::search::{SearchCtl, SearchResult, Searcher};

/// One episode lane's finished rollout.
pub struct LaneRollout {
    pub bits: Vec<u32>,
    pub probs: Vec<Vec<f32>>,
    pub records: Vec<StepRecord>,
}

/// A pre-submitted first-layer act_batch for an upcoming chunk (the
/// double-buffering handle): the lane count it was staged for plus the
/// in-flight execution. Dropped unused (size mismatch, early convergence)
/// it simply wastes one dispatch; the lockstep driver recomputes
/// synchronously and results are unchanged.
pub(super) struct ActPending {
    n: usize,
    pending: Pending<Vec<HostLit>>,
}

impl Searcher {
    /// Roll out `rngs.len()` training episodes in lockstep (lane `i` samples
    /// from `rngs[i]`). Lane count must not exceed the act_batch artifact's
    /// baked width; a single active lane takes the scalar `act` path.
    /// `pending0`, if provided and staged for exactly this lane count, is
    /// joined in place of the layer-0 act_batch execution. `ctl` is
    /// consulted at every per-step chunk boundary (each layer costs an
    /// act_batch plus up to one accuracy megabatch), so a cancellation or
    /// deadline bounds wall-clock within one step, not one whole episode
    /// chunk.
    pub(super) fn rollout_lockstep(&mut self, ctl: &SearchCtl, rngs: &mut [Pcg32],
                                   mut pending0: Option<ActPending>) -> Result<Vec<LaneRollout>> {
        let n = rngs.len();
        let l_total = self.env.net.l;
        let lanes = self.agent.act_lanes;
        anyhow::ensure!(n >= 1, "lockstep rollout needs at least one lane");
        anyhow::ensure!(
            n <= lanes,
            "{n} lanes exceed the act_batch artifact's width {lanes}"
        );
        let (h0, c0) = self.agent.initial_hidden();
        let hidden = h0.len();
        let n_actions = self.agent.n_actions;

        // per-lane episode state (paper §5.1: all layers start at bits_max)
        let mut bits: Vec<Vec<u32>> = vec![vec![self.bits_max; l_total]; n];
        let mut hs: Vec<Vec<f32>> = vec![h0; n];
        let mut cs: Vec<Vec<f32>> = vec![c0; n];
        let mut state_accs = vec![1.0f64; n];
        let mut state_qs: Vec<f64> = bits.iter().map(|b| self.env.state_q(b)).collect();
        let mut out: Vec<LaneRollout> = (0..n)
            .map(|_| LaneRollout {
                bits: Vec::new(),
                probs: Vec::with_capacity(l_total),
                records: Vec::with_capacity(l_total),
            })
            .collect();

        for l in 0..l_total {
            ctl.check()?;
            let mut lane_states: Vec<[f32; STATE_DIM]> = Vec::with_capacity(n);
            for i in 0..n {
                let mut s = [0.0f32; STATE_DIM];
                embed(&self.statics, l, &bits[i], self.bits_max, state_accs[i], state_qs[i],
                      &mut s);
                lane_states.push(s);
            }

            // one batched forward for all lanes (scalar act when only one
            // lane is active: cheaper than padding, and bit-identical to the
            // serial rollout — the B=1 parity guarantee)
            let (probs_per_lane, values, new_h, new_c) = if n == 1 {
                let (p, v, h2, c2) = self.agent.act(&lane_states[0], &hs[0], &cs[0])?;
                (vec![p], vec![v], vec![h2], vec![c2])
            } else {
                // the double-buffered first-layer forward: join the
                // pre-submitted execution if it was staged for exactly this
                // chunk shape; its operands equal the ones packed below
                // (layer-0 states are params-independent constants), so the
                // result is bit-identical to the synchronous dispatch
                let prefetched = match pending0.take() {
                    Some(p) if l == 0 && p.n == n => match p.pending.wait() {
                        Ok(parts) => Some(self.agent.act_batch_take(&parts)?),
                        Err(e) => {
                            // a failed speculative dispatch must not fail the
                            // search: recompute synchronously (same values)
                            eprintln!("[pipeline] prefetched act_batch failed ({e:#}); \
                                       recomputing synchronously");
                            None
                        }
                    },
                    _ => None,
                };
                let (pf, vf, hf, cf) = match prefetched {
                    Some(r) => r,
                    None => {
                        let mut states = vec![0.0f32; lanes * STATE_DIM];
                        let mut hcat = vec![0.0f32; lanes * hidden];
                        let mut ccat = vec![0.0f32; lanes * hidden];
                        for i in 0..n {
                            states[i * STATE_DIM..(i + 1) * STATE_DIM]
                                .copy_from_slice(&lane_states[i]);
                            hcat[i * hidden..(i + 1) * hidden].copy_from_slice(&hs[i]);
                            ccat[i * hidden..(i + 1) * hidden].copy_from_slice(&cs[i]);
                        }
                        self.agent.act_batch(&states, &hcat, &ccat)?
                    }
                };
                (
                    (0..n).map(|i| pf[i * n_actions..(i + 1) * n_actions].to_vec()).collect(),
                    vf[..n].to_vec(),
                    (0..n).map(|i| hf[i * hidden..(i + 1) * hidden].to_vec()).collect(),
                    (0..n).map(|i| cf[i * hidden..(i + 1) * hidden].to_vec()).collect(),
                )
            };

            let mut actions = Vec::with_capacity(n);
            for i in 0..n {
                let action = PpoAgent::sample(&probs_per_lane[i], &mut rngs[i]);
                bits[i][l] = self.action_to_bits(action, bits[i][l]);
                state_qs[i] = self.env.state_q(&bits[i]);
                hs[i] = new_h[i].clone();
                cs[i] = new_c[i].clone();
                actions.push(action);
            }

            let last = l + 1 == l_total;
            let mut rewards = vec![0.0f32; n];
            if self.cfg.eval_every_step || last {
                // dedup the ≤n distinct candidate vectors and score them as
                // ONE megabatch: hits shrink the batch inside the memo's
                // batch protocol and the remaining misses cost
                // ceil(misses / K) device executions (envs without the
                // batch artifact fan the misses across shard threads
                // inside `accuracy_batch` — the pre-megabatch behavior).
                // First-occurrence order, indexed by a hash map so the
                // dedup is O(n·L), not the old O(n²·L) linear rescans.
                let mut cands: Vec<Vec<u32>> = Vec::with_capacity(n);
                let mut pos_of: HashMap<Vec<u32>, usize> = HashMap::with_capacity(n);
                let mut lane_pos: Vec<usize> = Vec::with_capacity(n);
                for b in bits.iter().take(n) {
                    let next = cands.len();
                    let pos = *pos_of.entry(b.clone()).or_insert(next);
                    if pos == next {
                        cands.push(b.clone());
                    }
                    lane_pos.push(pos);
                }
                if self.cfg.pipeline > 0 {
                    // speculation accounting: a speculated vector the search
                    // actually evaluates is a hit (value served warm — or
                    // coalesced with the still-in-flight speculative leader)
                    for c in &cands {
                        self.env.spec().claim(c);
                    }
                }
                let accs = self.env.accuracy_batch(&cands)?;
                for i in 0..n {
                    state_accs[i] = self.env.state_acc_of(accs[lane_pos[i]]);
                    rewards[i] = self.cfg.reward.reward(state_accs[i], state_qs[i]) as f32;
                }
            }

            for i in 0..n {
                out[i].records.push(StepRecord {
                    state: lane_states[i],
                    action: actions[i],
                    logp: probs_per_lane[i][actions[i]].max(1e-8).ln(),
                    value: values[i],
                    reward: rewards[i],
                });
                out[i].probs.push(probs_per_lane[i].clone());
            }
        }

        for (lane, b) in out.iter_mut().zip(bits) {
            lane.bits = b;
        }
        Ok(out)
    }

    /// The batched search loop: lockstep rollouts in chunks of `cfg.lanes`
    /// (default: episodes_per_update, one PPO batch per chunk), with the same
    /// logging, update cadence, and greedy convergence detection as the
    /// serial driver. `ctl` is checked at every chunk boundary and again at
    /// every per-step (per-layer) boundary inside the lockstep rollout, so a
    /// deadline bounds wall-clock to one step's device work, not one whole
    /// chunk of episodes.
    ///
    /// `cfg.pipeline = 0` runs fully synchronously (no dispatcher is ever
    /// constructed); `pipeline > 0` runs the same episode loop with the
    /// double-buffering hooks armed, plus ledger/pool cleanup on every exit
    /// — success, error, or cancellation — so a shared serve-session ledger
    /// is never left unbalanced and no device work outlives the search.
    /// Results are bit-identical either way.
    pub(super) fn run_batched(&mut self, ctl: &SearchCtl,
                              mut durable: Option<&mut Durable>) -> Result<SearchResult> {
        let lanes = if self.cfg.lanes == 0 {
            self.agent.act_lanes.min(self.cfg.ppo.episodes_per_update)
        } else {
            self.cfg.lanes
        };
        anyhow::ensure!(
            lanes >= 1 && lanes <= self.agent.act_lanes,
            "--lanes {lanes} out of range 1..={}",
            self.agent.act_lanes
        );
        let mut log = SearchLog::default();
        let mut episodes_run = 0usize;
        if self.cfg.pipeline == 0 {
            self.batched_episodes(ctl, lanes, None, &mut log, &mut episodes_run,
                                  durable.as_deref_mut())?;
        } else {
            // at least two workers: one lane for the double-buffered
            // act_batch, one for the speculative accuracy slate; the depth
            // caps each artifact's in-flight dispatches (the speculation
            // budget). On a multi-device pool, one worker per device so
            // speculative slates pinned to different devices can overlap
            // (a 1-device pool keeps exactly the pre-pool two workers). The
            // watchdog trips the pool health AND — for `submit`ted exes —
            // the hung device's own health, quarantining it from placement.
            let workers = 2.max(self.env.engine().n_devices());
            let disp = if self.cfg.watchdog_ms > 0 {
                Dispatcher::with_watchdog(
                    workers,
                    self.cfg.pipeline,
                    std::time::Duration::from_millis(self.cfg.watchdog_ms),
                    self.env.engine().health(),
                )
            } else {
                Dispatcher::new(workers, self.cfg.pipeline)
            };
            let prefetcher = Prefetcher::new(self.env.clone(), &disp);
            let looped = self.batched_episodes(
                ctl,
                lanes,
                Some((&disp, &prefetcher)),
                &mut log,
                &mut episodes_run,
                durable.as_deref_mut(),
            );
            // tally never-claimed speculations as wasted and quiesce the
            // pool on EVERY exit (a dropped pending's execution still
            // completes under drain)
            prefetcher.abandon();
            disp.drain();
            looped?;
        }
        ctl.check()?;
        self.finalize(log, episodes_run)
    }

    /// The one episode-loop body behind both `pipeline` modes. The per-lane
    /// processing (episode logging, `ctl` notification, `finish_episode`,
    /// greedy-convergence breaks) is shared verbatim — the parity contract
    /// between `pipeline = 0` and `pipeline = N` rests on there being
    /// exactly one copy of it — and `pipeline` arms the only two additions:
    /// joining a pre-submitted first-layer act_batch and handing the next
    /// chunk's work to the dispatcher once this chunk's last PPO update has
    /// run.
    /// Durability: `durable` (if armed with resume state by
    /// `Searcher::restore`) moves the loop's starting episode to the
    /// checkpoint boundary — always a PPO-update boundary, so when `lanes`
    /// divides `episodes_per_update` (the default and every parity-tested
    /// config) the resumed chunk grouping matches the uninterrupted run's
    /// exactly. The first resumed chunk computes its layer-0 forward
    /// synchronously (no pre-submitted pending survives a restart), which
    /// the pipeline contract already guarantees is value-identical.
    fn batched_episodes(&mut self, ctl: &SearchCtl, lanes: usize,
                        pipeline: Option<(&Dispatcher, &Prefetcher)>, log: &mut SearchLog,
                        episodes_run: &mut usize,
                        mut durable: Option<&mut Durable>) -> Result<()> {
        let epu = self.cfg.ppo.episodes_per_update;
        let mut stable_updates = 0usize;
        let mut last_greedy: Option<Vec<u32>> = None;
        let mut pending0: Option<ActPending> = None;

        let mut ep = 0usize;
        if let Some(d) = durable.as_deref_mut() {
            if let Some(rs) = d.resume.take() {
                ep = rs.start;
                log.episodes = rs.episodes;
                *episodes_run = rs.start;
                last_greedy = rs.last_greedy;
                stable_updates = rs.stable_updates;
            }
        }
        'episodes: while ep < self.cfg.episodes {
            ctl.check()?;
            let n = lanes.min(self.cfg.episodes - ep);
            let mut rngs: Vec<Pcg32> = (ep..ep + n).map(|e| self.episode_rng(e)).collect();
            let batch = self.rollout_lockstep(ctl, &mut rngs, pending0.take())?;
            // the chunk's first-layer policy probabilities nominate the
            // speculative candidates for the NEXT chunk's first step
            // (collected up front — the lane loop consumes `batch`)
            let probs0: Vec<Vec<f32>> = match pipeline {
                Some(_) => {
                    batch.iter().filter_map(|lane| lane.probs.first().cloned()).collect()
                }
                None => Vec::new(),
            };
            // the last lane whose finish_episode triggers a PPO update in
            // this chunk (updates land exactly when the total number of
            // finished episodes is a multiple of episodes_per_update);
            // after it the params are final for the next chunk
            let last_update_lane = (0..n).rev().find(|i| (ep + i + 1) % epu == 0);
            let mut next_submitted = false;
            if let Some((disp, prefetcher)) = pipeline {
                if last_update_lane.is_none() {
                    // no update this chunk: params are already final, so the
                    // whole chunk's host work overlaps next-chunk device work
                    pending0 = self.submit_next_chunk(disp, prefetcher, lanes, ep + n, &probs0)?;
                    next_submitted = true;
                }
            }
            for (i, lane) in batch.into_iter().enumerate() {
                *episodes_run = ep + i + 1;
                let reward_sum: f64 = lane.records.iter().map(|r| r.reward as f64).sum();
                let state_acc = self.env.state_acc(&lane.bits)?;
                let state_q = self.env.state_q(&lane.bits);
                let entry = EpisodeLog {
                    episode: ep + i,
                    reward: reward_sum,
                    state_acc,
                    state_q,
                    bits: lane.bits.clone(),
                    probs: lane.probs,
                };
                ctl.notify(&entry);
                log.push(entry);
                let updated = self.agent.finish_episode(lane.records)?.is_some();
                if let Some((disp, prefetcher)) = pipeline {
                    if updated && Some(i) == last_update_lane && !next_submitted {
                        // the chunk's final update just ran: overlap the
                        // greedy probe and the remaining lanes' logging with
                        // the next chunk's first-layer forward + speculative
                        // accuracies
                        pending0 =
                            self.submit_next_chunk(disp, prefetcher, lanes, ep + n, &probs0)?;
                        next_submitted = true;
                    }
                }
                if updated
                    && self.cfg.patience > 0
                    && self.greedy_converged(&mut last_greedy, &mut stable_updates)?
                {
                    break 'episodes;
                }
                if updated {
                    if let Some(d) = durable.as_deref_mut() {
                        let ck = self.checkpoint_at(d, ep + i + 1, log, &last_greedy,
                                                    stable_updates);
                        d.on_boundary(ck);
                    }
                }
            }
            ep += n;
        }
        Ok(())
    }

    /// Hand the next chunk's device work to the dispatcher: the speculative
    /// first-step accuracy slate (memo warming, from the current chunk's
    /// layer-0 policy) and the double-buffered first-layer act_batch.
    /// Returns the act pending, or `None` when there is no next chunk or it
    /// would take the scalar act path.
    fn submit_next_chunk(&mut self, disp: &Dispatcher, prefetcher: &Prefetcher, lanes: usize,
                         next_ep: usize, probs0: &[Vec<f32>]) -> Result<Option<ActPending>> {
        if next_ep >= self.cfg.episodes {
            return Ok(None);
        }
        // speculative accuracy prefetch is only useful when the next chunk
        // evaluates its first step (terminal-only nets skip it)
        if self.cfg.eval_every_step && !probs0.is_empty() {
            let cands = self.top_prob_step0_candidates(probs0, self.cfg.pipeline);
            prefetcher.speculate(cands);
        }
        let n_next = lanes.min(self.cfg.episodes - next_ep);
        if n_next <= 1 {
            // a single lane dispatches through the scalar act artifact
            return Ok(None);
        }
        let (states, h, c) = self.layer0_operands(n_next);
        let pending = self.agent.act_batch_submit(&states, &h, &c, disp)?;
        Ok(Some(ActPending { n: n_next, pending }))
    }

    /// The act_batch operands of a fresh chunk's first layer, packed exactly
    /// as [`Searcher::rollout_lockstep`] would pack them: every lane starts
    /// at uniform `bits_max` with `State_A = 1` and zero hidden state, so
    /// the lane states are identical params-independent constants and the
    /// whole stage is computable before the chunk exists.
    fn layer0_operands(&self, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let lanes = self.agent.act_lanes;
        let (h0, _) = self.agent.initial_hidden();
        let hidden = h0.len();
        let bits = vec![self.bits_max; self.env.net.l];
        let state_q = self.env.state_q(&bits);
        let mut s = [0.0f32; STATE_DIM];
        embed(&self.statics, 0, &bits, self.bits_max, 1.0, state_q, &mut s);
        let mut states = vec![0.0f32; lanes * STATE_DIM];
        for i in 0..n {
            states[i * STATE_DIM..(i + 1) * STATE_DIM].copy_from_slice(&s);
        }
        // h0/c0 are zero vectors, matching the zero-filled packing
        (states, vec![0.0f32; lanes * hidden], vec![0.0f32; lanes * hidden])
    }

    /// Nominate up to `budget` speculative first-step candidate vectors for
    /// the next chunk from this chunk's layer-0 lane probabilities: rank
    /// actions by mean probability across lanes, map each to the bits
    /// vector the next chunk would evaluate after taking it at layer 0
    /// (uniform `bits_max` elsewhere), dedup (the action space may clamp
    /// several actions onto one bitwidth).
    fn top_prob_step0_candidates(&self, probs0: &[Vec<f32>], budget: usize) -> Vec<Vec<u32>> {
        let n_actions = self.agent.n_actions;
        let mut mean = vec![0.0f64; n_actions];
        for p in probs0 {
            for (a, &v) in p.iter().enumerate().take(n_actions) {
                mean[a] += v as f64;
            }
        }
        let mut order: Vec<usize> = (0..n_actions).collect();
        order.sort_by(|&a, &b| mean[b].total_cmp(&mean[a]).then(a.cmp(&b)));
        let l = self.env.net.l;
        let mut out: Vec<Vec<u32>> = Vec::with_capacity(budget);
        for &a in order.iter().take(budget) {
            let mut bits = vec![self.bits_max; l];
            bits[0] = self.action_to_bits(a, self.bits_max);
            if !out.contains(&bits) {
                out.push(bits);
            }
        }
        out
    }
}
