//! Lockstep batched rollouts (EXPERIMENTS.md §Perf): advance a whole PPO
//! batch of episodes layer-by-layer instead of episode-by-episode.
//!
//! Per layer the driver pays
//!
//! 1. **one** `agent_*_act_batch` PJRT execution for all B lanes (the serial
//!    driver pays B scalar `act` executions), then
//! 2. `ceil(misses / K)` accuracy executions for the lanes' **distinct
//!    uncached** bits vectors: the candidate slate goes through
//!    `EnvCore::accuracy_batch`, whose batch single-flight protocol shrinks
//!    it by cache hits and scores the misses K lanes at a time via the
//!    vmapped `<net>_retrain_eval_batch` artifact (envs without that
//!    artifact fall back to fanning misses across shard threads inside the
//!    same call).
//!
//! Equivalence with the serial driver: every episode samples from its own
//! per-episode PCG stream (`Searcher::episode_rng`) and `EnvCore::accuracy`
//! is a pure function of the bits vector, so a lanes=1 run replays the
//! serial trajectory bit-for-bit (it even dispatches through the scalar
//! `act` artifact), and a lanes=B run draws the same actions the serial
//! driver would whenever B divides `episodes_per_update` — PPO updates then
//! land on the same episode boundaries — up to the vmapped act_batch
//! artifact agreeing numerically with the scalar act (XLA guarantees this
//! only to float-rounding level; python/tests/test_agent.py pins it at
//! ~1e-5, so parity tests compare converged solutions, not raw
//! trajectories: `rust/tests/rollout_parity.rs`).

use anyhow::Result;

use crate::metrics::{EpisodeLog, SearchLog};
use crate::util::rng::Pcg32;

use super::embedding::{embed, STATE_DIM};
use super::ppo::{PpoAgent, StepRecord};
use super::search::{SearchCtl, SearchResult, Searcher};

/// One episode lane's finished rollout.
pub struct LaneRollout {
    pub bits: Vec<u32>,
    pub probs: Vec<Vec<f32>>,
    pub records: Vec<StepRecord>,
}

impl Searcher {
    /// Roll out `rngs.len()` training episodes in lockstep (lane `i` samples
    /// from `rngs[i]`). Lane count must not exceed the act_batch artifact's
    /// baked width; a single active lane takes the scalar `act` path.
    pub(super) fn rollout_lockstep(&mut self, rngs: &mut [Pcg32]) -> Result<Vec<LaneRollout>> {
        let n = rngs.len();
        let l_total = self.env.net.l;
        let lanes = self.agent.act_lanes;
        anyhow::ensure!(n >= 1, "lockstep rollout needs at least one lane");
        anyhow::ensure!(
            n <= lanes,
            "{n} lanes exceed the act_batch artifact's width {lanes}"
        );
        let (h0, c0) = self.agent.initial_hidden();
        let hidden = h0.len();
        let n_actions = self.agent.n_actions;

        // per-lane episode state (paper §5.1: all layers start at bits_max)
        let mut bits: Vec<Vec<u32>> = vec![vec![self.bits_max; l_total]; n];
        let mut hs: Vec<Vec<f32>> = vec![h0; n];
        let mut cs: Vec<Vec<f32>> = vec![c0; n];
        let mut state_accs = vec![1.0f64; n];
        let mut state_qs: Vec<f64> = bits.iter().map(|b| self.env.state_q(b)).collect();
        let mut out: Vec<LaneRollout> = (0..n)
            .map(|_| LaneRollout {
                bits: Vec::new(),
                probs: Vec::with_capacity(l_total),
                records: Vec::with_capacity(l_total),
            })
            .collect();

        for l in 0..l_total {
            let mut lane_states: Vec<[f32; STATE_DIM]> = Vec::with_capacity(n);
            for i in 0..n {
                let mut s = [0.0f32; STATE_DIM];
                embed(&self.statics, l, &bits[i], self.bits_max, state_accs[i], state_qs[i],
                      &mut s);
                lane_states.push(s);
            }

            // one batched forward for all lanes (scalar act when only one
            // lane is active: cheaper than padding, and bit-identical to the
            // serial rollout — the B=1 parity guarantee)
            let (probs_per_lane, values, new_h, new_c) = if n == 1 {
                let (p, v, h2, c2) = self.agent.act(&lane_states[0], &hs[0], &cs[0])?;
                (vec![p], vec![v], vec![h2], vec![c2])
            } else {
                let mut states = vec![0.0f32; lanes * STATE_DIM];
                let mut hcat = vec![0.0f32; lanes * hidden];
                let mut ccat = vec![0.0f32; lanes * hidden];
                for i in 0..n {
                    states[i * STATE_DIM..(i + 1) * STATE_DIM].copy_from_slice(&lane_states[i]);
                    hcat[i * hidden..(i + 1) * hidden].copy_from_slice(&hs[i]);
                    ccat[i * hidden..(i + 1) * hidden].copy_from_slice(&cs[i]);
                }
                let (pf, vf, hf, cf) = self.agent.act_batch(&states, &hcat, &ccat)?;
                (
                    (0..n).map(|i| pf[i * n_actions..(i + 1) * n_actions].to_vec()).collect(),
                    vf[..n].to_vec(),
                    (0..n).map(|i| hf[i * hidden..(i + 1) * hidden].to_vec()).collect(),
                    (0..n).map(|i| cf[i * hidden..(i + 1) * hidden].to_vec()).collect(),
                )
            };

            let mut actions = Vec::with_capacity(n);
            for i in 0..n {
                let action = PpoAgent::sample(&probs_per_lane[i], &mut rngs[i]);
                bits[i][l] = self.action_to_bits(action, bits[i][l]);
                state_qs[i] = self.env.state_q(&bits[i]);
                hs[i] = new_h[i].clone();
                cs[i] = new_c[i].clone();
                actions.push(action);
            }

            let last = l + 1 == l_total;
            let mut rewards = vec![0.0f32; n];
            if self.cfg.eval_every_step || last {
                // dedup the ≤n distinct candidate vectors and score them as
                // ONE megabatch: hits shrink the batch inside the memo's
                // batch protocol and the remaining misses cost
                // ceil(misses / K) device executions (envs without the
                // batch artifact fan the misses across shard threads
                // inside `accuracy_batch` — the pre-megabatch behavior)
                let mut cands: Vec<Vec<u32>> = Vec::with_capacity(n);
                for b in bits.iter().take(n) {
                    if !cands.contains(b) {
                        cands.push(b.clone());
                    }
                }
                let accs = self.env.accuracy_batch(&cands)?;
                for i in 0..n {
                    let pos = cands.iter().position(|c| c == &bits[i]).expect("deduped above");
                    state_accs[i] = self.env.state_acc_of(accs[pos]);
                    rewards[i] = self.cfg.reward.reward(state_accs[i], state_qs[i]) as f32;
                }
            }

            for i in 0..n {
                out[i].records.push(StepRecord {
                    state: lane_states[i],
                    action: actions[i],
                    logp: probs_per_lane[i][actions[i]].max(1e-8).ln(),
                    value: values[i],
                    reward: rewards[i],
                });
                out[i].probs.push(probs_per_lane[i].clone());
            }
        }

        for (lane, b) in out.iter_mut().zip(bits) {
            lane.bits = b;
        }
        Ok(out)
    }

    /// The batched search loop: lockstep rollouts in chunks of `cfg.lanes`
    /// (default: episodes_per_update, one PPO batch per chunk), with the same
    /// logging, update cadence, and greedy convergence detection as the
    /// serial driver. `ctl` is checked once per lockstep chunk (the batched
    /// equivalent of the serial driver's per-episode boundary).
    pub(super) fn run_batched(&mut self, ctl: &SearchCtl) -> Result<SearchResult> {
        let lanes = if self.cfg.lanes == 0 {
            self.agent.act_lanes.min(self.cfg.ppo.episodes_per_update)
        } else {
            self.cfg.lanes
        };
        anyhow::ensure!(
            lanes >= 1 && lanes <= self.agent.act_lanes,
            "--lanes {lanes} out of range 1..={}",
            self.agent.act_lanes
        );
        let mut log = SearchLog::default();
        let mut stable_updates = 0usize;
        let mut last_greedy: Option<Vec<u32>> = None;
        let mut episodes_run = 0usize;

        let mut ep = 0usize;
        'episodes: while ep < self.cfg.episodes {
            ctl.check()?;
            let n = lanes.min(self.cfg.episodes - ep);
            let mut rngs: Vec<Pcg32> = (ep..ep + n).map(|e| self.episode_rng(e)).collect();
            let batch = self.rollout_lockstep(&mut rngs)?;
            for (i, lane) in batch.into_iter().enumerate() {
                episodes_run = ep + i + 1;
                let reward_sum: f64 = lane.records.iter().map(|r| r.reward as f64).sum();
                let state_acc = self.env.state_acc(&lane.bits)?;
                let state_q = self.env.state_q(&lane.bits);
                let entry = EpisodeLog {
                    episode: ep + i,
                    reward: reward_sum,
                    state_acc,
                    state_q,
                    bits: lane.bits.clone(),
                    probs: lane.probs,
                };
                ctl.notify(&entry);
                log.push(entry);
                let updated = self.agent.finish_episode(lane.records)?.is_some();
                if updated
                    && self.cfg.patience > 0
                    && self.greedy_converged(&mut last_greedy, &mut stable_updates)?
                {
                    break 'episodes;
                }
            }
            ep += n;
        }

        ctl.check()?;
        self.finalize(log, episodes_run)
    }
}
