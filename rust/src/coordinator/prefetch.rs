//! Speculative accuracy prefetch for the pipelined search driver.
//!
//! While the host runs PPO updates, action sampling and episode logging,
//! the device can already be scoring the bitwidth vectors the *next*
//! lockstep chunk is most likely to ask for. The [`Prefetcher`] takes a
//! slate of candidate vectors (the driver derives them from the current
//! chunk's lane policy probabilities — see
//! `Searcher::top_prob_step0_candidates`), filters out everything already
//! memoized or already speculated, and enqueues one
//! `EnvCore::accuracy_batch` call on the [`Dispatcher`].
//!
//! **Memo-warming only.** The prefetch result values are discarded here;
//! they land in the shared single-flight [`AccMemo`] exactly as a real
//! evaluation would, and accuracy is a pure function of the bits vector —
//! so a later real query observes a bit-identical value whether the
//! speculation won the race, lost it (the real query's leader computes and
//! the speculative one coalesces, or vice versa), or never happened.
//! Speculation can waste device work, never change results
//! (`rust/tests/pipeline_parity.rs`).
//!
//! **Budgeted.** The dispatcher's per-artifact in-flight cap bounds how
//! many speculative batches may be outstanding; a refused dispatch rolls
//! its ledger marks back and drops the slate (the driving loop must never
//! stall on speculation). Accounting flows through the env's
//! [`SpecLedger`]: `spec_submitted`/`spec_hits`/`spec_wasted` in
//! `EnvStats`, the CLI report and `GET /v1/stats`. The ledger is shared
//! per env core, so concurrent pipelined searches on one serve session may
//! attribute each other's speculations (one job's `abandon` can count
//! another's still-outstanding key as wasted) — hit counts are then
//! conservative, but `hits <= submitted` and the post-quiescence balance
//! `hits + wasted == submitted` hold regardless.

use crate::parallel::SpecLedger;
use crate::runtime::Dispatcher;

use super::env::QuantEnv;

/// Dispatcher tag for speculative accuracy slates (its in-flight cap is
/// the speculation budget).
pub const SPEC_TAG: &str = "accuracy_prefetch";

pub struct Prefetcher<'a> {
    env: QuantEnv,
    disp: &'a Dispatcher,
}

impl<'a> Prefetcher<'a> {
    pub fn new(env: QuantEnv, disp: &'a Dispatcher) -> Prefetcher<'a> {
        Prefetcher { env, disp }
    }

    fn ledger(&self) -> &SpecLedger {
        self.env.spec()
    }

    /// Enqueue `cands` for memo warming. Already-memoized and
    /// already-outstanding vectors are skipped; if the dispatcher refuses
    /// the slate (speculation budget exhausted) the ledger marks are rolled
    /// back (`begin` counts at mark-time, `cancel` un-counts — a mark a
    /// concurrent consumer claimed in between stays counted, see
    /// [`SpecLedger`]). Returns how many vectors were actually submitted.
    pub fn speculate(&self, cands: Vec<Vec<u32>>) -> usize {
        let slate: Vec<Vec<u32>> = cands
            .into_iter()
            .filter(|c| !self.env.memo().contains(c))
            .filter(|c| self.ledger().begin(c))
            .collect();
        if slate.is_empty() {
            return 0;
        }
        let n = slate.len();
        let env = self.env.clone();
        let task_slate = slate.clone();
        let submitted = self
            .disp
            .try_submit_with(SPEC_TAG, move || {
                // least-loaded placement: pin the worker thread for the
                // duration of this slate so its chunks land on the idlest
                // healthy device instead of competing with the rollout's
                // round-robin stripe (values are device-independent, so
                // placement is purely a throughput choice; on a 1-device
                // pool the pin is Some(0) and changes nothing)
                let _pin = env.engine().pin_least_loaded();
                // values discarded: this call's only job is to publish into
                // the shared memo (or coalesce with whoever beat us to it)
                env.accuracy_batch(&task_slate).map(|_| ())
            })
            .is_some();
        if submitted {
            n
        } else {
            for c in &slate {
                self.ledger().cancel(c);
            }
            0
        }
    }

    /// End of the pipelined search: everything speculated but never claimed
    /// is wasted.
    pub fn abandon(&self) {
        self.ledger().abandon();
    }
}
