//! Durable search state: versioned, checksummed `SearchCheckpoint` files
//! and the [`Durable`] driver that writes them at PPO update boundaries.
//!
//! A ReLeQ search is hundreds of episodes of retrain+eval; losing episode
//! 180/200 to a crash forfeits hours of device time. This module captures
//! everything a resumed run needs to continue **bit-identically**:
//!
//! * the episode index — per-episode PCG streams derive from the base seed
//!   and the episode number alone (`Searcher::episode_rng`), so stream
//!   positions need no explicit serialization;
//! * the downloaded PPO agent state (params + Adam moments + step count),
//!   snapshotted only at update boundaries where no trajectory is pending;
//! * the episode log so far and the convergence-detector state;
//! * the accuracy memo export, so resumed runs re-execute **only**
//!   post-checkpoint episodes (pre-checkpoint evaluations hit the memo —
//!   pinned by exec accounting in `tests/durable_jobs.rs`).
//!
//! Files follow the archive's durability idiom: a `schema_version` stamp,
//! an FNV-1a checksum over the canonical payload, and atomic tmp+rename
//! installation. The rename is wired through the `$RELEQ_FAULTS` seam
//! (action point [`CHECKPOINT_FAULT`]) so chaos tests can tear the write;
//! a torn or corrupt checkpoint is detected at load and the caller falls
//! back to a fresh run — never a hard job failure.
//!
//! f32 tensors (agent params, Adam moments) are persisted as their raw
//! `u32` bit patterns: every bit pattern (±0.0, subnormals, NaN payloads)
//! round-trips exactly through the integer-formatting JSON writer, which a
//! decimal rendering cannot guarantee. Resume bit-identity depends on it.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::metrics::{episodes_json, EpisodeLog};
use crate::runtime::faults::FaultPlan;
use crate::util::fnv::Fnv;
use crate::util::json::Json;

/// Bump on incompatible layout changes; loaders refuse newer files.
pub const CHECKPOINT_SCHEMA_VERSION: u64 = 1;

/// Fault-plan action point consulted between staging a checkpoint's tmp
/// file and renaming it into place (mirrors `registry_install`).
pub const CHECKPOINT_FAULT: &str = "checkpoint_save";

// ---- agent snapshot ----------------------------------------------------------

/// The PPO agent's learnable state at an update boundary: flat parameters,
/// Adam first/second moments, the Adam step count, and the update counter.
/// Captured/applied by `PpoAgent::{snapshot, restore}`.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentSnapshot {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_t: f32,
    pub updates_done: usize,
}

/// f32 slice → JSON array of raw u32 bit patterns (exact round trip).
fn f32_bits_json(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(x.to_bits() as f64)).collect())
}

/// JSON array of u32 bit patterns → f32 vector.
fn f32s_from_bits(j: Option<&Json>, what: &str) -> Result<Vec<f32>> {
    j.and_then(Json::as_arr)
        .with_context(|| format!("checkpoint agent missing `{what}`"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .filter(|n| (0.0..=u32::MAX as f64).contains(n) && n.fract() == 0.0)
                .map(|n| f32::from_bits(n as u32))
                .with_context(|| format!("bad f32 bit pattern in checkpoint `{what}`"))
        })
        .collect()
}

impl AgentSnapshot {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("params", f32_bits_json(&self.params)),
            ("adam_m", f32_bits_json(&self.adam_m)),
            ("adam_v", f32_bits_json(&self.adam_v)),
            ("adam_t", Json::Num(self.adam_t.to_bits() as f64)),
            ("updates_done", Json::Num(self.updates_done as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<AgentSnapshot> {
        Ok(AgentSnapshot {
            params: f32s_from_bits(j.get("params"), "params")?,
            adam_m: f32s_from_bits(j.get("adam_m"), "adam_m")?,
            adam_v: f32s_from_bits(j.get("adam_v"), "adam_v")?,
            adam_t: f32::from_bits(
                j.get("adam_t")
                    .and_then(Json::as_f64)
                    .context("checkpoint agent missing `adam_t`")? as u32,
            ),
            updates_done: j
                .get("updates_done")
                .and_then(Json::as_usize)
                .context("checkpoint agent missing `updates_done`")?,
        })
    }
}

// ---- checkpoint --------------------------------------------------------------

/// One resumable search state, written at a PPO update boundary (no
/// trajectory is pending there, so the agent snapshot alone is complete).
#[derive(Debug, Clone)]
pub struct SearchCheckpoint {
    /// logical network name (operator visibility + a cheap sanity gate)
    pub net: String,
    /// opaque fingerprint of the full search spec; a checkpoint only
    /// resumes a search with the identical fingerprint
    pub search_fp: u64,
    /// episodes fully completed (the resumed loop starts here)
    pub episodes_done: usize,
    /// the episode log so far, with probs (part of the final result)
    pub log: Vec<EpisodeLog>,
    pub agent: AgentSnapshot,
    /// convergence-detector state (`Searcher::greedy_converged`)
    pub last_greedy: Option<Vec<u32>>,
    pub stable_updates: usize,
    /// accuracy memo export — what makes resumed runs skip re-execution
    pub memo: Vec<(Vec<u32>, f64)>,
}

fn checksum_hex(payload: &str) -> String {
    format!("{:016x}", Fnv::new().write_bytes(payload.as_bytes()).finish())
}

impl SearchCheckpoint {
    /// Best-so-far (bits, reward) from the log — the paper's running
    /// solution, surfaced for operators and the fleet replication listing.
    pub fn best(&self) -> Option<(&[u32], f64)> {
        self.log
            .iter()
            .filter(|e| e.reward.is_finite())
            .max_by(|a, b| a.reward.total_cmp(&b.reward))
            .map(|e| (e.bits.as_slice(), e.reward))
    }

    fn payload_json(&self) -> Json {
        let memo = Json::Arr(
            self.memo
                .iter()
                .map(|(bits, acc)| {
                    Json::obj(vec![("bits", Json::arr_u32(bits)), ("acc", Json::Num(*acc))])
                })
                .collect(),
        );
        let best = match self.best() {
            Some((bits, reward)) => Json::obj(vec![
                ("bits", Json::arr_u32(bits)),
                ("reward", Json::Num(reward)),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("schema_version", Json::Num(CHECKPOINT_SCHEMA_VERSION as f64)),
            ("net", Json::Str(self.net.clone())),
            ("search_fp", Json::Str(format!("{:016x}", self.search_fp))),
            ("episodes_done", Json::Num(self.episodes_done as f64)),
            ("log", episodes_json(&self.log, true)),
            ("agent", self.agent.to_json()),
            (
                "last_greedy",
                match &self.last_greedy {
                    Some(b) => Json::arr_u32(b),
                    None => Json::Null,
                },
            ),
            ("stable_updates", Json::Num(self.stable_updates as f64)),
            ("memo", memo),
            ("best", best),
        ])
    }

    /// Full JSON document: the canonical payload plus its checksum.
    pub fn to_json(&self) -> Json {
        let payload = self.payload_json();
        let sum = checksum_hex(&payload.dump());
        match payload {
            Json::Obj(mut m) => {
                m.insert("checksum".to_string(), Json::Str(sum));
                Json::Obj(m)
            }
            _ => unreachable!("payload is an object"),
        }
    }

    /// Decode + verify. The checksum is recomputed over the re-serialized
    /// payload (canonical: sorted keys, shortest-round-trip floats), so any
    /// bit flip, truncation, or hand edit is rejected; a newer
    /// `schema_version` is refused rather than misread.
    pub fn from_json(j: &Json) -> Result<SearchCheckpoint> {
        let obj = j.as_obj().context("checkpoint is not a JSON object")?;
        let schema = j
            .get("schema_version")
            .and_then(Json::as_f64)
            .context("checkpoint missing `schema_version`")? as u64;
        anyhow::ensure!(
            schema <= CHECKPOINT_SCHEMA_VERSION,
            "checkpoint schema_version {schema} is newer than supported {CHECKPOINT_SCHEMA_VERSION}"
        );
        let recorded = j
            .get("checksum")
            .and_then(Json::as_str)
            .context("checkpoint missing `checksum`")?;
        let mut payload = obj.clone();
        payload.remove("checksum");
        let expect = checksum_hex(&Json::Obj(payload).dump());
        anyhow::ensure!(
            recorded == expect,
            "checkpoint checksum mismatch (recorded {recorded}, computed {expect}): \
             corrupt or torn write"
        );
        let log = j
            .get("log")
            .and_then(Json::as_arr)
            .context("checkpoint missing `log`")?
            .iter()
            .map(EpisodeLog::from_json)
            .collect::<Result<Vec<_>>>()?;
        let memo = j
            .get("memo")
            .and_then(Json::as_arr)
            .context("checkpoint missing `memo`")?
            .iter()
            .map(|e| {
                let bits = e
                    .get("bits")
                    .and_then(Json::as_arr)
                    .context("memo entry missing `bits`")?
                    .iter()
                    .map(|b| {
                        b.as_f64()
                            .map(|n| n as u32)
                            .context("non-numeric memo bit")
                    })
                    .collect::<Result<Vec<u32>>>()?;
                let acc = e
                    .get("acc")
                    .and_then(Json::as_f64)
                    .context("memo entry missing `acc`")?;
                Ok((bits, acc))
            })
            .collect::<Result<Vec<_>>>()?;
        let last_greedy = match j.get("last_greedy") {
            None | Some(Json::Null) => None,
            Some(b) => Some(
                b.as_arr()
                    .context("checkpoint `last_greedy` is not an array")?
                    .iter()
                    .map(|x| x.as_f64().map(|n| n as u32).context("bad greedy bit"))
                    .collect::<Result<Vec<u32>>>()?,
            ),
        };
        Ok(SearchCheckpoint {
            net: j
                .get("net")
                .and_then(Json::as_str)
                .context("checkpoint missing `net`")?
                .to_string(),
            search_fp: u64::from_str_radix(
                j.get("search_fp")
                    .and_then(Json::as_str)
                    .context("checkpoint missing `search_fp`")?,
                16,
            )
            .context("checkpoint `search_fp` is not 16-hex")?,
            episodes_done: j
                .get("episodes_done")
                .and_then(Json::as_usize)
                .context("checkpoint missing `episodes_done`")?,
            log,
            agent: AgentSnapshot::from_json(
                j.get("agent").context("checkpoint missing `agent`")?,
            )?,
            last_greedy,
            stable_updates: j
                .get("stable_updates")
                .and_then(Json::as_usize)
                .context("checkpoint missing `stable_updates`")?,
            memo,
        })
    }

    /// Atomically install this checkpoint at `path`: write `<path>.tmp`,
    /// consult the fault plan ([`CHECKPOINT_FAULT`]), then rename. A fault
    /// or I/O error leaves the previous checkpoint (if any) intact and the
    /// tmp file removed.
    pub fn save(&self, path: &Path, faults: Option<&FaultPlan>) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
            }
        }
        let tmp = path.with_extension("tmp");
        let stage = (|| -> Result<()> {
            std::fs::write(&tmp, self.to_json().dump())
                .with_context(|| format!("staging checkpoint {tmp:?}"))?;
            if let Some(f) = faults {
                f.on_exec(CHECKPOINT_FAULT)
                    .context("checkpoint install fault")?;
            }
            std::fs::rename(&tmp, path)
                .with_context(|| format!("installing checkpoint {path:?}"))?;
            Ok(())
        })();
        if stage.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        stage
    }

    /// Load a checkpoint if one exists. `Ok(None)` means no file; `Err`
    /// means a file exists but is unusable (corrupt, torn, newer schema) —
    /// callers count it and fall back to a fresh run.
    pub fn load(path: &Path) -> Result<Option<SearchCheckpoint>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading checkpoint {path:?}")),
        };
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("checkpoint {path:?} is not valid JSON: {e}"))?;
        SearchCheckpoint::from_json(&j)
            .with_context(|| format!("decoding checkpoint {path:?}"))
            .map(Some)
    }
}

// ---- durable driver ----------------------------------------------------------

/// Resume state handed from [`Durable`] (after a successful restore) to the
/// search drivers: where to pick the episode loop back up.
#[derive(Debug)]
pub struct ResumeState {
    pub start: usize,
    pub episodes: Vec<EpisodeLog>,
    pub last_greedy: Option<Vec<u32>>,
    pub stable_updates: usize,
}

/// Checkpoint policy + bookkeeping for one durable search run. The search
/// drivers hand it a fresh [`SearchCheckpoint`] at every update boundary;
/// it persists one every `every` episodes (and stashes the latest boundary
/// in between, so a cancellation can still [`Durable::flush`] a final
/// checkpoint). Save failures are counted and logged, never fatal: a
/// search must not die because its safety net did.
pub struct Durable {
    pub path: PathBuf,
    /// minimum completed episodes between persisted checkpoints (>= 1)
    pub every: usize,
    pub net: String,
    pub search_fp: u64,
    faults: Option<Arc<FaultPlan>>,
    pub saves: u64,
    pub save_failures: u64,
    /// `Some(ep)` when this run restored a checkpoint at episode `ep`
    pub resumed_from: Option<usize>,
    pub(super) last_saved: usize,
    pending: Option<SearchCheckpoint>,
    pub(super) resume: Option<ResumeState>,
}

impl Durable {
    /// A durable driver writing to `path` every `every` episodes, with the
    /// process fault plan (`$RELEQ_FAULTS`) wired into the install path.
    pub fn new(path: PathBuf, every: usize, net: &str, search_fp: u64) -> Result<Durable> {
        let faults = FaultPlan::from_env()?.filter(|p| !p.is_empty());
        Ok(Durable {
            path,
            every: every.max(1),
            net: net.to_string(),
            search_fp,
            faults,
            saves: 0,
            save_failures: 0,
            resumed_from: None,
            last_saved: 0,
            pending: None,
            resume: None,
        })
    }

    /// Replace the fault plan (tests inject torn writes without touching
    /// the process environment).
    pub fn with_fault_plan(mut self, faults: Option<Arc<FaultPlan>>) -> Durable {
        self.faults = faults;
        self
    }

    /// Called by the search drivers at each PPO update boundary. Persists
    /// when `every` episodes have completed since the last save; otherwise
    /// keeps the snapshot in memory for a potential [`Durable::flush`].
    pub fn on_boundary(&mut self, ck: SearchCheckpoint) {
        if ck.episodes_done >= self.last_saved + self.every {
            self.write(&ck);
            self.pending = None;
        } else {
            self.pending = Some(ck);
        }
    }

    /// Persist the newest unsaved boundary snapshot, if any — the "final
    /// checkpoint" on cancellation/shutdown.
    pub fn flush(&mut self) {
        if let Some(ck) = self.pending.take() {
            self.write(&ck);
        }
    }

    /// The search finished: the checkpoint has served its purpose. Removes
    /// the file so a later identical submission starts fresh instead of
    /// resuming into an instant no-op.
    pub fn complete(&mut self) {
        self.pending = None;
        let _ = std::fs::remove_file(&self.path);
    }

    fn write(&mut self, ck: &SearchCheckpoint) {
        match ck.save(&self.path, self.faults.as_deref()) {
            Ok(()) => {
                self.saves += 1;
                self.last_saved = ck.episodes_done;
            }
            Err(e) => {
                self.save_failures += 1;
                eprintln!(
                    "[checkpoint] save to {:?} failed (search continues): {e:#}",
                    self.path
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("releq_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(episodes_done: usize) -> SearchCheckpoint {
        let log = (0..episodes_done)
            .map(|i| EpisodeLog {
                episode: i,
                reward: 0.5 + i as f64 * 0.0625,
                state_acc: 0.9,
                state_q: 4.0 - i as f64 * 0.125,
                bits: vec![8, 4, 2, 8],
                probs: vec![vec![0.125f32; 8]; 4],
            })
            .collect();
        SearchCheckpoint {
            net: "lenet".to_string(),
            search_fp: 0xdead_beef_0123_4567,
            episodes_done,
            log,
            agent: AgentSnapshot {
                params: vec![0.5, -0.25, 1.5e-3, -0.0, f32::MIN_POSITIVE],
                adam_m: vec![0.0; 5],
                adam_v: vec![1e-8; 5],
                adam_t: 2.0,
                updates_done: 1,
            },
            last_greedy: Some(vec![8, 2, 2, 8]),
            stable_updates: 1,
            memo: vec![(vec![8, 4, 2, 8], 0.912345678), (vec![2, 2, 2, 2], 0.5)],
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let ck = sample(4);
        let back =
            SearchCheckpoint::from_json(&Json::parse(&ck.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.net, ck.net);
        assert_eq!(back.search_fp, ck.search_fp);
        assert_eq!(back.episodes_done, 4);
        assert_eq!(back.agent, ck.agent, "agent state must round-trip bit-exactly");
        assert_eq!(back.last_greedy, ck.last_greedy);
        assert_eq!(back.memo.len(), 2);
        assert_eq!(back.memo[0].1.to_bits(), ck.memo[0].1.to_bits());
        assert_eq!(back.log.len(), 4);
        assert_eq!(back.log[3].reward.to_bits(), ck.log[3].reward.to_bits());
        assert_eq!(back.log[3].probs, ck.log[3].probs);
    }

    #[test]
    fn negative_zero_param_survives() {
        let ck = sample(1);
        let back =
            SearchCheckpoint::from_json(&Json::parse(&ck.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.agent.params[3].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn checksum_rejects_tampering() {
        let ck = sample(2);
        let text = ck.to_json().dump();
        let bad = text.replacen("\"episodes_done\":2", "\"episodes_done\":3", 1);
        assert_ne!(text, bad, "test must actually alter the payload");
        let err = SearchCheckpoint::from_json(&Json::parse(&bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn newer_schema_is_refused() {
        let ck = sample(1);
        let mut m = match ck.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("schema_version".to_string(), Json::Num(99.0));
        let err = SearchCheckpoint::from_json(&Json::Obj(m)).unwrap_err();
        assert!(format!("{err:#}").contains("schema_version"), "{err:#}");
    }

    #[test]
    fn load_missing_is_none_and_corrupt_is_err() {
        let dir = tmp_dir("load");
        let path = dir.join("lenet.ckpt.json");
        assert!(SearchCheckpoint::load(&path).unwrap().is_none());
        sample(2).save(&path, None).unwrap();
        assert_eq!(SearchCheckpoint::load(&path).unwrap().unwrap().episodes_done, 2);
        // torn tail: truncate mid-document
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(SearchCheckpoint::load(&path).is_err());
    }

    #[test]
    fn injected_install_fault_leaves_no_file() {
        let dir = tmp_dir("fault");
        let path = dir.join("lenet.ckpt.json");
        let plan = Arc::new(FaultPlan::parse("checkpoint_save:nth=1:perm").unwrap());
        let mut d = Durable::new(path.clone(), 1, "lenet", 7)
            .unwrap()
            .with_fault_plan(Some(plan.clone()));
        d.on_boundary(sample(1));
        assert_eq!(d.save_failures, 1);
        assert_eq!(d.saves, 0);
        assert!(!path.exists(), "faulted install must not leave a checkpoint");
        assert!(!path.with_extension("tmp").exists(), "tmp must be cleaned up");
        assert_eq!(plan.injected(), 1);
        // the next boundary succeeds (nth=1 fired once)
        d.on_boundary(sample(2));
        assert_eq!(d.saves, 1);
        assert!(path.exists());
    }

    #[test]
    fn every_throttles_and_flush_persists_pending() {
        let dir = tmp_dir("every");
        let path = dir.join("net.ckpt.json");
        let mut d = Durable::new(path.clone(), 4, "net", 1).unwrap();
        d.on_boundary(sample(2));
        assert_eq!(d.saves, 0, "below the interval: stashed, not written");
        assert!(!path.exists());
        d.flush();
        assert_eq!(d.saves, 1, "flush persists the stashed boundary");
        assert_eq!(SearchCheckpoint::load(&path).unwrap().unwrap().episodes_done, 2);
        d.on_boundary(sample(4));
        assert_eq!(d.saves, 1, "interval counts from the flushed save");
        d.on_boundary(sample(6));
        assert_eq!(d.saves, 2);
        d.complete();
        assert!(!path.exists(), "complete removes the checkpoint");
    }
}
