//! PPO driver (paper §2.7, §4.7, Table 3): owns the agent parameter/optimizer
//! state, runs the act hot path, accumulates whole-episode trajectories,
//! computes GAE advantages + returns, and applies the AOT `agent_*_update`
//! artifact for the clipped-surrogate Adam steps.
//!
//! Heavy math (LSTM forward, surrogate gradients, Adam) lives in the lowered
//! HLO; this module owns the *algorithm*: trajectory bookkeeping, GAE,
//! advantage normalization, epoch looping — plus action sampling via the
//! deterministic PCG stream.
//!
//! Perf (EXPERIMENTS.md §Perf): `act` is called L times per episode for
//! thousands of episodes, and the parameter vector dominates its operand
//! bytes. The params are therefore kept device-resident — uploaded once per
//! PPO update (lazily, on the first act after an update invalidates them)
//! instead of once per act call. Only the tiny state/h/c vectors transfer
//! per call. The recurrent h'/c' come back to the host because PJRT returns
//! the output tuple as a single host literal; re-uploading them costs
//! `2*hidden` floats, negligible next to the param vector this path saves.
//!
//! Device pool: an agent is bound at construction to the constructing
//! thread's pinned device (device 0 when unpinned — the default, identical
//! to the pre-pool behavior). Every executable it compiles and every
//! operand it uploads lands on that one device, so `run_replicas`' pinned
//! shard threads get whole per-replica agents resident on their own
//! devices instead of serializing act/update traffic through device 0.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{
    lit_f32, lit_scalar, to_f32, to_vec_f32, DeviceBuf, Dispatcher, Engine, Exe, HostLit,
    Manifest, Pending,
};
use crate::util::rng::Pcg32;
use xla::Literal;

use super::embedding::STATE_DIM;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    /// paper's architecture: shared LSTM first layer
    Lstm,
    /// ablation (§2.7): FC encoder instead of the LSTM
    Fc,
}

impl AgentKind {
    pub fn tag(&self) -> &'static str {
        match self {
            AgentKind::Lstm => "lstm",
            AgentKind::Fc => "fc",
        }
    }

    pub fn parse(s: &str) -> Result<AgentKind> {
        match s {
            "lstm" => Ok(AgentKind::Lstm),
            "fc" => Ok(AgentKind::Fc),
            other => anyhow::bail!("unknown agent kind `{other}` (expected lstm|fc)"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct PpoConfig {
    /// PPO clipped-objective epsilon (paper Table 5: 0.1 is best)
    pub clip_eps: f32,
    /// entropy bonus coefficient
    pub ent_coef: f32,
    /// Adam step size. The paper uses 1e-4 over ~1500 episodes; this testbed
    /// runs 200-400 episodes, so the default is 1e-3 to reach the same number
    /// of effective policy improvements (documented in EXPERIMENTS.md).
    pub lr: f32,
    /// epochs per update (paper Table 3: 3)
    pub epochs: usize,
    /// GAE discount (paper Table 3 lists 0.99)
    pub gamma: f64,
    /// GAE lambda
    pub lam: f64,
    /// episodes per update batch (fixed at AOT time)
    pub episodes_per_update: usize,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            clip_eps: 0.1,
            ent_coef: 0.01,
            lr: 1e-3,
            epochs: 3,
            gamma: 0.99,
            lam: 0.95,
            episodes_per_update: 8,
        }
    }
}

/// One agent step's record within an episode.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub state: [f32; STATE_DIM],
    pub action: usize,
    pub logp: f32,
    pub value: f32,
    pub reward: f32,
}

/// Aggregate statistics from one PPO update (averaged over epochs).
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateStats {
    pub pi_loss: f64,
    pub v_loss: f64,
    pub entropy: f64,
    pub approx_kl: f64,
}

/// GAE(γ, λ) over one finite episode (terminal value 0, no bootstrap).
pub fn gae(gamma: f64, lam: f64, ep: &[StepRecord]) -> (Vec<f32>, Vec<f32>) {
    let n = ep.len();
    let mut adv = vec![0.0f32; n];
    let mut ret = vec![0.0f32; n];
    let mut last_adv = 0.0f64;
    for t in (0..n).rev() {
        let next_v = if t + 1 < n { ep[t + 1].value as f64 } else { 0.0 };
        let delta = ep[t].reward as f64 + gamma * next_v - ep[t].value as f64;
        last_adv = delta + gamma * lam * last_adv;
        adv[t] = last_adv as f32;
        ret[t] = (last_adv + ep[t].value as f64) as f32;
    }
    (adv, ret)
}

pub struct PpoAgent {
    pub kind: AgentKind,
    pub cfg: PpoConfig,
    /// episode length this agent instance is bound to (the network's L)
    pub episode_len: usize,
    engine: Arc<Engine>,
    /// pool device this agent's executables and resident operands live on
    /// (the constructing thread's pin, else 0)
    device: usize,
    act_exe: Arc<Exe>,
    /// vectorized act artifact (`agent_*_act_batch`), compiled lazily on the
    /// first `act_batch` call so serial-only runs never pay for it
    act_batch_exe: Option<Arc<Exe>>,
    update_exe: Arc<Exe>,
    pub params: Vec<f32>,
    /// device-resident copy of `params`; uploaded lazily on the first act
    /// after construction or an update, then reused for every act until the
    /// next update invalidates it. `Arc` so an asynchronously dispatched
    /// act_batch keeps the buffer alive even if an update invalidates this
    /// slot while the execution is still in flight.
    params_buf: Option<Arc<DeviceBuf>>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    adam_t: f32,
    hidden: usize,
    pub n_actions: usize,
    /// lanes baked into the `agent_*_act_batch` artifact (manifest
    /// `act_batch`; = episodes_per_update as AOT-compiled)
    pub act_lanes: usize,
    /// finished episodes waiting for the next update
    pending: Vec<Vec<StepRecord>>,
    pub updates_done: usize,
    /// perf counters: host->device transfers of the full param vector, and
    /// act calls served by the resident copy (EXPERIMENTS.md §Perf asserts
    /// uploads == updates+1 over a run)
    pub param_uploads: u64,
    pub act_calls: u64,
    /// lockstep batched forwards: each replaces up to `act_lanes` scalar
    /// `act` dispatches with one PJRT execution
    pub act_batch_calls: u64,
}

impl PpoAgent {
    pub fn new(engine: Arc<Engine>, manifest: &Manifest, kind: AgentKind,
               episode_len: usize, seed: u64, cfg: PpoConfig) -> Result<PpoAgent> {
        anyhow::ensure!(
            manifest.agent.state_dim == STATE_DIM,
            "python STATE_DIM {} != rust {}",
            manifest.agent.state_dim,
            STATE_DIM
        );
        anyhow::ensure!(
            cfg.episodes_per_update == manifest.agent.episodes_per_update,
            "episodes_per_update {} != AOT batch {}",
            cfg.episodes_per_update,
            manifest.agent.episodes_per_update
        );
        let device = engine.current_device();
        let act_exe = engine.exe_on(&format!("agent_{}_act", kind.tag()), device)?;
        let update_exe = engine
            .exe_on(&format!("agent_{}_update_l{}", kind.tag(), episode_len), device)
            .with_context(|| {
                format!("no update artifact for {} episode length {episode_len}", kind.tag())
            })?;
        let init_exe = engine.exe_on(&format!("agent_{}_init", kind.tag()), device)?;
        let out = init_exe.run(&[lit_scalar(seed as f32)])?;
        let params = to_vec_f32(&out[0])?;
        let p = params.len();
        let expect = match kind {
            AgentKind::Lstm => manifest.agent.p_lstm,
            AgentKind::Fc => manifest.agent.p_fc,
        };
        anyhow::ensure!(p == expect, "agent param count {p} != manifest {expect}");
        Ok(PpoAgent {
            kind,
            cfg,
            episode_len,
            engine,
            device,
            act_exe,
            act_batch_exe: None,
            update_exe,
            params,
            params_buf: None,
            adam_m: vec![0.0; p],
            adam_v: vec![0.0; p],
            adam_t: 0.0,
            hidden: manifest.agent.hidden,
            n_actions: manifest.agent.n_actions,
            act_lanes: manifest.agent.act_batch,
            pending: Vec::new(),
            updates_done: 0,
            param_uploads: 0,
            act_calls: 0,
            act_batch_calls: 0,
        })
    }

    /// Fresh recurrent state for an episode.
    pub fn initial_hidden(&self) -> (Vec<f32>, Vec<f32>) {
        (vec![0.0; self.hidden], vec![0.0; self.hidden])
    }

    /// Upload the params to the device if stale (post-update) or never
    /// uploaded. This is the only place the full param vector crosses to the
    /// device on the act path.
    fn ensure_resident_params(&mut self) -> Result<()> {
        if self.params_buf.is_none() {
            self.params_buf = Some(Arc::new(self.engine.buffer_f32_on(
                &self.params,
                &[self.params.len()],
                self.device,
            )?));
            self.param_uploads += 1;
        }
        Ok(())
    }

    /// Policy forward: returns (action-probabilities, value, h', c').
    ///
    /// Hot path: the params operand is device-resident (zero per-call param
    /// uploads between PPO updates); only state/h/c (a few hundred bytes)
    /// transfer per call.
    pub fn act(&mut self, state: &[f32; STATE_DIM], h: &[f32], c: &[f32])
               -> Result<(Vec<f32>, f32, Vec<f32>, Vec<f32>)> {
        self.act_calls += 1;
        self.ensure_resident_params()?;
        let s_buf = self.engine.buffer_f32_on(state, &[STATE_DIM], self.device)?;
        let h_buf = self.engine.buffer_f32_on(h, &[self.hidden], self.device)?;
        let c_buf = self.engine.buffer_f32_on(c, &[self.hidden], self.device)?;
        let params_buf = self.params_buf.as_ref().expect("just ensured");
        let args = [params_buf.raw(), s_buf.raw(), h_buf.raw(), c_buf.raw()];
        let out = self.act_exe.run_b(&args).context("agent act")?;
        Ok((
            to_vec_f32(&out[0])?,
            to_f32(&out[1])?,
            to_vec_f32(&out[2])?,
            to_vec_f32(&out[3])?,
        ))
    }

    /// Vectorized policy forward over `act_lanes` independent lanes: one
    /// PJRT execution where the serial driver would issue `act_lanes`
    /// (EXPERIMENTS.md §Perf). Operands are flattened row-major:
    /// `states[B*STATE_DIM]`, `h`/`c` `[B*hidden]`; returns
    /// `(probs[B*n_actions], values[B], h'[B*hidden], c'[B*hidden])`.
    ///
    /// The params operand is the same device-resident buffer the scalar act
    /// path uses (zero per-call param uploads between PPO updates); only the
    /// lane states/hiddens transfer per call.
    pub fn act_batch(&mut self, states: &[f32], h: &[f32], c: &[f32])
                     -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (exe, params_buf, s_buf, h_buf, c_buf) = self.stage_act_batch(states, h, c)?;
        let args = [params_buf.raw(), s_buf.raw(), h_buf.raw(), c_buf.raw()];
        let out = exe.run_b(&args).context("agent act_batch")?;
        self.act_batch_decode(&out)
    }

    /// Asynchronous [`PpoAgent::act_batch`]: stage the operands, hand the
    /// execution to `disp`, and return immediately. The pipelined rollout
    /// driver uses this to double-buffer the next chunk's first-layer
    /// forward behind the current chunk's host work; decode the joined
    /// result with [`PpoAgent::act_batch_take`]. Counts as an
    /// `act_batch_calls` dispatch at submission (a discarded pending still
    /// executed). Bit-identical to the synchronous call on the same
    /// operands: same artifact, same device-resident params.
    pub fn act_batch_submit(&mut self, states: &[f32], h: &[f32], c: &[f32],
                            disp: &Dispatcher) -> Result<Pending<Vec<HostLit>>> {
        let (exe, params_buf, s_buf, h_buf, c_buf) = self.stage_act_batch(states, h, c)?;
        Ok(disp.submit(exe, vec![params_buf, Arc::new(s_buf), Arc::new(h_buf), Arc::new(c_buf)]))
    }

    /// Decode a joined [`PpoAgent::act_batch_submit`] result.
    pub fn act_batch_take(&self, parts: &[HostLit])
                          -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let refs: Vec<&Literal> = parts.iter().map(|l| l.raw()).collect();
        self.act_batch_decode(&refs)
    }

    /// Shared staging for the sync and async act_batch paths: validate the
    /// operand shapes, lazily compile the artifact, ensure the params are
    /// device-resident, and upload the lane states/hiddens.
    fn stage_act_batch(&mut self, states: &[f32], h: &[f32], c: &[f32])
                       -> Result<(Arc<Exe>, Arc<DeviceBuf>, DeviceBuf, DeviceBuf, DeviceBuf)> {
        let b = self.act_lanes;
        anyhow::ensure!(
            states.len() == b * STATE_DIM && h.len() == b * self.hidden
                && c.len() == b * self.hidden,
            "act_batch operands must cover exactly {b} lanes"
        );
        if self.act_batch_exe.is_none() {
            let exe = self
                .engine
                .exe_on(&format!("agent_{}_act_batch", self.kind.tag()), self.device)
                .with_context(|| {
                    format!(
                        "no act_batch artifact for `{}` — re-run `make artifacts` \
                         (the lockstep driver needs agent_{}_act_batch.hlo.txt)",
                        self.kind.tag(),
                        self.kind.tag()
                    )
                })?;
            self.act_batch_exe = Some(exe);
        }
        self.act_batch_calls += 1;
        self.ensure_resident_params()?;
        let s_buf = self.engine.buffer_f32_on(states, &[b, STATE_DIM], self.device)?;
        let h_buf = self.engine.buffer_f32_on(h, &[b, self.hidden], self.device)?;
        let c_buf = self.engine.buffer_f32_on(c, &[b, self.hidden], self.device)?;
        Ok((
            self.act_batch_exe.clone().expect("just ensured"),
            self.params_buf.clone().expect("just ensured"),
            s_buf,
            h_buf,
            c_buf,
        ))
    }

    /// Shared output decode for the sync and async act_batch paths.
    fn act_batch_decode<L: std::borrow::Borrow<Literal>>(&self, out: &[L])
                        -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let b = self.act_lanes;
        anyhow::ensure!(out.len() >= 4, "act_batch artifact returned too few outputs");
        let probs = to_vec_f32(out[0].borrow())?;
        let values = to_vec_f32(out[1].borrow())?;
        let h2 = to_vec_f32(out[2].borrow())?;
        let c2 = to_vec_f32(out[3].borrow())?;
        anyhow::ensure!(
            probs.len() == b * self.n_actions && values.len() == b,
            "act_batch artifact returned unexpected shapes"
        );
        Ok((probs, values, h2, c2))
    }

    /// The pre-resident-buffer act path (full param vector re-marshalled as a
    /// host literal on every call). Kept for the bench_agent before/after
    /// measurement; not used by the search loop.
    pub fn act_via_literals(&mut self, state: &[f32; STATE_DIM], h: &[f32], c: &[f32])
                            -> Result<(Vec<f32>, f32, Vec<f32>, Vec<f32>)> {
        self.act_calls += 1;
        let args = [
            lit_f32(&self.params, &[self.params.len() as i64])?,
            lit_f32(state, &[STATE_DIM as i64])?,
            lit_f32(h, &[self.hidden as i64])?,
            lit_f32(c, &[self.hidden as i64])?,
        ];
        let out = self.act_exe.run(&args).context("agent act (literals)")?;
        Ok((
            to_vec_f32(&out[0])?,
            to_f32(&out[1])?,
            to_vec_f32(&out[2])?,
            to_vec_f32(&out[3])?,
        ))
    }

    /// Sample an action index from probabilities (deterministic PCG stream).
    pub fn sample(probs: &[f32], rng: &mut Pcg32) -> usize {
        rng.categorical(probs)
    }

    /// Queue a finished episode; triggers a PPO update when the batch fills.
    /// Returns update stats when an update ran.
    pub fn finish_episode(&mut self, episode: Vec<StepRecord>)
                          -> Result<Option<UpdateStats>> {
        anyhow::ensure!(
            episode.len() == self.episode_len,
            "episode length {} != {}",
            episode.len(),
            self.episode_len
        );
        self.pending.push(episode);
        if self.pending.len() < self.cfg.episodes_per_update {
            return Ok(None);
        }
        let batch = std::mem::take(&mut self.pending);
        self.update(&batch).map(Some)
    }

    /// One PPO update: GAE + advantage normalization + `epochs` Adam steps
    /// through the AOT update artifact.
    ///
    /// The batch tensors (states/actions/old_logp/advs/rets) and the scalar
    /// hyperparameters are constant across the epoch loop, so they are
    /// uploaded to the device once per update; only the evolving params and
    /// Adam state (which PJRT returns to the host each epoch) re-transfer
    /// per epoch. Invalidates the resident act-path params on completion.
    pub fn update(&mut self, batch: &[Vec<StepRecord>]) -> Result<UpdateStats> {
        let b = batch.len();
        let l = self.episode_len;
        let d = STATE_DIM;
        let mut states = Vec::with_capacity(b * l * d);
        let mut actions = Vec::with_capacity(b * l);
        let mut old_logp = Vec::with_capacity(b * l);
        let mut advs = Vec::with_capacity(b * l);
        let mut rets = Vec::with_capacity(b * l);
        for ep in batch {
            let (adv, ret) = gae(self.cfg.gamma, self.cfg.lam, ep);
            for (t, s) in ep.iter().enumerate() {
                states.extend_from_slice(&s.state);
                actions.push(s.action as f32);
                old_logp.push(s.logp);
                advs.push(adv[t]);
                rets.push(ret[t]);
            }
        }
        // advantage normalization across the whole batch
        let n = advs.len() as f64;
        let mean = advs.iter().map(|&a| a as f64).sum::<f64>() / n;
        let var = advs.iter().map(|&a| (a as f64 - mean).powi(2)).sum::<f64>() / n;
        let std = var.sqrt().max(1e-6);
        for a in advs.iter_mut() {
            *a = ((*a as f64 - mean) / std) as f32;
        }

        // per-update resident operands (constant across epochs), on this
        // agent's bound device like every other operand it stages
        let e = &self.engine;
        let dev = self.device;
        let states_buf = e.buffer_f32_on(&states, &[b, l, d], dev)?;
        let actions_buf = e.buffer_f32_on(&actions, &[b, l], dev)?;
        let old_logp_buf = e.buffer_f32_on(&old_logp, &[b, l], dev)?;
        let advs_buf = e.buffer_f32_on(&advs, &[b, l], dev)?;
        let rets_buf = e.buffer_f32_on(&rets, &[b, l], dev)?;
        let clip_buf = e.buffer_scalar_on(self.cfg.clip_eps, dev)?;
        let ent_buf = e.buffer_scalar_on(self.cfg.ent_coef, dev)?;
        let lr_buf = e.buffer_scalar_on(self.cfg.lr, dev)?;

        let p = self.params.len();
        let mut stats = UpdateStats::default();
        for _ in 0..self.cfg.epochs {
            // evolving state: PJRT hands these back as host literals each
            // epoch, so they re-upload per epoch (small next to the batch)
            let params_buf = e.buffer_f32_on(&self.params, &[p], dev)?;
            let m_buf = e.buffer_f32_on(&self.adam_m, &[p], dev)?;
            let v_buf = e.buffer_f32_on(&self.adam_v, &[p], dev)?;
            let t_buf = e.buffer_scalar_on(self.adam_t, dev)?;
            let args = [
                params_buf.raw(),
                m_buf.raw(),
                v_buf.raw(),
                t_buf.raw(),
                states_buf.raw(),
                actions_buf.raw(),
                old_logp_buf.raw(),
                advs_buf.raw(),
                rets_buf.raw(),
                clip_buf.raw(),
                ent_buf.raw(),
                lr_buf.raw(),
            ];
            let out = self.update_exe.run_b(&args).context("agent update")?;
            self.params = to_vec_f32(&out[0])?;
            self.adam_m = to_vec_f32(&out[1])?;
            self.adam_v = to_vec_f32(&out[2])?;
            self.adam_t = to_f32(&out[3])?;
            stats.pi_loss += to_f32(&out[4])? as f64;
            stats.v_loss += to_f32(&out[5])? as f64;
            stats.entropy += to_f32(&out[6])? as f64;
            stats.approx_kl += to_f32(&out[7])? as f64;
        }
        let ep_count = self.cfg.epochs as f64;
        stats.pi_loss /= ep_count;
        stats.v_loss /= ep_count;
        stats.entropy /= ep_count;
        stats.approx_kl /= ep_count;
        self.updates_done += 1;
        // the resident act-path copy is stale now; next act re-uploads once
        self.params_buf = None;
        Ok(stats)
    }

    pub fn pending_episodes(&self) -> usize {
        self.pending.len()
    }

    /// Capture the learnable state for a search checkpoint. Only meaningful
    /// at an update boundary (no pending trajectories) — the checkpoint
    /// driver calls it exactly there, so trajectories never serialize.
    pub fn snapshot(&self) -> super::checkpoint::AgentSnapshot {
        debug_assert!(
            self.pending.is_empty(),
            "agent snapshot taken mid-batch: pending trajectories would be lost"
        );
        super::checkpoint::AgentSnapshot {
            params: self.params.clone(),
            adam_m: self.adam_m.clone(),
            adam_v: self.adam_v.clone(),
            adam_t: self.adam_t,
            updates_done: self.updates_done,
        }
    }

    /// Restore a [`super::checkpoint::AgentSnapshot`] captured by
    /// [`PpoAgent::snapshot`]. Invalidates the device-resident params (the
    /// next act re-uploads, exactly as after a PPO update), so a resumed
    /// run's act path is bit-identical to the uninterrupted one.
    pub fn restore(&mut self, s: &super::checkpoint::AgentSnapshot) -> Result<()> {
        let p = self.params.len();
        anyhow::ensure!(
            s.params.len() == p && s.adam_m.len() == p && s.adam_v.len() == p,
            "agent snapshot has {} params, this agent has {p} (different \
             network or architecture)",
            s.params.len()
        );
        self.params = s.params.clone();
        self.adam_m = s.adam_m.clone();
        self.adam_v = s.adam_v.clone();
        self.adam_t = s.adam_t;
        self.updates_done = s.updates_done;
        self.pending.clear();
        self.params_buf = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(value: f32, reward: f32) -> StepRecord {
        StepRecord { state: [0.0; STATE_DIM], action: 0, logp: 0.0, value, reward }
    }

    #[test]
    fn gae_matches_hand_computation() {
        // gamma = lam = 1.0 makes adv[t] = sum(rewards[t..]) - value[t]
        let ep = vec![step(0.5, 1.0), step(0.25, 2.0), step(0.125, 3.0)];
        let (adv, ret) = gae(1.0, 1.0, &ep);
        assert!((adv[0] - (6.0 - 0.5)).abs() < 1e-5);
        assert!((adv[1] - (5.0 - 0.25)).abs() < 1e-5);
        assert!((adv[2] - (3.0 - 0.125)).abs() < 1e-5);
        assert!((ret[0] - 6.0).abs() < 1e-5);
        assert!((ret[2] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn gae_discounting() {
        let ep = vec![step(0.0, 0.0), step(0.0, 1.0)];
        let (adv, _) = gae(0.5, 1.0, &ep);
        assert!((adv[0] - 0.5).abs() < 1e-6);
        assert!((adv[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gae_lambda_zero_is_td() {
        // lam = 0: adv[t] = r[t] + gamma*v[t+1] - v[t]
        let ep = vec![step(0.3, 1.0), step(0.7, 2.0)];
        let (adv, _) = gae(0.9, 0.0, &ep);
        assert!((adv[0] - (1.0 + 0.9 * 0.7 - 0.3)).abs() < 1e-6);
        assert!((adv[1] - (2.0 - 0.7)).abs() < 1e-6);
    }
}
