//! Synthetic dataset substrate (DESIGN.md §7).
//!
//! The paper evaluates on MNIST/CIFAR-10/SVHN/ImageNet, none of which exist in
//! this offline environment. ReLeQ's search only needs a task on which (a) the
//! network trains to a stable reference accuracy and (b) accuracy degrades
//! with aggressive quantization — the search loop (quantized-retrain → eval →
//! reward → PPO) is identical. Each paper dataset is replaced by a
//! deterministic, seeded generator of class-conditional images:
//!
//! * class identity is carried by a mixture of 2-D sinusoid gratings whose
//!   frequencies/phases are class-specific,
//! * per-sample nuisance: random phase jitter, amplitude scaling, Gaussian
//!   pixel noise, and a random spatial shift,
//! * channel count / size / noise level vary per stand-in ("mnist_syn" is
//!   1-channel and easy; "imagenet_syn" is 3-channel, noisier, with more
//!   distractor gratings — so AlexNet/MobileNet face a harder task, as in
//!   the paper).

use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub channels: usize,
    /// base noise std added to every pixel
    pub noise: f32,
    /// number of class-carrying gratings
    pub gratings: usize,
    /// number of class-independent distractor gratings
    pub distractors: usize,
    /// phase jitter amplitude (radians)
    pub jitter: f32,
}

/// Resolve a dataset stand-in by name (the manifest's `dataset` field).
pub fn spec(name: &str) -> DatasetSpec {
    match name {
        "mnist_syn" => DatasetSpec { channels: 1, noise: 0.10, gratings: 3, distractors: 1, jitter: 0.3 },
        "cifar_syn" => DatasetSpec { channels: 3, noise: 0.18, gratings: 3, distractors: 2, jitter: 0.5 },
        "svhn_syn" => DatasetSpec { channels: 3, noise: 0.15, gratings: 3, distractors: 2, jitter: 0.4 },
        "imagenet_syn" => DatasetSpec { channels: 3, noise: 0.25, gratings: 4, distractors: 3, jitter: 0.7 },
        other => panic!("unknown dataset `{other}`"),
    }
}

/// A materialized split: images NHWC flattened, labels as f32 class ids
/// (f32 because the AOT artifacts take labels as f32 operands).
#[derive(Debug, Clone)]
pub struct Split {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub images: Vec<f32>,
    pub labels: Vec<f32>,
}

impl Split {
    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Copy batch `idx` (wrapping) into caller-provided buffers.
    pub fn fill_batch(&self, idx: usize, batch: usize, xs: &mut Vec<f32>, ys: &mut Vec<f32>) {
        xs.clear();
        ys.clear();
        let il = self.image_len();
        for i in 0..batch {
            let s = (idx * batch + i) % self.n;
            xs.extend_from_slice(&self.images[s * il..(s + 1) * il]);
            ys.push(self.labels[s]);
        }
    }
}

/// Deterministic generator.
///
/// `template_seed` defines the *classes* (the grating mixtures) and MUST be
/// shared between the train and validation splits of one task — otherwise the
/// two splits describe different classification problems. `sample_seed`
/// drives the per-sample nuisance (jitter, shifts, noise) and must differ
/// between splits so validation measures generalization.
pub fn generate(name: &str, template_seed: u64, sample_seed: u64, n: usize, hw: usize,
                classes: usize) -> Split {
    let sp = spec(name);
    let mut trng = Pcg32::new(template_seed ^ 0x7e3a_91a7);
    let mut rng = Pcg32::new(sample_seed ^ 0xda7a_5e7);
    // class templates: per class, `gratings` (fx, fy, phase, amp, channel)
    let mut templates = Vec::with_capacity(classes);
    for _ in 0..classes {
        let mut gs = Vec::with_capacity(sp.gratings);
        for _ in 0..sp.gratings {
            gs.push((
                0.5 + 2.5 * trng.next_f32(),         // fx (cycles over image)
                0.5 + 2.5 * trng.next_f32(),         // fy
                std::f32::consts::TAU * trng.next_f32(), // phase
                0.6 + 0.6 * trng.next_f32(),         // amplitude
                trng.below(sp.channels),             // carrier channel
            ));
        }
        templates.push(gs);
    }

    let il = hw * hw * sp.channels;
    let mut images = vec![0.0f32; n * il];
    let mut labels = vec![0.0f32; n];
    let tau = std::f32::consts::TAU;
    for i in 0..n {
        let class = i % classes; // balanced
        labels[i] = class as f32;
        let img = &mut images[i * il..(i + 1) * il];
        let dx = rng.next_f32() * 0.2 - 0.1; // spatial shift (fraction of image)
        let dy = rng.next_f32() * 0.2 - 0.1;
        let gain = 0.8 + 0.4 * rng.next_f32();
        // class-carrying gratings
        for &(fx, fy, ph, amp, ch) in &templates[class] {
            let jit = (rng.next_f32() - 0.5) * 2.0 * sp.jitter;
            for y in 0..hw {
                for x in 0..hw {
                    let u = (x as f32 / hw as f32 + dx) * fx;
                    let v = (y as f32 / hw as f32 + dy) * fy;
                    let val = amp * gain * (tau * (u + v) + ph + jit).sin();
                    img[(y * hw + x) * sp.channels + ch] += val;
                }
            }
        }
        // distractors: class-independent structured noise
        for _ in 0..sp.distractors {
            let fx = 0.5 + 3.0 * rng.next_f32();
            let fy = 0.5 + 3.0 * rng.next_f32();
            let ph = tau * rng.next_f32();
            let amp = 0.3 * rng.next_f32();
            let ch = rng.below(sp.channels);
            for y in 0..hw {
                for x in 0..hw {
                    let u = x as f32 / hw as f32 * fx;
                    let v = y as f32 / hw as f32 * fy;
                    img[(y * hw + x) * sp.channels + ch] += amp * (tau * (u + v) + ph).sin();
                }
            }
        }
        // pixel noise
        for p in img.iter_mut() {
            *p += sp.noise * rng.gaussian();
        }
    }
    Split { n, h: hw, w: hw, c: sp.channels, images, labels }
}

/// Train + validation splits: SAME class templates, disjoint sample seeds.
pub fn train_val(name: &str, seed: u64, n_train: usize, n_val: usize, hw: usize,
                 classes: usize) -> (Split, Split) {
    (
        generate(name, seed, seed.wrapping_mul(2).wrapping_add(1), n_train, hw, classes),
        generate(name, seed, seed.wrapping_mul(2).wrapping_add(2), n_val, hw, classes),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate("cifar_syn", 7, 3, 64, 16, 10);
        let b = generate("cifar_syn", 7, 3, 64, 16, 10);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn seeds_differ() {
        let a = generate("cifar_syn", 1, 1, 16, 16, 10);
        let b = generate("cifar_syn", 1, 2, 16, 16, 10);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn balanced_labels() {
        let a = generate("mnist_syn", 3, 4, 100, 16, 10);
        for c in 0..10 {
            let n = a.labels.iter().filter(|&&l| l == c as f32).count();
            assert_eq!(n, 10);
        }
    }

    #[test]
    fn shapes() {
        let a = generate("mnist_syn", 3, 4, 10, 16, 10);
        assert_eq!(a.c, 1);
        assert_eq!(a.images.len(), 10 * 16 * 16);
        let b = generate("imagenet_syn", 3, 4, 10, 16, 10);
        assert_eq!(b.c, 3);
        assert_eq!(b.images.len(), 10 * 16 * 16 * 3);
    }

    #[test]
    fn class_signal_exceeds_noise() {
        // images of the same class (ignoring per-sample jitter) correlate more
        // than images of different classes
        let a = generate("mnist_syn", 11, 5, 40, 16, 10);
        let il = a.image_len();
        let img = |i: usize| &a.images[i * il..(i + 1) * il];
        let corr = |x: &[f32], y: &[f32]| {
            let n = x.len() as f32;
            let mx = x.iter().sum::<f32>() / n;
            let my = y.iter().sum::<f32>() / n;
            let mut num = 0.0;
            let mut dx = 0.0;
            let mut dy = 0.0;
            for (a, b) in x.iter().zip(y) {
                num += (a - mx) * (b - my);
                dx += (a - mx) * (a - mx);
                dy += (b - my) * (b - my);
            }
            num / (dx.sqrt() * dy.sqrt() + 1e-9)
        };
        // samples 0,10,20,30 are class 0; 1,11 are class 1
        let same = corr(img(0), img(10)) + corr(img(10), img(20));
        let diff = corr(img(0), img(1)) + corr(img(10), img(11));
        assert!(same > diff, "same {same} diff {diff}");
    }

    #[test]
    fn batch_fill_wraps() {
        let a = generate("mnist_syn", 3, 4, 10, 16, 10);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        a.fill_batch(3, 4, &mut xs, &mut ys); // samples 12..16 -> wraps to 2..6
        assert_eq!(xs.len(), 4 * a.image_len());
        assert_eq!(ys, vec![2.0, 3.0, 4.0, 5.0]);
    }
}

#[cfg(test)]
mod split_tests {
    use super::*;

    #[test]
    fn splits_share_templates_but_differ_in_samples() {
        let (tr, va) = train_val("mnist_syn", 9, 40, 40, 16, 10);
        assert_ne!(tr.images, va.images, "splits must not be identical");
        // same class templates: class-0 means across splits correlate strongly
        let il = tr.image_len();
        let mean_img = |s: &Split, class: f32| {
            let mut acc = vec![0.0f32; il];
            let mut n = 0;
            for i in 0..s.n {
                if s.labels[i] == class {
                    for (a, b) in acc.iter_mut().zip(&s.images[i * il..(i + 1) * il]) {
                        *a += b;
                    }
                    n += 1;
                }
            }
            for a in acc.iter_mut() {
                *a /= n as f32;
            }
            acc
        };
        let corr = |x: &[f32], y: &[f32]| {
            let n = x.len() as f32;
            let mx = x.iter().sum::<f32>() / n;
            let my = y.iter().sum::<f32>() / n;
            let (mut num, mut dx, mut dy) = (0.0, 0.0, 0.0);
            for (a, b) in x.iter().zip(y) {
                num += (a - mx) * (b - my);
                dx += (a - mx) * (a - mx);
                dy += (b - my) * (b - my);
            }
            num / (dx.sqrt() * dy.sqrt() + 1e-9)
        };
        let c_same = corr(&mean_img(&tr, 0.0), &mean_img(&va, 0.0));
        let c_cross = corr(&mean_img(&tr, 0.0), &mean_img(&va, 1.0));
        assert!(c_same > 0.5, "class templates not shared: corr {c_same}");
        assert!(c_same > c_cross + 0.2, "{c_same} vs {c_cross}");
    }
}
