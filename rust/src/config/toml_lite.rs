//! Minimal TOML-subset parser for experiment config files (the real `toml`
//! crate is unavailable offline — DESIGN.md §9).
//!
//! Supported grammar: `[section]` / `[section.sub]` headers, `key = value`
//! lines, `#` comments, and scalar values (integer, float, bool, "string")
//! plus flat arrays of scalars. That covers every config this repo ships.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Num(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

pub type Doc = BTreeMap<String, BTreeMap<String, TomlValue>>;

fn parse_scalar(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_scalar(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match s.parse::<f64>() {
        Ok(n) => Ok(TomlValue::Num(n)),
        Err(_) => bail!("cannot parse value `{s}`"),
    }
}

/// Parse a TOML-lite document into section -> key -> value.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // don't strip '#' inside quoted strings
            Some(i) if !raw[..i].contains('"') || raw[..i].matches('"').count() % 2 == 0 => {
                &raw[..i]
            }
            _ => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got `{line}`", lineno + 1);
        };
        let value = parse_scalar(v)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone())
            .or_default()
            .insert(k.trim().to_string(), value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            "# comment\ntop = 1\n[search]\nepisodes = 500 # inline\nlr = 0.05\n\
             reward = \"proposed\"\nflag = true\n[search.lenet]\nepisodes = 300\n",
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Num(1.0));
        assert_eq!(doc["search"]["episodes"], TomlValue::Num(500.0));
        assert_eq!(doc["search"]["lr"], TomlValue::Num(0.05));
        assert_eq!(doc["search"]["reward"], TomlValue::Str("proposed".into()));
        assert_eq!(doc["search"]["flag"], TomlValue::Bool(true));
        assert_eq!(doc["search.lenet"]["episodes"], TomlValue::Num(300.0));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("bits = [2, 3, 4]\nnames = [\"a\", \"b\"]\n").unwrap();
        match &doc[""]["bits"] {
            TomlValue::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("key value\n").is_err());
        assert!(parse("k = @bad\n").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc[""]["s"], TomlValue::Str("a#b".into()));
    }
}
