//! Experiment configuration: a TOML-subset file format + per-network presets
//! + CLI override plumbing, feeding [`crate::coordinator::SearchConfig`].
//!
//! Precedence (lowest to highest): built-in defaults -> network preset ->
//! `--config file.toml` -> individual CLI flags.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{ActionSpace, AgentKind, RewardKind, SearchConfig};
use crate::util::cli::Args;

pub mod toml_lite;

pub use toml_lite::TomlValue;

/// Per-network search presets, tuned for the 1-core CPU-PJRT testbed.
/// Deeper networks get terminal-only accuracy evaluation (paper §3) and
/// fewer episodes; small ones evaluate every step.
pub fn preset(net: &str) -> SearchConfig {
    let mut cfg = SearchConfig::default();
    match net {
        "lenet" => {
            cfg.episodes = 400;
            cfg.env.pretrain_steps = 300;
        }
        "simplenet" => {
            cfg.episodes = 350;
            cfg.env.pretrain_steps = 350;
        }
        "alexnet" | "vgg11" | "svhn10" => {
            // L >= 8: evaluate at episode end (paper §3: "for deeper networks
            // ... we perform this phase after all the bitwidths are selected")
            cfg.episodes = 300;
            cfg.env.pretrain_steps = 400;
            cfg.env.retrain_steps = 3;
            cfg.eval_every_step = false;
        }
        "resnet20" | "mobilenet" => {
            cfg.episodes = 240;
            cfg.env.pretrain_steps = 450;
            // more retrain steps than the shallow nets: deep nets'
            // short-retrain accuracy is noisy and the reward's acc^5 term
            // amplifies that noise (5 is the wall-clock compromise; see
            // EXPERIMENTS.md §Perf on why these nets run the per-step path)
            cfg.env.retrain_steps = 5;
            cfg.eval_every_step = false;
        }
        _ => {}
    }
    cfg
}

/// Apply a parsed TOML-lite table to a SearchConfig.
pub fn apply_toml(cfg: &mut SearchConfig, tbl: &BTreeMap<String, TomlValue>) {
    let f = |v: &TomlValue| v.as_f64().unwrap_or_else(|| panic!("number expected"));
    for (k, v) in tbl {
        match k.as_str() {
            "episodes" => cfg.episodes = f(v) as usize,
            "pretrain_steps" => cfg.env.pretrain_steps = f(v) as usize,
            "retrain_steps" => cfg.env.retrain_steps = f(v) as usize,
            "long_retrain_steps" => cfg.env.long_retrain_steps = f(v) as usize,
            "lr" => cfg.env.lr = f(v) as f32,
            "train_size" => cfg.env.train_size = f(v) as usize,
            "seed" => cfg.seed = f(v) as u64,
            "clip_eps" => cfg.ppo.clip_eps = f(v) as f32,
            "ent_coef" => cfg.ppo.ent_coef = f(v) as f32,
            "agent_lr" => cfg.ppo.lr = f(v) as f32,
            "epochs" => cfg.ppo.epochs = f(v) as usize,
            "gamma" => cfg.ppo.gamma = f(v),
            "lam" => cfg.ppo.lam = f(v),
            "reward" => cfg.reward.kind = RewardKind::parse(v.as_str().unwrap()),
            "reward_a" => cfg.reward.a = f(v),
            "reward_b" => cfg.reward.b = f(v),
            "reward_th" => cfg.reward.th = f(v),
            "agent" => cfg.agent_kind = AgentKind::parse(v.as_str().unwrap()),
            "action_space" => cfg.action_space = ActionSpace::parse(v.as_str().unwrap()),
            "eval_every_step" => cfg.eval_every_step = v.as_bool().unwrap(),
            "min_bits" => cfg.min_bits = f(v) as u32,
            "patience" => cfg.patience = f(v) as usize,
            other => panic!("unknown config key `{other}`"),
        }
    }
}

/// Apply individual CLI flags (highest precedence).
pub fn apply_cli(cfg: &mut SearchConfig, args: &Args) {
    if let Some(v) = args.opt_str("episodes") {
        cfg.episodes = v.parse().expect("--episodes");
    }
    if let Some(v) = args.opt_str("seed") {
        cfg.seed = v.parse().expect("--seed");
    }
    if let Some(v) = args.opt_str("reward") {
        cfg.reward.kind = RewardKind::parse(&v);
    }
    if let Some(v) = args.opt_str("agent") {
        cfg.agent_kind = AgentKind::parse(&v);
    }
    if let Some(v) = args.opt_str("action-space") {
        cfg.action_space = ActionSpace::parse(&v);
    }
    if let Some(v) = args.opt_str("agent-lr") {
        cfg.ppo.lr = v.parse().expect("--agent-lr");
    }
    if let Some(v) = args.opt_str("ent-coef") {
        cfg.ppo.ent_coef = v.parse().expect("--ent-coef");
    }
    if let Some(v) = args.opt_str("clip-eps") {
        cfg.ppo.clip_eps = v.parse().expect("--clip-eps");
    }
    if let Some(v) = args.opt_str("retrain-steps") {
        cfg.env.retrain_steps = v.parse().expect("--retrain-steps");
    }
    if let Some(v) = args.opt_str("pretrain-steps") {
        cfg.env.pretrain_steps = v.parse().expect("--pretrain-steps");
    }
    if let Some(v) = args.opt_str("lr") {
        cfg.env.lr = v.parse().expect("--lr");
    }
    if let Some(v) = args.opt_str("patience") {
        cfg.patience = v.parse().expect("--patience");
    }
    if args.has("eval-at-end") {
        cfg.eval_every_step = false;
    }
}

/// Resolve the full precedence chain for a network.
pub fn resolve(net: &str, args: &Args) -> Result<SearchConfig> {
    let mut cfg = preset(net);
    if let Some(path) = args.opt_str("config") {
        let text = std::fs::read_to_string(Path::new(&path))
            .with_context(|| format!("reading config {path}"))?;
        let doc = toml_lite::parse(&text).with_context(|| format!("parsing {path}"))?;
        // global [search] section, then per-network [search.<net>]
        if let Some(tbl) = doc.get("search") {
            apply_toml(&mut cfg, tbl);
        }
        if let Some(tbl) = doc.get(&format!("search.{net}")) {
            apply_toml(&mut cfg, tbl);
        }
    }
    apply_cli(&mut cfg, args);
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(std::iter::once("releq".into()).chain(s.split_whitespace().map(String::from)))
    }

    #[test]
    fn presets_differ_by_depth() {
        assert!(preset("lenet").eval_every_step);
        assert!(!preset("mobilenet").eval_every_step);
        assert!(preset("lenet").episodes > preset("mobilenet").episodes);
    }

    #[test]
    fn cli_overrides_preset() {
        let cfg = resolve("lenet", &args("search --net lenet --episodes 7 --reward diff")).unwrap();
        assert_eq!(cfg.episodes, 7);
        assert_eq!(cfg.reward.kind, RewardKind::Diff);
    }

    #[test]
    fn toml_then_cli_precedence() {
        let dir = std::env::temp_dir().join("releq_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(&p, "[search]\nepisodes = 50\nseed = 3\n[search.lenet]\nepisodes = 60\n")
            .unwrap();
        let a = args(&format!("search --config {} --seed 9", p.display()));
        let cfg = resolve("lenet", &a).unwrap();
        assert_eq!(cfg.episodes, 60); // per-net toml beats global toml
        assert_eq!(cfg.seed, 9); // cli beats toml
    }
}
