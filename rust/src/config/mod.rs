//! Experiment configuration: a TOML-subset file format + per-network presets
//! + CLI override plumbing, feeding [`crate::coordinator::SearchConfig`] —
//! plus the `releq serve` job/daemon config layer.
//!
//! Precedence (lowest to highest): built-in defaults -> network preset ->
//! `--config file.toml` -> individual CLI flags. A serve job resolves the
//! same chain with its JSON `config` object in place of the TOML file: both
//! formats funnel through one key table ([`apply_kv`] via [`Val`]), so a
//! key accepted in `releq.toml` is accepted verbatim in `POST /v1/jobs`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{ActionSpace, AgentKind, RewardKind, RolloutMode, SearchConfig};
use crate::util::cli::Args;
use crate::util::json::Json;

pub mod toml_lite;

pub use toml_lite::TomlValue;

/// Per-network search presets, tuned for the 1-core CPU-PJRT testbed.
/// Deeper networks get terminal-only accuracy evaluation (paper §3) and
/// fewer episodes; small ones evaluate every step.
pub fn preset(net: &str) -> SearchConfig {
    let mut cfg = SearchConfig::default();
    match net {
        "lenet" => {
            cfg.episodes = 400;
            cfg.env.pretrain_steps = 300;
        }
        "simplenet" => {
            cfg.episodes = 350;
            cfg.env.pretrain_steps = 350;
        }
        "alexnet" | "vgg11" | "svhn10" => {
            // L >= 8: evaluate at episode end (paper §3: "for deeper networks
            // ... we perform this phase after all the bitwidths are selected")
            cfg.episodes = 300;
            cfg.env.pretrain_steps = 400;
            cfg.env.retrain_steps = 3;
            cfg.eval_every_step = false;
        }
        "resnet20" | "mobilenet" => {
            cfg.episodes = 240;
            cfg.env.pretrain_steps = 450;
            // more retrain steps than the shallow nets: deep nets'
            // short-retrain accuracy is noisy and the reward's acc^5 term
            // amplifies that noise (5 is the wall-clock compromise; see
            // EXPERIMENTS.md §Perf on why these nets run the per-step path)
            cfg.env.retrain_steps = 5;
            cfg.eval_every_step = false;
        }
        _ => {}
    }
    cfg
}

/// A borrowed scalar config value — the common shape of a TOML-lite value
/// and a JSON value, so the config file layer and the serve job layer flow
/// through one [`apply_kv`] key table instead of two drifting copies.
enum Val<'a> {
    Num(f64),
    Bool(bool),
    Str(&'a str),
}

impl<'a> Val<'a> {
    fn from_toml(v: &'a TomlValue) -> Option<Val<'a>> {
        match v {
            TomlValue::Num(n) => Some(Val::Num(*n)),
            TomlValue::Bool(b) => Some(Val::Bool(*b)),
            TomlValue::Str(s) => Some(Val::Str(s)),
            TomlValue::Arr(_) => None,
        }
    }

    fn from_json(v: &'a Json) -> Option<Val<'a>> {
        match v {
            Json::Num(n) => Some(Val::Num(*n)),
            Json::Bool(b) => Some(Val::Bool(*b)),
            Json::Str(s) => Some(Val::Str(s)),
            _ => None,
        }
    }

    fn num(&self, k: &str) -> Result<f64> {
        match self {
            Val::Num(n) => Ok(*n),
            _ => anyhow::bail!("config key `{k}` expects a number"),
        }
    }

    fn str(&self, k: &str) -> Result<&'a str> {
        match self {
            Val::Str(s) => Ok(s),
            _ => anyhow::bail!("config key `{k}` expects a string"),
        }
    }

    fn bool(&self, k: &str) -> Result<bool> {
        match self {
            Val::Bool(b) => Ok(*b),
            _ => anyhow::bail!("config key `{k}` expects a bool"),
        }
    }
}

/// Apply one `key = value` to a SearchConfig — THE key table, shared by the
/// TOML file layer and the serve job-JSON layer. Unknown keys and malformed
/// values surface as errors, not panics.
fn apply_kv(cfg: &mut SearchConfig, k: &str, v: &Val) -> Result<()> {
    match k {
        "episodes" => cfg.episodes = v.num(k)? as usize,
        "pretrain_steps" => cfg.env.pretrain_steps = v.num(k)? as usize,
        "retrain_steps" => cfg.env.retrain_steps = v.num(k)? as usize,
        "long_retrain_steps" => cfg.env.long_retrain_steps = v.num(k)? as usize,
        "lr" => cfg.env.lr = v.num(k)? as f32,
        "train_size" => cfg.env.train_size = v.num(k)? as usize,
        "memo_cap" => cfg.env.memo_cap = v.num(k)? as usize,
        "eval_batch" => cfg.env.eval_batch = v.num(k)? as usize,
        "seed" => cfg.seed = v.num(k)? as u64,
        "clip_eps" => cfg.ppo.clip_eps = v.num(k)? as f32,
        "ent_coef" => cfg.ppo.ent_coef = v.num(k)? as f32,
        "agent_lr" => cfg.ppo.lr = v.num(k)? as f32,
        "epochs" => cfg.ppo.epochs = v.num(k)? as usize,
        "gamma" => cfg.ppo.gamma = v.num(k)?,
        "lam" => cfg.ppo.lam = v.num(k)?,
        "reward" => cfg.reward.kind = RewardKind::parse(v.str(k)?)?,
        "reward_a" => cfg.reward.a = v.num(k)?,
        "reward_b" => cfg.reward.b = v.num(k)?,
        "reward_th" => cfg.reward.th = v.num(k)?,
        "agent" => cfg.agent_kind = AgentKind::parse(v.str(k)?)?,
        "action_space" => cfg.action_space = ActionSpace::parse(v.str(k)?)?,
        "rollout" => cfg.rollout = RolloutMode::parse(v.str(k)?)?,
        "lanes" => cfg.lanes = v.num(k)? as usize,
        "pipeline" => cfg.pipeline = v.num(k)? as usize,
        "devices" => {
            let n = v.num(k)? as usize;
            anyhow::ensure!(n >= 1, "config key `devices` must be >= 1");
            cfg.devices = n;
        }
        "watchdog_ms" => cfg.watchdog_ms = v.num(k)? as u64,
        "eval_every_step" => cfg.eval_every_step = v.bool(k)?,
        "min_bits" => cfg.min_bits = v.num(k)? as u32,
        "patience" => cfg.patience = v.num(k)? as usize,
        other => anyhow::bail!("unknown config key `{other}`"),
    }
    Ok(())
}

/// Apply a parsed TOML-lite table to a SearchConfig.
pub fn apply_toml(cfg: &mut SearchConfig, tbl: &BTreeMap<String, TomlValue>) -> Result<()> {
    for (k, v) in tbl {
        let v = Val::from_toml(v)
            .with_context(|| format!("config key `{k}` expects a scalar value"))?;
        apply_kv(cfg, k, &v)?;
    }
    Ok(())
}

/// Apply a job-JSON `config` object to a SearchConfig — the serve wire
/// format's counterpart of [`apply_toml`], same keys, same validation.
pub fn apply_json(cfg: &mut SearchConfig, obj: &BTreeMap<String, Json>) -> Result<()> {
    for (k, v) in obj {
        let v = Val::from_json(v)
            .with_context(|| format!("config key `{k}` expects a scalar value"))?;
        apply_kv(cfg, k, &v)?;
    }
    Ok(())
}

/// Result-returning numeric flag parse, shared by [`apply_cli`] and
/// [`serve_config`]: `Ok(None)` when absent, an error naming the flag on a
/// malformed value.
fn flag_num<T: std::str::FromStr>(args: &Args, flag: &str) -> Result<Option<T>> {
    match args.opt_str(flag) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| anyhow::anyhow!("--{flag} expects a number, got `{v}`")),
    }
}

/// Apply individual CLI flags (highest precedence). Bad flag values are
/// reported as errors naming the flag.
pub fn apply_cli(cfg: &mut SearchConfig, args: &Args) -> Result<()> {
    if let Some(v) = flag_num(args, "episodes")? {
        cfg.episodes = v;
    }
    if let Some(v) = flag_num(args, "seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.opt_str("reward") {
        cfg.reward.kind = RewardKind::parse(&v)?;
    }
    if let Some(v) = args.opt_str("agent") {
        cfg.agent_kind = AgentKind::parse(&v)?;
    }
    if let Some(v) = args.opt_str("action-space") {
        cfg.action_space = ActionSpace::parse(&v)?;
    }
    if let Some(v) = args.opt_str("rollout") {
        cfg.rollout = RolloutMode::parse(&v)?;
    }
    if let Some(v) = flag_num(args, "lanes")? {
        cfg.lanes = v;
    }
    if let Some(v) = flag_num(args, "pipeline")? {
        cfg.pipeline = v;
    }
    if let Some(v) = flag_num(args, "devices")? {
        anyhow::ensure!(v >= 1, "--devices must be >= 1");
        cfg.devices = v;
    }
    if let Some(v) = flag_num(args, "watchdog-ms")? {
        cfg.watchdog_ms = v;
    }
    if let Some(v) = flag_num(args, "eval-batch")? {
        cfg.env.eval_batch = v;
    }
    if let Some(v) = flag_num(args, "agent-lr")? {
        cfg.ppo.lr = v;
    }
    if let Some(v) = flag_num(args, "ent-coef")? {
        cfg.ppo.ent_coef = v;
    }
    if let Some(v) = flag_num(args, "clip-eps")? {
        cfg.ppo.clip_eps = v;
    }
    if let Some(v) = flag_num(args, "retrain-steps")? {
        cfg.env.retrain_steps = v;
    }
    if let Some(v) = flag_num(args, "pretrain-steps")? {
        cfg.env.pretrain_steps = v;
    }
    if let Some(v) = flag_num(args, "lr")? {
        cfg.env.lr = v;
    }
    if let Some(v) = flag_num(args, "patience")? {
        cfg.patience = v;
    }
    if args.has("eval-at-end") {
        cfg.eval_every_step = false;
    }
    Ok(())
}

/// Resolve the full precedence chain for a network.
pub fn resolve(net: &str, args: &Args) -> Result<SearchConfig> {
    let mut cfg = preset(net);
    if let Some(path) = args.opt_str("config") {
        let text = std::fs::read_to_string(Path::new(&path))
            .with_context(|| format!("reading config {path}"))?;
        let doc = toml_lite::parse(&text).with_context(|| format!("parsing {path}"))?;
        // global [search] section, then per-network [search.<net>]
        if let Some(tbl) = doc.get("search") {
            apply_toml(&mut cfg, tbl).with_context(|| format!("config {path} [search]"))?;
        }
        if let Some(tbl) = doc.get(&format!("search.{net}")) {
            apply_toml(&mut cfg, tbl)
                .with_context(|| format!("config {path} [search.{net}]"))?;
        }
    }
    apply_cli(&mut cfg, args)?;
    Ok(cfg)
}

// ---- network names ----------------------------------------------------------

/// Validate a client-supplied network name — the one gate shared by job JSON
/// (`POST /v1/jobs`) and registry manifests (`POST /v1/networks`). Names
/// become path components (registry source/install dirs) and artifact-name
/// prefixes, so the charset is a strict identifier alphabet: path separators,
/// `.` (and with it `..`), and `@` (reserved for the registry's
/// digest-qualified names) are all structurally impossible.
pub fn validate_net_name(name: &str) -> Result<()> {
    anyhow::ensure!(!name.is_empty(), "network name must be non-empty");
    anyhow::ensure!(
        name.len() <= 64,
        "network name too long ({} chars, max 64)",
        name.len()
    );
    anyhow::ensure!(
        name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-'),
        "network name `{name}` may only contain [A-Za-z0-9_-]"
    );
    Ok(())
}

// ---- bitwidth lists ---------------------------------------------------------

/// Validate a bitwidth list — the one gate shared by CLI `--bits`, archive
/// records and job JSON (so all entry points reject the same garbage).
pub fn validate_bits(bits: &[u32]) -> Result<()> {
    anyhow::ensure!(!bits.is_empty(), "empty bitwidth list");
    for &b in bits {
        anyhow::ensure!((1..=32).contains(&b), "bitwidth {b} out of range 1..=32");
    }
    Ok(())
}

/// Parse a comma-separated bitwidth list (`"8,4,4,8"`), validated.
pub fn parse_bits(s: &str) -> Result<Vec<u32>> {
    let bits = s
        .split(',')
        .map(|t| {
            let t = t.trim();
            t.parse()
                .map_err(|_| anyhow::anyhow!("bad bitwidth `{t}` (expected e.g. 8,4,4,8)"))
        })
        .collect::<Result<Vec<u32>>>()?;
    validate_bits(&bits)?;
    Ok(bits)
}

/// Decode a JSON bitwidth array, validated through the same gate.
pub fn bits_from_json(v: &Json) -> Result<Vec<u32>> {
    let arr = v.as_arr().context("expected a bits array")?;
    let bits = arr
        .iter()
        .map(|x| {
            x.as_f64()
                .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                .map(|f| f as u32)
                .context("bits array entries must be non-negative integers")
        })
        .collect::<Result<Vec<u32>>>()?;
    validate_bits(&bits)?;
    Ok(bits)
}

// ---- serve: job + daemon config ---------------------------------------------

/// One decoded `POST /v1/jobs` request: the target network, the fully
/// resolved search config (network preset -> job `config` overrides), and
/// an optional wall-clock deadline (measured from submission, so queue wait
/// counts).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub net: String,
    pub cfg: SearchConfig,
    pub deadline_ms: Option<u64>,
    /// client-supplied dedupe key: a resubmission carrying the same key
    /// returns the original job instead of queueing a duplicate (the fleet
    /// router stamps one on every forwarded job so a retried POST after a
    /// dropped keep-alive response can never double-run)
    pub idempotency_key: Option<String>,
    /// the original request body, journaled verbatim into the job WAL so a
    /// recovered job re-decodes through [`job_from_json`] with full fidelity
    pub raw: Json,
}

/// Validate a client-supplied idempotency key: same strictness philosophy as
/// [`validate_net_name`] — the key lands in WAL records and stats output, so
/// keep the charset boring.
pub fn validate_idempotency_key(key: &str) -> Result<()> {
    anyhow::ensure!(!key.is_empty(), "idempotency_key must be non-empty");
    anyhow::ensure!(
        key.len() <= 80,
        "idempotency_key too long ({} chars, max 80)",
        key.len()
    );
    anyhow::ensure!(
        key.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-' || b == b'.'),
        "idempotency_key may only contain [A-Za-z0-9._-]"
    );
    Ok(())
}

/// Decode a job submission. The `config` object accepts exactly the keys a
/// `[search]` TOML section accepts (one shared [`apply_kv`] table), and the
/// top level is equally strict — a typo like `deadline` for `deadline_ms`
/// must 400, not silently run with no deadline.
pub fn job_from_json(j: &Json) -> Result<JobSpec> {
    let obj = j.as_obj().context("job body must be a JSON object")?;
    for k in obj.keys() {
        anyhow::ensure!(
            matches!(k.as_str(), "net" | "config" | "deadline_ms" | "idempotency_key"),
            "unknown job key `{k}` (expected net, config, deadline_ms, idempotency_key)"
        );
    }
    let net = j
        .get("net")
        .and_then(Json::as_str)
        .context("job needs a string `net` field")?
        .to_string();
    validate_net_name(&net)?;
    let mut cfg = preset(&net);
    if let Some(c) = j.get("config") {
        let obj = c.as_obj().context("job `config` must be an object")?;
        apply_json(&mut cfg, obj)?;
    }
    let deadline_ms = match j.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_f64()
                .filter(|x| *x >= 0.0)
                .context("`deadline_ms` must be a non-negative number")? as u64,
        ),
    };
    let idempotency_key = match j.get("idempotency_key") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let key = v.as_str().context("`idempotency_key` must be a string")?;
            validate_idempotency_key(key)?;
            Some(key.to_string())
        }
    };
    Ok(JobSpec { net, cfg, deadline_ms, idempotency_key, raw: j.clone() })
}

/// `releq serve` daemon configuration (see `serve::Server`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// listen address (`--addr`; port 0 binds an ephemeral port)
    pub addr: String,
    /// worker threads executing searches (`--workers`)
    pub workers: usize,
    /// queued-job bound before submissions get 429 (`--queue-cap`)
    pub queue_cap: usize,
    /// solution archive path (`--archive`)
    pub archive: PathBuf,
    /// episodes kept in each job's live log tail (`--log-tail`)
    pub log_tail: usize,
    /// accuracy-memo entries persisted per archive record for warm-starts
    /// (`--memo-persist`)
    pub memo_persist: usize,
    /// per-job retry budget for transient execution failures
    /// (`--job-retries`; 0 disables retries)
    pub job_retries: u32,
    /// consecutive failures on one session key before the cached env is
    /// quarantined: evicted and rebuilt once, then poisoned
    /// (`--quarantine-k`; 0 disables quarantine)
    pub quarantine_k: u32,
    /// consecutive job failures across the scheduler before the circuit
    /// breaker opens and submissions shed with 503 until a job completes
    /// (`--breaker-fails`; 0 disables the breaker)
    pub breaker_fails: u32,
    /// content-addressed install cache for `POST /v1/networks`
    /// (`--registry-dir`; absent = registration disabled, resolution still
    /// serves the startup manifest)
    pub registry_dir: Option<PathBuf>,
    /// emit one structured JSON access-log line per request to stderr
    /// (`--access-log`; same line shape as the fleet router's)
    pub access_log: bool,
    /// write-ahead job journal path (`--wal`; absent = no journal). Job
    /// submissions and status transitions append here fsync'd; on restart
    /// incomplete jobs are recovered and re-enqueued under their old ids.
    pub wal: Option<PathBuf>,
    /// search checkpoint directory (`--checkpoint-dir`; absent = searches
    /// run without checkpoints). Recovered and resubmitted jobs resume from
    /// the latest valid checkpoint instead of restarting.
    pub checkpoint_dir: Option<PathBuf>,
    /// episodes between checkpoint writes (`--checkpoint-every`; writes
    /// land on the nearest PPO update boundary at or after the mark)
    pub checkpoint_every: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7463".to_string(),
            workers: 2,
            queue_cap: 64,
            archive: PathBuf::from("archive.json"),
            log_tail: 32,
            memo_persist: 256,
            job_retries: 2,
            quarantine_k: 3,
            breaker_fails: 8,
            registry_dir: None,
            access_log: false,
            wal: None,
            checkpoint_dir: None,
            checkpoint_every: 8,
        }
    }
}

/// Resolve the serve daemon config from CLI flags, with the same
/// Result-returning discipline as [`apply_cli`].
pub fn serve_config(args: &Args) -> Result<ServeConfig> {
    let mut c = ServeConfig::default();
    c.addr = args.str_of("addr", &c.addr);
    if let Some(v) = flag_num(args, "workers")? {
        anyhow::ensure!(v >= 1, "--workers must be >= 1");
        c.workers = v;
    }
    if let Some(v) = flag_num(args, "queue-cap")? {
        anyhow::ensure!(v >= 1, "--queue-cap must be >= 1");
        c.queue_cap = v;
    }
    if let Some(v) = args.opt_str("archive") {
        c.archive = PathBuf::from(v);
    }
    if let Some(v) = flag_num(args, "log-tail")? {
        c.log_tail = v;
    }
    if let Some(v) = flag_num(args, "memo-persist")? {
        c.memo_persist = v;
    }
    if let Some(v) = flag_num(args, "job-retries")? {
        c.job_retries = v;
    }
    if let Some(v) = flag_num(args, "quarantine-k")? {
        c.quarantine_k = v;
    }
    if let Some(v) = flag_num(args, "breaker-fails")? {
        c.breaker_fails = v;
    }
    if let Some(v) = args.opt_str("registry-dir") {
        c.registry_dir = Some(PathBuf::from(v));
    }
    c.access_log = args.has("access-log");
    if let Some(v) = args.opt_str("wal") {
        c.wal = Some(PathBuf::from(v));
    }
    if let Some(v) = args.opt_str("checkpoint-dir") {
        c.checkpoint_dir = Some(PathBuf::from(v));
    }
    if let Some(v) = flag_num(args, "checkpoint-every")? {
        anyhow::ensure!(v >= 1usize, "--checkpoint-every must be >= 1");
        c.checkpoint_every = v;
    }
    Ok(c)
}

/// `releq fleet` configuration: the front-end router plus the worker set
/// it spawns or joins.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// router listen address (`--addr`; port 0 binds an ephemeral port)
    pub addr: String,
    /// `releq serve` child processes to spawn on ephemeral ports
    /// (`--spawn-workers`)
    pub spawn_workers: usize,
    /// already-running workers to join, comma-separated `host:port` list
    /// (`--worker-addrs`; the flags map holds one value per flag, so the
    /// list is one comma-separated token rather than a repeated flag)
    pub worker_addrs: Vec<String>,
    /// merged fleet archive path (`--archive`); spawned worker i gets
    /// `<stem>.w{i}.json` beside it
    pub archive: PathBuf,
    /// worker threads per SPAWNED worker (`--worker-threads`)
    pub worker_threads: usize,
    /// queue cap per SPAWNED worker (`--worker-queue-cap`)
    pub worker_queue_cap: usize,
    /// ms between archive pull-merge rounds (`--merge-interval-ms`;
    /// 0 = only on demand via `POST /v1/fleet/merge` and at shutdown)
    pub merge_interval_ms: u64,
    /// ms between `/v1/health` polls of each worker (`--health-interval-ms`)
    pub health_interval_ms: u64,
    /// extra ring successors tried when the home worker answers 429
    /// (`--steal-budget`; 0 = never steal, pass the 429 through)
    pub steal_budget: usize,
    /// structured access-log lines on the router (and forwarded to
    /// spawned workers) (`--access-log`)
    pub access_log: bool,
    /// durable fleet mode (`--durable`): spawned worker i gets a job WAL at
    /// `<stem>.w{i}.wal` and a checkpoint dir at `<stem>.w{i}.ckpt` beside
    /// the fleet archive, checkpoints replicate between workers during merge
    /// rounds, and jobs in flight on a worker that goes Down are
    /// re-dispatched to its ring successor
    pub durable: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            addr: "127.0.0.1:7470".to_string(),
            spawn_workers: 0,
            worker_addrs: Vec::new(),
            archive: PathBuf::from("fleet_archive.json"),
            worker_threads: 2,
            worker_queue_cap: 64,
            merge_interval_ms: 5000,
            health_interval_ms: 1000,
            steal_budget: 1,
            access_log: false,
            durable: false,
        }
    }
}

/// Resolve the fleet router config from CLI flags. A fleet with no workers
/// at all is a configuration error, caught here rather than at the first
/// unroutable job.
pub fn fleet_config(args: &Args) -> Result<FleetConfig> {
    let mut c = FleetConfig::default();
    c.addr = args.str_of("addr", &c.addr);
    if let Some(v) = flag_num(args, "spawn-workers")? {
        c.spawn_workers = v;
    }
    if let Some(v) = args.opt_str("worker-addrs") {
        c.worker_addrs = v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
    }
    anyhow::ensure!(
        c.spawn_workers + c.worker_addrs.len() >= 1,
        "a fleet needs workers: pass --spawn-workers N and/or --worker-addrs host:port,..."
    );
    if let Some(v) = args.opt_str("archive") {
        c.archive = PathBuf::from(v);
    }
    if let Some(v) = flag_num(args, "worker-threads")? {
        anyhow::ensure!(v >= 1, "--worker-threads must be >= 1");
        c.worker_threads = v;
    }
    if let Some(v) = flag_num(args, "worker-queue-cap")? {
        anyhow::ensure!(v >= 1, "--worker-queue-cap must be >= 1");
        c.worker_queue_cap = v;
    }
    if let Some(v) = flag_num(args, "merge-interval-ms")? {
        c.merge_interval_ms = v;
    }
    if let Some(v) = flag_num(args, "health-interval-ms")? {
        anyhow::ensure!(v >= 1, "--health-interval-ms must be >= 1");
        c.health_interval_ms = v;
    }
    if let Some(v) = flag_num(args, "steal-budget")? {
        c.steal_budget = v;
    }
    c.access_log = args.has("access-log");
    c.durable = args.has("durable");
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(std::iter::once("releq".into()).chain(s.split_whitespace().map(String::from)))
    }

    #[test]
    fn presets_differ_by_depth() {
        assert!(preset("lenet").eval_every_step);
        assert!(!preset("mobilenet").eval_every_step);
        assert!(preset("lenet").episodes > preset("mobilenet").episodes);
    }

    #[test]
    fn cli_overrides_preset() {
        let cfg = resolve("lenet", &args("search --net lenet --episodes 7 --reward diff")).unwrap();
        assert_eq!(cfg.episodes, 7);
        assert_eq!(cfg.reward.kind, RewardKind::Diff);
    }

    #[test]
    fn bad_flag_values_are_errors_not_panics() {
        assert!(resolve("lenet", &args("search --episodes nope")).is_err());
        assert!(resolve("lenet", &args("search --agent gru")).is_err());
        assert!(resolve("lenet", &args("search --action-space wild")).is_err());
        assert!(resolve("lenet", &args("search --reward spicy")).is_err());
        assert!(resolve("lenet", &args("search --rollout warp")).is_err());
        assert!(resolve("lenet", &args("search --lanes many")).is_err());
    }

    #[test]
    fn rollout_flags_resolve() {
        let cfg = resolve("lenet", &args("search --rollout batched --lanes 4")).unwrap();
        assert_eq!(cfg.rollout, RolloutMode::Batched);
        assert_eq!(cfg.lanes, 4);
        assert_eq!(preset("lenet").rollout, RolloutMode::Serial);
    }

    #[test]
    fn eval_batch_resolves_through_every_layer() {
        // default: 0 = the artifact's baked width
        assert_eq!(preset("lenet").env.eval_batch, 0);
        // CLI
        let cfg = resolve("lenet", &args("search --eval-batch 4")).unwrap();
        assert_eq!(cfg.env.eval_batch, 4);
        assert!(resolve("lenet", &args("search --eval-batch lots")).is_err());
        // TOML and job-JSON share the key table
        let mut via_toml = preset("lenet");
        let doc = toml_lite::parse("[search]\neval_batch = 2\n").unwrap();
        apply_toml(&mut via_toml, doc.get("search").unwrap()).unwrap();
        assert_eq!(via_toml.env.eval_batch, 2);
        let spec = job_from_json(
            &Json::parse(r#"{"net": "lenet", "config": {"eval_batch": 8}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.cfg.env.eval_batch, 8);
    }

    #[test]
    fn pipeline_resolves_through_every_layer() {
        // default: 0 = fully synchronous, dispatcher bypassed
        assert_eq!(preset("lenet").pipeline, 0);
        // CLI
        let cfg = resolve("lenet", &args("search --rollout batched --pipeline 2")).unwrap();
        assert_eq!(cfg.pipeline, 2);
        assert!(resolve("lenet", &args("search --pipeline deep")).is_err());
        // TOML and job-JSON share the key table
        let mut via_toml = preset("lenet");
        let doc = toml_lite::parse("[search]\npipeline = 4\n").unwrap();
        apply_toml(&mut via_toml, doc.get("search").unwrap()).unwrap();
        assert_eq!(via_toml.pipeline, 4);
        let spec = job_from_json(
            &Json::parse(r#"{"net": "lenet", "config": {"pipeline": 3}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.cfg.pipeline, 3);
    }

    #[test]
    fn devices_resolves_through_every_layer() {
        // default: 1 = single-device pool, byte-identical to the pre-pool path
        assert_eq!(preset("lenet").devices, 1);
        // CLI
        let cfg = resolve("lenet", &args("search --devices 4")).unwrap();
        assert_eq!(cfg.devices, 4);
        assert!(resolve("lenet", &args("search --devices many")).is_err());
        assert!(resolve("lenet", &args("search --devices 0")).is_err(), "0 devices rejected");
        // TOML and job-JSON share the key table
        let mut via_toml = preset("lenet");
        let doc = toml_lite::parse("[search]\ndevices = 2\n").unwrap();
        apply_toml(&mut via_toml, doc.get("search").unwrap()).unwrap();
        assert_eq!(via_toml.devices, 2);
        let doc = toml_lite::parse("[search]\ndevices = 0\n").unwrap();
        assert!(apply_toml(&mut via_toml, doc.get("search").unwrap()).is_err());
        let spec = job_from_json(
            &Json::parse(r#"{"net": "lenet", "config": {"devices": 3}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.cfg.devices, 3);
    }

    #[test]
    fn watchdog_resolves_through_every_layer() {
        // default: 0 = no watchdog
        assert_eq!(preset("lenet").watchdog_ms, 0);
        // CLI
        let cfg = resolve("lenet", &args("search --pipeline 2 --watchdog-ms 5000")).unwrap();
        assert_eq!(cfg.watchdog_ms, 5000);
        assert!(resolve("lenet", &args("search --watchdog-ms soon")).is_err());
        // TOML and job-JSON share the key table
        let mut via_toml = preset("lenet");
        let doc = toml_lite::parse("[search]\nwatchdog_ms = 750\n").unwrap();
        apply_toml(&mut via_toml, doc.get("search").unwrap()).unwrap();
        assert_eq!(via_toml.watchdog_ms, 750);
        let spec = job_from_json(
            &Json::parse(r#"{"net": "lenet", "config": {"watchdog_ms": 250}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.cfg.watchdog_ms, 250);
    }

    #[test]
    fn json_and_toml_share_the_key_table() {
        // same overrides through both layers must produce the same config
        let mut via_toml = preset("lenet");
        let doc = toml_lite::parse(
            "[search]\nepisodes = 9\nreward = \"diff\"\neval_every_step = false\nmemo_cap = 128\n",
        )
        .unwrap();
        apply_toml(&mut via_toml, doc.get("search").unwrap()).unwrap();

        let mut via_json = preset("lenet");
        let j = Json::parse(
            r#"{"episodes": 9, "reward": "diff", "eval_every_step": false, "memo_cap": 128}"#,
        )
        .unwrap();
        apply_json(&mut via_json, j.as_obj().unwrap()).unwrap();

        for cfg in [&via_toml, &via_json] {
            assert_eq!(cfg.episodes, 9);
            assert_eq!(cfg.reward.kind, RewardKind::Diff);
            assert!(!cfg.eval_every_step);
            assert_eq!(cfg.env.memo_cap, 128);
        }
        // unknown keys and type mismatches error in both layers
        let bad = Json::parse(r#"{"episodez": 1}"#).unwrap();
        assert!(apply_json(&mut via_json, bad.as_obj().unwrap()).is_err());
        let bad = Json::parse(r#"{"episodes": "many"}"#).unwrap();
        assert!(apply_json(&mut via_json, bad.as_obj().unwrap()).is_err());
    }

    #[test]
    fn bits_parsers_share_validation() {
        assert_eq!(parse_bits("8, 4,4,8").unwrap(), vec![8, 4, 4, 8]);
        assert!(parse_bits("8,nope").is_err());
        assert!(parse_bits("").is_err());
        assert!(parse_bits("8,0").is_err(), "0 bits rejected");
        assert!(parse_bits("8,64").is_err(), "64 bits rejected");
        let j = Json::parse("[8, 4, 2]").unwrap();
        assert_eq!(bits_from_json(&j).unwrap(), vec![8, 4, 2]);
        assert!(bits_from_json(&Json::parse("[8, 2.5]").unwrap()).is_err());
        assert!(bits_from_json(&Json::parse("[]").unwrap()).is_err());
        assert!(bits_from_json(&Json::parse("[8, 0]").unwrap()).is_err());
    }

    #[test]
    fn job_from_json_resolves_preset_then_overrides() {
        let j = Json::parse(
            r#"{"net": "lenet", "config": {"episodes": 12, "seed": 5}, "deadline_ms": 60000}"#,
        )
        .unwrap();
        let spec = job_from_json(&j).unwrap();
        assert_eq!(spec.net, "lenet");
        assert_eq!(spec.cfg.episodes, 12);
        assert_eq!(spec.cfg.seed, 5);
        // untouched keys come from the preset
        assert_eq!(spec.cfg.env.pretrain_steps, preset("lenet").env.pretrain_steps);
        assert_eq!(spec.deadline_ms, Some(60_000));

        assert!(job_from_json(&Json::parse(r#"{"config": {}}"#).unwrap()).is_err());
        assert!(
            job_from_json(&Json::parse(r#"{"net": "lenet", "config": 3}"#).unwrap()).is_err()
        );
        assert!(job_from_json(
            &Json::parse(r#"{"net": "lenet", "deadline_ms": -1}"#).unwrap()
        )
        .is_err());
        // top-level typos are rejected, same strictness as config keys
        assert!(job_from_json(
            &Json::parse(r#"{"net": "lenet", "deadline": 60000}"#).unwrap()
        )
        .is_err());
        assert!(job_from_json(&Json::parse("[1,2]").unwrap()).is_err());
    }

    #[test]
    fn net_name_validation() {
        for good in ["lenet", "unknown-net", "mobilenet_v1", "Net3", &"a".repeat(64)] {
            assert!(validate_net_name(good).is_ok(), "{good}");
        }
        for bad in ["", "../lenet", "a/b", "a\\b", "a.b", "net@v2", "a b", &"a".repeat(65)] {
            assert!(validate_net_name(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn job_from_json_rejects_traversal_names() {
        for bad in ["../../etc/passwd", "a/b", "", "a.b"] {
            let j = Json::obj(vec![("net", Json::Str(bad.to_string()))]);
            assert!(job_from_json(&j).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn serve_config_flags_resolve() {
        let c = serve_config(&args("serve")).unwrap();
        assert_eq!(c.addr, "127.0.0.1:7463");
        assert_eq!(c.workers, 2);
        assert_eq!(c.job_retries, 2);
        assert_eq!(c.quarantine_k, 3);
        assert_eq!(c.breaker_fails, 8);
        assert_eq!(c.registry_dir, None);
        let c = serve_config(&args(
            "serve --addr 127.0.0.1:0 --workers 4 --queue-cap 2 --archive /tmp/a.json \
             --job-retries 0 --quarantine-k 1 --breaker-fails 3 --registry-dir /tmp/reg",
        ))
        .unwrap();
        assert_eq!(c.addr, "127.0.0.1:0");
        assert_eq!(c.workers, 4);
        assert_eq!(c.queue_cap, 2);
        assert_eq!(c.archive, std::path::PathBuf::from("/tmp/a.json"));
        assert_eq!(c.job_retries, 0);
        assert_eq!(c.quarantine_k, 1);
        assert_eq!(c.breaker_fails, 3);
        assert_eq!(c.registry_dir, Some(std::path::PathBuf::from("/tmp/reg")));
        assert!(serve_config(&args("serve --workers 0")).is_err());
        assert!(serve_config(&args("serve --queue-cap zero")).is_err());
        assert!(serve_config(&args("serve --job-retries lots")).is_err());
        assert!(!serve_config(&args("serve")).unwrap().access_log);
        assert!(serve_config(&args("serve --access-log")).unwrap().access_log);
    }

    #[test]
    fn fleet_config_flags_resolve() {
        // no workers at all is a configuration error, not a silent no-op
        assert!(fleet_config(&args("fleet")).is_err());
        let c = fleet_config(&args("fleet --spawn-workers 2")).unwrap();
        assert_eq!(c.addr, "127.0.0.1:7470");
        assert_eq!(c.spawn_workers, 2);
        assert!(c.worker_addrs.is_empty());
        assert_eq!(c.merge_interval_ms, 5000);
        assert_eq!(c.steal_budget, 1);
        assert!(!c.access_log);
        let c = fleet_config(&args(
            "fleet --addr 127.0.0.1:0 --worker-addrs 127.0.0.1:7463,127.0.0.1:7464 \
             --archive /tmp/f.json --merge-interval-ms 0 --health-interval-ms 50 \
             --steal-budget 2 --worker-threads 1 --worker-queue-cap 3 --access-log",
        ))
        .unwrap();
        assert_eq!(c.worker_addrs, vec!["127.0.0.1:7463", "127.0.0.1:7464"]);
        assert_eq!(c.archive, std::path::PathBuf::from("/tmp/f.json"));
        assert_eq!(c.merge_interval_ms, 0);
        assert_eq!(c.health_interval_ms, 50);
        assert_eq!(c.steal_budget, 2);
        assert_eq!((c.worker_threads, c.worker_queue_cap), (1, 3));
        assert!(c.access_log);
        // joins + spawns compose; stray commas are tolerated
        let c = fleet_config(&args("fleet --spawn-workers 1 --worker-addrs 127.0.0.1:7463,"))
            .unwrap();
        assert_eq!((c.spawn_workers, c.worker_addrs.len()), (1, 1));
        assert!(fleet_config(&args("fleet --spawn-workers 1 --worker-threads 0")).is_err());
        assert!(fleet_config(&args("fleet --spawn-workers 1 --health-interval-ms 0")).is_err());
        assert!(fleet_config(&args("fleet --spawn-workers nope")).is_err());
    }

    #[test]
    fn idempotency_key_decodes_and_validates() {
        let j = Json::parse(r#"{"net": "lenet", "idempotency_key": "cli.retry-7"}"#).unwrap();
        let spec = job_from_json(&j).unwrap();
        assert_eq!(spec.idempotency_key.as_deref(), Some("cli.retry-7"));
        // raw body is carried verbatim for the WAL, key included
        assert_eq!(
            spec.raw.get("idempotency_key").and_then(Json::as_str),
            Some("cli.retry-7")
        );
        // absent and null both mean "no key"
        let j = Json::parse(r#"{"net": "lenet"}"#).unwrap();
        assert_eq!(job_from_json(&j).unwrap().idempotency_key, None);
        let j = Json::parse(r#"{"net": "lenet", "idempotency_key": null}"#).unwrap();
        assert_eq!(job_from_json(&j).unwrap().idempotency_key, None);
        // bad keys 400 at decode, same strictness as net names
        for bad in ["", "a b", "a/b", "k\u{e9}y", &"k".repeat(81)] {
            let j = Json::obj(vec![
                ("net", Json::Str("lenet".into())),
                ("idempotency_key", Json::Str(bad.to_string())),
            ]);
            assert!(job_from_json(&j).is_err(), "{bad:?} must be rejected");
        }
        assert!(job_from_json(
            &Json::parse(r#"{"net": "lenet", "idempotency_key": 7}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn durability_flags_resolve() {
        let c = serve_config(&args("serve")).unwrap();
        assert_eq!((c.wal.clone(), c.checkpoint_dir.clone()), (None, None));
        assert_eq!(c.checkpoint_every, 8);
        let c = serve_config(&args(
            "serve --wal /tmp/jobs.wal --checkpoint-dir /tmp/ckpt --checkpoint-every 4",
        ))
        .unwrap();
        assert_eq!(c.wal, Some(std::path::PathBuf::from("/tmp/jobs.wal")));
        assert_eq!(c.checkpoint_dir, Some(std::path::PathBuf::from("/tmp/ckpt")));
        assert_eq!(c.checkpoint_every, 4);
        assert!(serve_config(&args("serve --checkpoint-every 0")).is_err());
        assert!(serve_config(&args("serve --checkpoint-every soon")).is_err());
        assert!(!fleet_config(&args("fleet --spawn-workers 1")).unwrap().durable);
        assert!(fleet_config(&args("fleet --spawn-workers 1 --durable")).unwrap().durable);
    }

    #[test]
    fn toml_then_cli_precedence() {
        let dir = std::env::temp_dir().join("releq_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(&p, "[search]\nepisodes = 50\nseed = 3\n[search.lenet]\nepisodes = 60\n")
            .unwrap();
        let a = args(&format!("search --config {} --seed 9", p.display()));
        let cfg = resolve("lenet", &a).unwrap();
        assert_eq!(cfg.episodes, 60); // per-net toml beats global toml
        assert_eq!(cfg.seed, 9); // cli beats toml
    }
}
