//! Experiment configuration: a TOML-subset file format + per-network presets
//! + CLI override plumbing, feeding [`crate::coordinator::SearchConfig`].
//!
//! Precedence (lowest to highest): built-in defaults -> network preset ->
//! `--config file.toml` -> individual CLI flags.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{ActionSpace, AgentKind, RewardKind, RolloutMode, SearchConfig};
use crate::util::cli::Args;

pub mod toml_lite;

pub use toml_lite::TomlValue;

/// Per-network search presets, tuned for the 1-core CPU-PJRT testbed.
/// Deeper networks get terminal-only accuracy evaluation (paper §3) and
/// fewer episodes; small ones evaluate every step.
pub fn preset(net: &str) -> SearchConfig {
    let mut cfg = SearchConfig::default();
    match net {
        "lenet" => {
            cfg.episodes = 400;
            cfg.env.pretrain_steps = 300;
        }
        "simplenet" => {
            cfg.episodes = 350;
            cfg.env.pretrain_steps = 350;
        }
        "alexnet" | "vgg11" | "svhn10" => {
            // L >= 8: evaluate at episode end (paper §3: "for deeper networks
            // ... we perform this phase after all the bitwidths are selected")
            cfg.episodes = 300;
            cfg.env.pretrain_steps = 400;
            cfg.env.retrain_steps = 3;
            cfg.eval_every_step = false;
        }
        "resnet20" | "mobilenet" => {
            cfg.episodes = 240;
            cfg.env.pretrain_steps = 450;
            // more retrain steps than the shallow nets: deep nets'
            // short-retrain accuracy is noisy and the reward's acc^5 term
            // amplifies that noise (5 is the wall-clock compromise; see
            // EXPERIMENTS.md §Perf on why these nets run the per-step path)
            cfg.env.retrain_steps = 5;
            cfg.eval_every_step = false;
        }
        _ => {}
    }
    cfg
}

/// Apply a parsed TOML-lite table to a SearchConfig. Unknown keys and
/// malformed values surface as errors, not panics.
pub fn apply_toml(cfg: &mut SearchConfig, tbl: &BTreeMap<String, TomlValue>) -> Result<()> {
    let f = |k: &str, v: &TomlValue| {
        v.as_f64().with_context(|| format!("config key `{k}` expects a number"))
    };
    let s = |k: &str, v: &TomlValue| {
        v.as_str().with_context(|| format!("config key `{k}` expects a string"))
    };
    for (k, v) in tbl {
        match k.as_str() {
            "episodes" => cfg.episodes = f(k, v)? as usize,
            "pretrain_steps" => cfg.env.pretrain_steps = f(k, v)? as usize,
            "retrain_steps" => cfg.env.retrain_steps = f(k, v)? as usize,
            "long_retrain_steps" => cfg.env.long_retrain_steps = f(k, v)? as usize,
            "lr" => cfg.env.lr = f(k, v)? as f32,
            "train_size" => cfg.env.train_size = f(k, v)? as usize,
            "seed" => cfg.seed = f(k, v)? as u64,
            "clip_eps" => cfg.ppo.clip_eps = f(k, v)? as f32,
            "ent_coef" => cfg.ppo.ent_coef = f(k, v)? as f32,
            "agent_lr" => cfg.ppo.lr = f(k, v)? as f32,
            "epochs" => cfg.ppo.epochs = f(k, v)? as usize,
            "gamma" => cfg.ppo.gamma = f(k, v)?,
            "lam" => cfg.ppo.lam = f(k, v)?,
            "reward" => cfg.reward.kind = RewardKind::parse(s(k, v)?)?,
            "reward_a" => cfg.reward.a = f(k, v)?,
            "reward_b" => cfg.reward.b = f(k, v)?,
            "reward_th" => cfg.reward.th = f(k, v)?,
            "agent" => cfg.agent_kind = AgentKind::parse(s(k, v)?)?,
            "action_space" => cfg.action_space = ActionSpace::parse(s(k, v)?)?,
            "rollout" => cfg.rollout = RolloutMode::parse(s(k, v)?)?,
            "lanes" => cfg.lanes = f(k, v)? as usize,
            "eval_every_step" => {
                cfg.eval_every_step = v
                    .as_bool()
                    .with_context(|| format!("config key `{k}` expects a bool"))?
            }
            "min_bits" => cfg.min_bits = f(k, v)? as u32,
            "patience" => cfg.patience = f(k, v)? as usize,
            other => anyhow::bail!("unknown config key `{other}`"),
        }
    }
    Ok(())
}

/// Apply individual CLI flags (highest precedence). Bad flag values are
/// reported as errors naming the flag.
pub fn apply_cli(cfg: &mut SearchConfig, args: &Args) -> Result<()> {
    fn num<T: std::str::FromStr>(args: &Args, flag: &str) -> Result<Option<T>> {
        match args.opt_str(flag) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{flag} expects a number, got `{v}`")),
        }
    }
    if let Some(v) = num(args, "episodes")? {
        cfg.episodes = v;
    }
    if let Some(v) = num(args, "seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.opt_str("reward") {
        cfg.reward.kind = RewardKind::parse(&v)?;
    }
    if let Some(v) = args.opt_str("agent") {
        cfg.agent_kind = AgentKind::parse(&v)?;
    }
    if let Some(v) = args.opt_str("action-space") {
        cfg.action_space = ActionSpace::parse(&v)?;
    }
    if let Some(v) = args.opt_str("rollout") {
        cfg.rollout = RolloutMode::parse(&v)?;
    }
    if let Some(v) = num(args, "lanes")? {
        cfg.lanes = v;
    }
    if let Some(v) = num(args, "agent-lr")? {
        cfg.ppo.lr = v;
    }
    if let Some(v) = num(args, "ent-coef")? {
        cfg.ppo.ent_coef = v;
    }
    if let Some(v) = num(args, "clip-eps")? {
        cfg.ppo.clip_eps = v;
    }
    if let Some(v) = num(args, "retrain-steps")? {
        cfg.env.retrain_steps = v;
    }
    if let Some(v) = num(args, "pretrain-steps")? {
        cfg.env.pretrain_steps = v;
    }
    if let Some(v) = num(args, "lr")? {
        cfg.env.lr = v;
    }
    if let Some(v) = num(args, "patience")? {
        cfg.patience = v;
    }
    if args.has("eval-at-end") {
        cfg.eval_every_step = false;
    }
    Ok(())
}

/// Resolve the full precedence chain for a network.
pub fn resolve(net: &str, args: &Args) -> Result<SearchConfig> {
    let mut cfg = preset(net);
    if let Some(path) = args.opt_str("config") {
        let text = std::fs::read_to_string(Path::new(&path))
            .with_context(|| format!("reading config {path}"))?;
        let doc = toml_lite::parse(&text).with_context(|| format!("parsing {path}"))?;
        // global [search] section, then per-network [search.<net>]
        if let Some(tbl) = doc.get("search") {
            apply_toml(&mut cfg, tbl).with_context(|| format!("config {path} [search]"))?;
        }
        if let Some(tbl) = doc.get(&format!("search.{net}")) {
            apply_toml(&mut cfg, tbl)
                .with_context(|| format!("config {path} [search.{net}]"))?;
        }
    }
    apply_cli(&mut cfg, args)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(std::iter::once("releq".into()).chain(s.split_whitespace().map(String::from)))
    }

    #[test]
    fn presets_differ_by_depth() {
        assert!(preset("lenet").eval_every_step);
        assert!(!preset("mobilenet").eval_every_step);
        assert!(preset("lenet").episodes > preset("mobilenet").episodes);
    }

    #[test]
    fn cli_overrides_preset() {
        let cfg = resolve("lenet", &args("search --net lenet --episodes 7 --reward diff")).unwrap();
        assert_eq!(cfg.episodes, 7);
        assert_eq!(cfg.reward.kind, RewardKind::Diff);
    }

    #[test]
    fn bad_flag_values_are_errors_not_panics() {
        assert!(resolve("lenet", &args("search --episodes nope")).is_err());
        assert!(resolve("lenet", &args("search --agent gru")).is_err());
        assert!(resolve("lenet", &args("search --action-space wild")).is_err());
        assert!(resolve("lenet", &args("search --reward spicy")).is_err());
        assert!(resolve("lenet", &args("search --rollout warp")).is_err());
        assert!(resolve("lenet", &args("search --lanes many")).is_err());
    }

    #[test]
    fn rollout_flags_resolve() {
        let cfg = resolve("lenet", &args("search --rollout batched --lanes 4")).unwrap();
        assert_eq!(cfg.rollout, RolloutMode::Batched);
        assert_eq!(cfg.lanes, 4);
        assert_eq!(preset("lenet").rollout, RolloutMode::Serial);
    }

    #[test]
    fn toml_then_cli_precedence() {
        let dir = std::env::temp_dir().join("releq_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(&p, "[search]\nepisodes = 50\nseed = 3\n[search.lenet]\nepisodes = 60\n")
            .unwrap();
        let a = args(&format!("search --config {} --seed 9", p.display()));
        let cfg = resolve("lenet", &a).unwrap();
        assert_eq!(cfg.episodes, 60); // per-net toml beats global toml
        assert_eq!(cfg.seed, 9); // cli beats toml
    }
}
