//! Search metrics: per-episode logs, moving averages, CSV/JSON emitters.
//!
//! Everything the experiment harness needs to regenerate the paper's learning
//! curves (Fig 5, Fig 7, Fig 10) is recorded here during a search run.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// One episode's record.
#[derive(Debug, Clone)]
pub struct EpisodeLog {
    pub episode: usize,
    pub reward: f64,
    pub state_acc: f64,
    pub state_q: f64,
    pub bits: Vec<u32>,
    /// per-layer action probability vectors at this episode (Fig 5)
    pub probs: Vec<Vec<f32>>,
}

impl EpisodeLog {
    /// JSON view of one episode. `with_probs` controls whether the (large)
    /// per-layer probability vectors are included: the file emitters keep
    /// them (Fig 5 needs them), the serve status tail drops them — a live
    /// polling client wants scalars, not O(L × A) floats per poll.
    pub fn to_json(&self, with_probs: bool) -> Json {
        let mut fields = vec![
            ("episode", Json::Num(self.episode as f64)),
            ("reward", Json::Num(self.reward)),
            ("state_acc", Json::Num(self.state_acc)),
            ("state_q", Json::Num(self.state_q)),
            ("bits", Json::arr_u32(&self.bits)),
        ];
        if with_probs {
            fields.push((
                "probs",
                Json::Arr(
                    self.probs
                        .iter()
                        .map(|p| {
                            Json::arr_f64(&p.iter().map(|&x| x as f64).collect::<Vec<_>>())
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Parse one episode back from its [`EpisodeLog::to_json`] view. The
    /// search checkpoint (`coordinator::checkpoint`) persists the episode
    /// log with probs and restores it on resume; a missing `probs` key
    /// (the serve status tail's lite view) parses to an empty vector.
    pub fn from_json(j: &Json) -> Result<EpisodeLog> {
        let num = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("episode log missing number `{k}`"))
        };
        let bits = j
            .get("bits")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("episode log missing `bits`"))?
            .iter()
            .map(|b| {
                b.as_f64()
                    .map(|n| n as u32)
                    .ok_or_else(|| anyhow::anyhow!("non-numeric bit in episode log"))
            })
            .collect::<Result<Vec<u32>>>()?;
        let probs = match j.get("probs") {
            None | Some(Json::Null) => Vec::new(),
            Some(p) => p
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("episode log `probs` is not an array"))?
                .iter()
                .map(|layer| {
                    layer
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("episode log probs row is not an array"))?
                        .iter()
                        .map(|x| {
                            x.as_f64()
                                .map(|n| n as f32)
                                .ok_or_else(|| anyhow::anyhow!("non-numeric prob in episode log"))
                        })
                        .collect::<Result<Vec<f32>>>()
                })
                .collect::<Result<Vec<Vec<f32>>>>()?,
        };
        Ok(EpisodeLog {
            episode: num("episode")? as usize,
            reward: num("reward")?,
            state_acc: num("state_acc")?,
            state_q: num("state_q")?,
            bits,
            probs,
        })
    }
}

/// JSON array over a slice of episodes — shared by [`SearchLog::write_json`]
/// and the serve daemon's live log tail (`GET /v1/jobs/{id}`).
pub fn episodes_json(eps: &[EpisodeLog], with_probs: bool) -> Json {
    Json::Arr(eps.iter().map(|e| e.to_json(with_probs)).collect())
}

#[derive(Debug, Default)]
pub struct SearchLog {
    pub episodes: Vec<EpisodeLog>,
}

impl SearchLog {
    pub fn push(&mut self, e: EpisodeLog) {
        self.episodes.push(e);
    }

    /// Moving average of a per-episode series.
    pub fn moving_average(series: &[f64], window: usize) -> Vec<f64> {
        let w = window.max(1);
        let mut out = Vec::with_capacity(series.len());
        let mut sum = 0.0;
        for (i, &x) in series.iter().enumerate() {
            sum += x;
            if i >= w {
                sum -= series[i - w];
            }
            out.push(sum / (i.min(w - 1) + 1) as f64);
        }
        out
    }

    pub fn rewards(&self) -> Vec<f64> {
        self.episodes.iter().map(|e| e.reward).collect()
    }

    pub fn state_accs(&self) -> Vec<f64> {
        self.episodes.iter().map(|e| e.state_acc).collect()
    }

    pub fn state_qs(&self) -> Vec<f64> {
        self.episodes.iter().map(|e| e.state_q).collect()
    }

    /// CSV: episode, reward, state_acc, state_q, bits...
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "episode,reward,state_acc,state_q,bits")?;
        for e in &self.episodes {
            let bits = e
                .bits
                .iter()
                .map(|b| b.to_string())
                .collect::<Vec<_>>()
                .join(" ");
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{}",
                e.episode, e.reward, e.state_acc, e.state_q, bits
            )?;
        }
        Ok(())
    }

    /// JSON dump including per-layer probability evolution (Fig 5 data).
    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, episodes_json(&self.episodes, true).dump())?;
        Ok(())
    }
}

/// Render an ASCII sparkline of a series (terminal "figures").
pub fn sparkline(series: &[f64], width: usize) -> String {
    if series.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = series.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = series.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let w = width.min(series.len()).max(1);
    let mut s = String::new();
    for j in 0..w {
        // endpoint-inclusive resampling so the last char reflects the last value
        let i = if w == 1 { 0 } else { j * (series.len() - 1) / (w - 1) };
        let v = series[i];
        let idx = (((v - lo) / span) * 7.0).round() as usize;
        s.push(BARS[idx.min(7)]);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moving_average_flat() {
        let s = vec![2.0; 10];
        assert_eq!(SearchLog::moving_average(&s, 3), vec![2.0; 10]);
    }

    #[test]
    fn moving_average_window() {
        let s = vec![0.0, 1.0, 2.0, 3.0];
        let ma = SearchLog::moving_average(&s, 2);
        assert_eq!(ma, vec![0.0, 0.5, 1.5, 2.5]);
    }

    #[test]
    fn csv_roundtrip_lines() {
        let mut log = SearchLog::default();
        log.push(EpisodeLog {
            episode: 0,
            reward: 0.5,
            state_acc: 0.9,
            state_q: 0.4,
            bits: vec![8, 2],
            probs: vec![],
        });
        let dir = std::env::temp_dir().join("releq_test_metrics");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("log.csv");
        log.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().nth(1).unwrap().starts_with("0,0.5"));
    }

    #[test]
    fn episode_json_tail_drops_probs() {
        let e = EpisodeLog {
            episode: 3,
            reward: 1.25,
            state_acc: 0.9,
            state_q: 0.4,
            bits: vec![4, 2],
            probs: vec![vec![0.25; 8]; 2],
        };
        let full = e.to_json(true);
        assert_eq!(full.req("probs").as_arr().unwrap().len(), 2);
        let lite = e.to_json(false);
        assert!(lite.get("probs").is_none());
        assert_eq!(lite.u("episode"), 3);
        assert_eq!(lite.f("reward"), 1.25);
        // the array emitter round-trips through the parser
        let arr = episodes_json(&[e], false).dump();
        let parsed = Json::parse(&arr).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn episode_json_roundtrips_bit_exactly() {
        let e = EpisodeLog {
            episode: 7,
            reward: -0.123456789012345,
            state_acc: 0.9172,
            state_q: 4.25,
            bits: vec![8, 4, 2, 8],
            probs: vec![vec![0.1f32, 0.3, 0.6], vec![0.25; 3]],
        };
        let back = EpisodeLog::from_json(&Json::parse(&e.to_json(true).dump()).unwrap()).unwrap();
        assert_eq!(back.episode, e.episode);
        assert_eq!(back.reward.to_bits(), e.reward.to_bits());
        assert_eq!(back.state_acc.to_bits(), e.state_acc.to_bits());
        assert_eq!(back.state_q.to_bits(), e.state_q.to_bits());
        assert_eq!(back.bits, e.bits);
        assert_eq!(back.probs, e.probs);
        // lite view (no probs) still parses, with an empty probs vector
        let lite = EpisodeLog::from_json(&e.to_json(false)).unwrap();
        assert!(lite.probs.is_empty());
    }

    #[test]
    fn sparkline_monotone() {
        let s: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let sp = sparkline(&s, 8);
        assert_eq!(sp.chars().count(), 8);
        assert!(sp.starts_with('▁'));
        assert!(sp.ends_with('█'));
    }
}
