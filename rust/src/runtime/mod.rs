//! PJRT runtime: artifact loading/compilation/execution (engine), the
//! asynchronous dispatcher worker pool (dispatch), deterministic fault
//! injection + typed retry/health primitives (faults), and the Python↔Rust
//! contract (manifest).

pub mod dispatch;
pub mod engine;
pub mod faults;
pub mod manifest;

pub use dispatch::{pick_device, Dispatcher, Pending};
pub use engine::{
    lit_f32, lit_scalar, thread_pin, to_f32, to_vec_f32, DeviceBuf, DevicePin, Engine, Exe,
    ExeStat, HostLit, Stage, DEVICES_ENV,
};
pub use faults::{classify, retry_transient, FaultClass, FaultError, FaultPlan, Health, RetryPolicy};
pub use manifest::{AgentMeta, LayerMeta, Manifest, NetworkMeta};
