//! PJRT runtime: artifact loading/compilation/execution (engine) and the
//! Python↔Rust contract (manifest).

pub mod engine;
pub mod manifest;

pub use engine::{lit_f32, lit_scalar, to_f32, to_vec_f32, DeviceBuf, Engine, Exe, HostLit, Stage};
pub use manifest::{AgentMeta, LayerMeta, Manifest, NetworkMeta};
