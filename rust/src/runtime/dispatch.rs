//! Asynchronous PJRT execution: a dispatcher worker pool over the shared
//! engine, so the host thread can submit device work and keep running.
//!
//! The synchronous hot paths (`Exe::run_b` → `to_literal_sync`) block the
//! coordinator for the full device-exec + download round trip, and the
//! device idles whenever the host is busy (PPO bookkeeping, sampling,
//! episode logging). A [`Dispatcher`] turns an execution into a
//! [`Pending`]: `submit` enqueues and returns immediately, a small worker
//! pool drives the blocking PJRT calls, and `Pending::wait` joins the
//! result when the host actually needs it. The pipelined search driver
//! (`coordinator::rollout`, `pipeline > 0`) uses this to double-buffer
//! lockstep chunks and to warm the accuracy memo speculatively.
//!
//! Properties:
//!
//! * **Per-artifact in-flight caps** — at most `inflight_cap` submissions
//!   per artifact tag may be queued or running; [`Dispatcher::submit`]
//!   blocks until a slot frees, the `try_*` variants refuse instead (the
//!   speculation budget check). The cap bounds how far a speculative
//!   producer can run ahead of the consumer.
//! * **Never-wedging pendings** — a panicking task resolves its `Pending`
//!   with an error (the panic message preserved) instead of hanging the
//!   waiter, mirroring `run_sharded`'s panic handling.
//! * **Drain/shutdown** — [`Dispatcher::drain`] blocks until every
//!   submitted task has completed (the quiesce point before a final greedy
//!   rollout); dropping the dispatcher drains the queue and joins the
//!   workers, so in-flight device work never outlives the owner.
//! * **Execution watchdog** ([`Dispatcher::with_watchdog`]) — every running
//!   task gets a wall-clock budget. A task that overruns it has its
//!   `Pending` resolved with a transient `watchdog` error (the waiter fails
//!   fast instead of wedging behind a hung PJRT call) and the shared
//!   [`Health`] flag flips unhealthy — `releq serve`'s circuit breaker
//!   sheds load until a later execution completes and clears it. The hung
//!   worker thread itself cannot be cancelled (PJRT has no cancellation
//!   API); it rejoins the pool if the call ever returns, and a dispatcher
//!   drop while a task is truly stuck will wait on it. Under a device pool,
//!   [`Dispatcher::submit`] additionally trips the hung exe's *device*
//!   health flag, so the least-loaded placement quarantines the sick device
//!   while the rest of the pool keeps serving.
//! * **Least-loaded device placement** — [`pick_device`] is the pool's
//!   placement policy (least in-flight healthy device, deterministic ties,
//!   degrade-not-deadlock when every device is sick); `Engine` wires it to
//!   the live per-device in-flight counters, and speculative producers pin
//!   their task's thread to the pick (`Engine::pin_least_loaded`) before
//!   striping work.
//!
//! Determinism: the dispatcher only *schedules* executions; the programs it
//! runs are pure functions of their operands, so a result obtained through
//! a `Pending` is bit-identical to the synchronous call it replaces
//! (`rust/tests/pipeline_parity.rs`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{DeviceBuf, Exe, HostLit};
use super::faults::{FaultError, Health};

/// Least-loaded device placement policy (pure, so the stub tier can pin it
/// without PJRT): given per-device in-flight depths and health flags, pick
/// the device new work should land on.
///
/// * unhealthy devices are skipped (sick-device quarantine);
/// * devices at `cap` in-flight are skipped when `cap > 0` (0 = uncapped);
/// * among the remaining, the least-loaded wins, ties breaking toward the
///   lowest index (deterministic picks);
/// * if every device is excluded (all sick and/or saturated), fall back to
///   the least-loaded device overall — the pool degrades instead of
///   deadlocking, and a completed execution on a sick device clears its
///   health flag again.
///
/// An empty pool returns device 0 (callers guarantee >= 1 slot).
pub fn pick_device(loads: &[u64], healthy: &[bool], cap: u64) -> usize {
    let eligible = |i: usize| {
        healthy.get(i).copied().unwrap_or(true) && (cap == 0 || loads[i] < cap)
    };
    let best = |it: &mut dyn Iterator<Item = usize>| -> Option<usize> {
        it.min_by_key(|&i| (loads[i], i))
    };
    best(&mut (0..loads.len()).filter(|&i| eligible(i)))
        .or_else(|| best(&mut (0..loads.len())))
        .unwrap_or(0)
}

/// A one-shot rendezvous for a dispatched task's result. Obtained from the
/// `submit` family; `wait` consumes it. Dropping a `Pending` without
/// waiting is fine — the task still runs to completion (its side effects,
/// e.g. memo inserts, land) and the result is discarded.
pub struct Pending<T> {
    slot: Arc<Slot<T>>,
}

struct Slot<T> {
    result: Mutex<Option<Result<T>>>,
    cv: Condvar,
}

impl<T> Pending<T> {
    fn new() -> (Pending<T>, Arc<Slot<T>>) {
        let slot = Arc::new(Slot { result: Mutex::new(None), cv: Condvar::new() });
        (Pending { slot: slot.clone() }, slot)
    }

    /// Block until the task completes and take its result.
    pub fn wait(self) -> Result<T> {
        let mut g = self.slot.result.lock().unwrap();
        while g.is_none() {
            g = self.slot.cv.wait(g).unwrap();
        }
        g.take().expect("checked above")
    }

    /// Has the task completed (successfully or not)?
    pub fn is_ready(&self) -> bool {
        self.slot.result.lock().unwrap().is_some()
    }
}

impl<T> Slot<T> {
    fn fulfill(&self, r: Result<T>) {
        *self.result.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }
}

/// A queued unit of work: the task body plus (under a watchdog) the
/// fail-fast handle that resolves the task's `Pending` with a timeout
/// error without waiting for the body to return.
struct Job {
    run: Box<dyn FnOnce() + Send>,
    abort: Option<Box<dyn FnOnce() + Send>>,
}

/// Watchdog configuration: the per-task wall-clock budget and the health
/// flag tripped on an overrun.
struct Watchdog {
    budget: Duration,
    health: Arc<Health>,
}

/// A running task's watchdog registration. `abort` is taken by whichever
/// side settles the task first: the watchdog (overrun → fail fast) or the
/// worker (completion → entry removed, handle dropped).
struct WatchEntry {
    deadline: Instant,
    abort: Option<Box<dyn FnOnce() + Send>>,
}

struct State {
    queue: VecDeque<Job>,
    /// queued + running submissions per artifact tag (the cap accounting)
    inflight: HashMap<String, usize>,
    /// queued + running tasks in total (the drain condition)
    active: usize,
    shutdown: bool,
}

struct Core {
    state: Mutex<State>,
    /// workers wait here for queue items (and the shutdown signal)
    work_cv: Condvar,
    /// cap-blocked submitters and `drain` wait here for completions
    idle_cv: Condvar,
    cap: usize,
    watchdog: Option<Watchdog>,
    /// running tasks under watchdog observation, keyed by a fresh id
    watch: Mutex<HashMap<u64, WatchEntry>>,
    next_watch_id: AtomicU64,
}

impl Core {
    /// Account one finished task (runs on the worker, after the task body).
    fn finish(&self, tag: &str) {
        let mut g = self.state.lock().unwrap();
        if let Some(n) = g.inflight.get_mut(tag) {
            *n -= 1;
            if *n == 0 {
                g.inflight.remove(tag);
            }
        }
        g.active -= 1;
        drop(g);
        self.idle_cv.notify_all();
    }

    fn worker_loop(self: Arc<Self>) {
        loop {
            let mut job = {
                let mut g = self.state.lock().unwrap();
                loop {
                    if let Some(t) = g.queue.pop_front() {
                        break t;
                    }
                    if g.shutdown {
                        return;
                    }
                    g = self.work_cv.wait(g).unwrap();
                }
            };
            let watch_id = self.watchdog.as_ref().map(|w| {
                let id = self.next_watch_id.fetch_add(1, Ordering::Relaxed);
                self.watch.lock().unwrap().insert(
                    id,
                    WatchEntry { deadline: Instant::now() + w.budget, abort: job.abort.take() },
                );
                id
            });
            (job.run)();
            if let Some(id) = watch_id {
                // dropping an un-taken abort handle; a taken one means the
                // watchdog already failed this task fast
                self.watch.lock().unwrap().remove(&id);
            }
        }
    }

    /// The watchdog monitor loop: periodically fail-fast every running task
    /// that overran its budget and trip the shared health flag. Exits with
    /// the pool's shutdown signal.
    fn watchdog_loop(self: Arc<Self>) {
        let w = self.watchdog.as_ref().expect("watchdog loop without config");
        let tick = (w.budget / 4).clamp(Duration::from_millis(5), Duration::from_millis(100));
        loop {
            std::thread::sleep(tick);
            if self.state.lock().unwrap().shutdown {
                return;
            }
            let now = Instant::now();
            let expired: Vec<Box<dyn FnOnce() + Send>> = {
                let mut g = self.watch.lock().unwrap();
                g.values_mut()
                    .filter(|e| now >= e.deadline)
                    .filter_map(|e| e.abort.take())
                    .collect()
            };
            for abort in expired {
                w.health.trip();
                eprintln!(
                    "[watchdog] execution exceeded its {:?} budget; failing the waiter \
                     fast and marking the engine unhealthy",
                    w.budget
                );
                abort();
            }
        }
    }
}

/// A small worker pool executing submitted tasks over the shared engine.
/// Owned (not `Arc`) by the driving loop; dropping it drains outstanding
/// work and joins the workers.
pub struct Dispatcher {
    core: Arc<Core>,
    workers: Vec<JoinHandle<()>>,
}

impl Dispatcher {
    /// `workers` threads, at most `inflight_cap` queued-or-running
    /// submissions per artifact tag (the pipeline depth knob; >= 1).
    pub fn new(workers: usize, inflight_cap: usize) -> Dispatcher {
        Dispatcher::build(workers, inflight_cap, None)
    }

    /// Like [`Dispatcher::new`], with an execution watchdog: any task
    /// running longer than `budget` has its `Pending` resolved with a
    /// transient `watchdog` error and trips `health` unhealthy.
    pub fn with_watchdog(
        workers: usize,
        inflight_cap: usize,
        budget: Duration,
        health: Arc<Health>,
    ) -> Dispatcher {
        Dispatcher::build(workers, inflight_cap, Some(Watchdog { budget, health }))
    }

    fn build(workers: usize, inflight_cap: usize, watchdog: Option<Watchdog>) -> Dispatcher {
        let watched = watchdog.is_some();
        let core = Arc::new(Core {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                inflight: HashMap::new(),
                active: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            cap: inflight_cap.max(1),
            watchdog,
            watch: Mutex::new(HashMap::new()),
            next_watch_id: AtomicU64::new(0),
        });
        let mut workers: Vec<JoinHandle<()>> = (0..workers.max(1))
            .map(|i| {
                let core = core.clone();
                std::thread::Builder::new()
                    .name(format!("releq-dispatch-{i}"))
                    .spawn(move || core.worker_loop())
                    .expect("spawning dispatcher worker")
            })
            .collect();
        if watched {
            let core = core.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("releq-watchdog".to_string())
                    .spawn(move || core.watchdog_loop())
                    .expect("spawning dispatcher watchdog"),
            );
        }
        Dispatcher { core, workers }
    }

    /// Enqueue `f` under `tag`, blocking while the tag is at its in-flight
    /// cap. Returns immediately once queued; `Pending::wait` joins the
    /// result.
    pub fn submit_with<T, F>(&self, tag: &str, f: F) -> Pending<T>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        self.enqueue(tag, f, true, None).expect("blocking submit always succeeds")
    }

    /// Non-blocking [`Dispatcher::submit_with`]: `None` when `tag` is at
    /// its in-flight cap — the speculation-budget refusal, so a producer at
    /// the cap drops work instead of stalling the driving loop.
    pub fn try_submit_with<T, F>(&self, tag: &str, f: F) -> Option<Pending<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        self.enqueue(tag, f, false, None)
    }

    /// Asynchronous `Exe::run_b`: one device execution with owned
    /// device-resident operands (the `Arc`s keep the buffers alive until
    /// the execution completes), tagged by the artifact name for the
    /// in-flight cap. Blocks while the artifact is at its cap. Under a
    /// watchdog, an overrun additionally trips the exe's *device* health —
    /// placement quarantines the wedged device, the pool keeps serving.
    pub fn submit(&self, exe: Arc<Exe>, args: Vec<Arc<DeviceBuf>>) -> Pending<Vec<HostLit>> {
        let tag = exe.name.clone();
        let dev_health = exe.device_health();
        self.enqueue(
            &tag,
            move || {
                let refs: Vec<&xla::PjRtBuffer> = args.iter().map(|b| b.raw()).collect();
                let parts = exe.run_b(&refs)?;
                Ok(parts.into_iter().map(HostLit::new).collect())
            },
            true,
            Some(Box::new(move || dev_health.trip())),
        )
        .expect("blocking submit always succeeds")
    }

    fn enqueue<T, F>(
        &self,
        tag: &str,
        f: F,
        block: bool,
        on_abort: Option<Box<dyn FnOnce() + Send>>,
    ) -> Option<Pending<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        let (pending, slot) = Pending::new();
        let core = self.core.clone();
        let tag_owned = tag.to_string();
        {
            let mut g = self.core.state.lock().unwrap();
            while g.inflight.get(tag).copied().unwrap_or(0) >= self.core.cap {
                if !block {
                    return None;
                }
                g = self.core.idle_cv.wait(g).unwrap();
            }
            *g.inflight.entry(tag_owned.clone()).or_insert(0) += 1;
            g.active += 1;
            // under a watchdog, the job carries a fail-fast handle: resolve
            // the pending with a typed transient error while the (possibly
            // hung) body keeps running; `on_abort` lets `submit` also trip
            // the wedged exe's device health for placement quarantine
            let abort = self.core.watchdog.as_ref().map(|w| {
                let abort_slot = slot.clone();
                let abort_tag = tag_owned.clone();
                let budget = w.budget;
                let hook = on_abort;
                Box::new(move || {
                    if let Some(h) = hook {
                        h();
                    }
                    abort_slot.fulfill(Err(FaultError::Transient(format!(
                        "watchdog: `{abort_tag}` exceeded its {budget:?} execution budget"
                    ))
                    .into()));
                }) as Box<dyn FnOnce() + Send>
            });
            let task_slot = slot;
            let run = Box::new(move || {
                // a panicking task must resolve its pending (a wedged waiter
                // would hang the driving loop) and must not kill the worker
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                let out = match r {
                    Ok(out) => out,
                    Err(panic) => {
                        let msg = panic
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| panic.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".to_string());
                        Err(anyhow::anyhow!("dispatched task panicked: {msg}"))
                    }
                };
                task_slot.fulfill(out);
                core.finish(&tag_owned);
            });
            g.queue.push_back(Job { run, abort });
        }
        self.core.work_cv.notify_one();
        Some(pending)
    }

    /// Block until every submitted task has completed (queue empty, nothing
    /// running). The quiesce point before work that must observe all
    /// speculative side effects — or before measuring.
    pub fn drain(&self) {
        let mut g = self.core.state.lock().unwrap();
        while g.active > 0 {
            g = self.core.idle_cv.wait(g).unwrap();
        }
    }

    /// Tasks currently queued or running (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.core.state.lock().unwrap().active
    }
}

impl Drop for Dispatcher {
    /// Graceful shutdown: workers finish everything already queued (their
    /// pendings resolve), then exit and are joined.
    fn drop(&mut self) {
        {
            let mut g = self.core.state.lock().unwrap();
            g.shutdown = true;
        }
        self.core.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn submit_returns_immediately_and_wait_joins() {
        let d = Dispatcher::new(2, 4);
        let p = d.submit_with("t", || {
            std::thread::sleep(Duration::from_millis(20));
            Ok(41 + 1)
        });
        let q = d.submit_with("t", || Ok("side".to_string()));
        assert_eq!(q.wait().unwrap(), "side");
        assert_eq!(p.wait().unwrap(), 42);
    }

    #[test]
    fn per_tag_cap_refuses_try_submissions() {
        let d = Dispatcher::new(1, 2);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let open = |g: &Arc<(Mutex<bool>, Condvar)>| {
            *g.0.lock().unwrap() = true;
            g.1.notify_all();
        };
        let hold = {
            let gate = gate.clone();
            move || {
                let (m, cv) = &*gate;
                let mut open = m.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Ok(0u32)
            }
        };
        // fill the tag's cap: one running (blocked on the gate), one queued
        let p1 = d.submit_with("acc", hold.clone());
        let p2 = d.try_submit_with("acc", hold.clone());
        assert!(p2.is_some(), "second submission fits the cap of 2");
        // at the cap: refused without blocking…
        assert!(d.try_submit_with("acc", hold.clone()).is_none());
        // …but an unrelated tag still has budget (queued behind the gate)
        let other = d.try_submit_with("act", || Ok(7u32));
        assert!(other.is_some());
        open(&gate);
        assert_eq!(p1.wait().unwrap(), 0);
        assert_eq!(p2.unwrap().wait().unwrap(), 0);
        assert_eq!(other.unwrap().wait().unwrap(), 7);
        // slots freed: the tag accepts again
        assert!(d.try_submit_with("acc", || Ok(1u32)).is_some());
        d.drain();
    }

    #[test]
    fn drain_waits_for_all_side_effects() {
        let d = Dispatcher::new(2, 8);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..6 {
            let hits = hits.clone();
            // dropped pendings: tasks still run and their effects land
            let _ = d.submit_with("fx", move || {
                std::thread::sleep(Duration::from_millis(5));
                hits.fetch_add(1, Ordering::SeqCst);
                Ok(())
            });
        }
        d.drain();
        assert_eq!(hits.load(Ordering::SeqCst), 6);
        assert_eq!(d.in_flight(), 0);
    }

    #[test]
    fn panicking_task_resolves_err_and_keeps_the_worker() {
        let d = Dispatcher::new(1, 4);
        let p = d.submit_with::<u32, _>("boom", || panic!("kapow"));
        let err = p.wait().unwrap_err();
        assert!(err.to_string().contains("kapow"), "{err}");
        // the single worker survived the panic
        let q = d.submit_with("boom", || Ok(5u32));
        assert_eq!(q.wait().unwrap(), 5);
    }

    #[test]
    fn drop_joins_after_finishing_queued_work() {
        let done = Arc::new(AtomicUsize::new(0));
        {
            let d = Dispatcher::new(1, 8);
            for _ in 0..4 {
                let done = done.clone();
                let _ = d.submit_with("q", move || {
                    std::thread::sleep(Duration::from_millis(3));
                    done.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                });
            }
            // drop without drain: queued tasks must still complete
        }
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn watchdog_fails_fast_and_trips_health() {
        let health = Arc::new(Health::new());
        let d = Dispatcher::with_watchdog(1, 4, Duration::from_millis(40), health.clone());
        let t0 = Instant::now();
        let p = d.submit_with::<u32, _>("hang", || {
            std::thread::sleep(Duration::from_millis(400));
            Ok(7)
        });
        let err = p.wait().unwrap_err();
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "the waiter must fail fast, not wait out the hang"
        );
        assert!(format!("{err:#}").contains("watchdog"), "{err}");
        assert!(!health.is_healthy(), "a hung exec must trip the health flag");
        // the worker rejoins the pool once the hang resolves
        let q = d.submit_with("after", || Ok(1u8));
        assert_eq!(q.wait().unwrap(), 1);
    }

    #[test]
    fn watchdog_leaves_fast_tasks_alone() {
        let health = Arc::new(Health::new());
        let d = Dispatcher::with_watchdog(2, 4, Duration::from_millis(500), health.clone());
        for i in 0..8u32 {
            let p = d.submit_with("quick", move || Ok(i));
            assert_eq!(p.wait().unwrap(), i);
        }
        assert!(health.is_healthy());
    }

    #[test]
    fn pick_device_prefers_least_loaded_with_deterministic_ties() {
        let all_ok = [true, true, true, true];
        assert_eq!(pick_device(&[3, 1, 2, 1], &all_ok, 0), 1, "least loaded, lowest index wins");
        assert_eq!(pick_device(&[0, 0, 0, 0], &all_ok, 0), 0, "full tie breaks to device 0");
        assert_eq!(pick_device(&[5], &[true], 0), 0, "single-device pool is always 0");
    }

    #[test]
    fn pick_device_quarantines_sick_and_saturated_devices() {
        // the least-loaded device is sick: skip it
        assert_eq!(pick_device(&[0, 2, 1], &[false, true, true], 0), 2);
        // cap excludes saturated devices (cap=0 means uncapped)
        assert_eq!(pick_device(&[2, 2, 1], &[true, true, true], 2), 2);
        assert_eq!(pick_device(&[2, 2, 2], &[true, true, true], 3), 0);
    }

    #[test]
    fn pick_device_degrades_instead_of_deadlocking() {
        // every device sick: fall back to the least-loaded overall
        assert_eq!(pick_device(&[4, 1, 3], &[false, false, false], 0), 1);
        // every healthy device saturated: same fallback
        assert_eq!(pick_device(&[2, 2, 1], &[true, true, false], 2), 2);
        assert_eq!(pick_device(&[], &[], 0), 0, "empty pool defaults to 0");
    }

    #[test]
    fn is_ready_flips_after_completion() {
        let d = Dispatcher::new(1, 1);
        let p = d.submit_with("r", || Ok(1u8));
        d.drain();
        assert!(p.is_ready());
        assert_eq!(p.wait().unwrap(), 1);
    }
}
