//! Typed view of `artifacts/manifest.json` — the contract between the Python
//! AOT compile path and the Rust runtime. Shapes, flat-parameter layouts and
//! per-layer metadata all come from here; nothing about the networks is
//! hard-coded on the Rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Manifest schema version the emitter currently writes. Version 0 means a
/// legacy manifest predating schema stamping; everything downgrades gracefully
/// (the `eval_batch_k: 0` pattern) rather than refusing to load.
pub const MANIFEST_SCHEMA_VERSION: u32 = 1;

/// One quantizable layer (the unit the RL agent assigns a bitwidth to).
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    /// `dense` | `conv` | `conv1x1` | `dwconv`
    pub kind: String,
    pub w_shape: Vec<usize>,
    pub w_offset: usize,
    pub w_len: usize,
    pub b_offset: usize,
    pub b_len: usize,
    /// multiply-accumulates per example (the paper's n_l^MAcc)
    pub n_macs: u64,
    pub in_dim: usize,
    pub out_dim: usize,
}

#[derive(Debug, Clone)]
pub struct NetworkMeta {
    pub name: String,
    /// episode length: number of quantizable layers
    pub l: usize,
    /// flat parameter count
    pub p: usize,
    /// input (H, W, C)
    pub input: [usize; 3],
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    /// SGD steps baked into the fused `<net>_retrain_eval` artifact
    pub fused_k: usize,
    /// candidate bits lanes baked into `<net>_retrain_eval_batch` (the
    /// megabatch accuracy evaluator: one execution scores up to this many
    /// bitwidth vectors). 0 = no batch artifact; manifests predating the
    /// batched evaluator fall back to 0, so the runtime degrades to the
    /// scalar fused path instead of demanding a missing file.
    pub eval_batch_k: usize,
    /// resident training-set size baked into the fused artifact
    pub train_size: usize,
    pub dataset: String,
    /// monotonically increasing network version stamped by the emitter (and
    /// bumped on registry upgrades). Legacy manifests fall back to 1.
    pub version: u64,
    /// per-artifact-file sha256 (`<name>_train.hlo.txt` → lowercase hex).
    /// Empty for legacy manifests — digest checks are then skipped and the
    /// network is counted in the registry's `legacy_manifests` stat.
    pub sha256: BTreeMap<String, String>,
    pub layers: Vec<LayerMeta>,
}

impl NetworkMeta {
    /// Total quantizable weights (the paper's n_l^w summed).
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.w_len as u64).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.n_macs).sum()
    }

    /// Parse one `networks.<name>` entry. Shared by `Manifest::load` and the
    /// registry, which parses the same shape out of per-network registry
    /// manifests (`registry.json` / `POST /v1/networks` bodies).
    pub fn from_json(name: &str, nj: &Json) -> Result<NetworkMeta> {
        let input = nj.req("input").as_arr().context("input")?;
        let layers = nj
            .req("layers")
            .as_arr()
            .context("layers")?
            .iter()
            .map(|lj| LayerMeta {
                name: lj.s("name").to_string(),
                kind: lj.s("kind").to_string(),
                w_shape: lj
                    .req("w_shape")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
                w_offset: lj.u("w_offset"),
                w_len: lj.u("w_len"),
                b_offset: lj.u("b_offset"),
                b_len: lj.u("b_len"),
                n_macs: lj.u("n_macs") as u64,
                in_dim: lj.u("in_dim"),
                out_dim: lj.u("out_dim"),
            })
            .collect::<Vec<_>>();
        let mut sha256 = BTreeMap::new();
        if let Some(sj) = nj.get("sha256") {
            for (file, hex) in sj.as_obj().context("sha256")? {
                let hex = hex.as_str().context("sha256 digest must be a string")?;
                sha256.insert(file.clone(), hex.to_string());
            }
        }
        Ok(NetworkMeta {
            name: name.to_string(),
            l: nj.u("l"),
            p: nj.u("p"),
            input: [
                input[0].as_usize().context("input[0]")?,
                input[1].as_usize().context("input[1]")?,
                input[2].as_usize().context("input[2]")?,
            ],
            classes: nj.u("classes"),
            train_batch: nj.u("train_batch"),
            eval_batch: nj.u("eval_batch"),
            fused_k: nj.u("fused_k"),
            eval_batch_k: nj
                .get("eval_batch_k")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
            train_size: nj.u("train_size"),
            dataset: nj.s("dataset").to_string(),
            version: nj.get("version").and_then(|v| v.as_usize()).unwrap_or(1) as u64,
            sha256,
            layers,
        })
    }

    /// True when this entry predates digest stamping (no per-file sha256).
    pub fn is_legacy(&self) -> bool {
        self.sha256.is_empty()
    }
}

#[derive(Debug, Clone)]
pub struct AgentMeta {
    pub state_dim: usize,
    pub n_actions: usize,
    pub hidden: usize,
    pub episodes_per_update: usize,
    /// lanes baked into the `agent_*_act_batch` artifacts (the lockstep
    /// rollout batch width). Manifests predating the batched-act artifact
    /// fall back to `episodes_per_update`, which is what the AOT compiler
    /// bakes anyway.
    pub act_batch: usize,
    /// flat param count of the LSTM agent
    pub p_lstm: usize,
    /// flat param count of the FC-ablation agent
    pub p_fc: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    /// manifest schema version (0 = legacy, pre-stamping emitter)
    pub schema_version: u32,
    pub fp_bits: f32,
    pub bits_max: u32,
    pub agent: AgentMeta,
    pub networks: Vec<NetworkMeta>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let agent = AgentMeta {
            state_dim: j.u("state_dim"),
            n_actions: j.u("n_actions"),
            hidden: j.u("hidden"),
            episodes_per_update: j.u("episodes_per_update"),
            act_batch: j
                .get("act_batch")
                .and_then(|v| v.as_usize())
                .unwrap_or_else(|| j.u("episodes_per_update")),
            p_lstm: j.req("agent").req("lstm").u("p"),
            p_fc: j.req("agent").req("fc").u("p"),
        };

        let mut networks = Vec::new();
        for (name, nj) in j.req("networks").as_obj().context("networks")? {
            networks.push(NetworkMeta::from_json(name, nj).with_context(|| format!("network {name}"))?);
        }

        Ok(Manifest {
            dir: artifacts_dir.to_path_buf(),
            schema_version: j
                .get("schema_version")
                .and_then(|v| v.as_usize())
                .unwrap_or(0) as u32,
            fp_bits: j.f("fp_bits") as f32,
            bits_max: j.u("bits_max") as u32,
            agent,
            networks,
        })
    }

    pub fn network(&self, name: &str) -> Result<&NetworkMeta> {
        self.networks
            .iter()
            .find(|n| n.name == name)
            .with_context(|| {
                format!(
                    "unknown network `{name}` (have: {})",
                    self.networks
                        .iter()
                        .map(|n| n.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A manifest with no `schema_version`, `version`, or `sha256` fields —
    /// the pre-registry emitter output — must still load, with the fallbacks
    /// (schema 0, version 1, empty digest map → `is_legacy()`), mirroring the
    /// `eval_batch_k: 0` degradation pattern.
    #[test]
    fn legacy_manifest_loads_with_fallbacks() {
        let dir = std::env::temp_dir().join(format!("releq_legacy_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
 "fp_bits": 9.0, "bits_max": 8,
 "state_dim": 10, "n_actions": 8, "hidden": 16, "episodes_per_update": 4,
 "agent": {"lstm": {"p": 100}, "fc": {"p": 50}},
 "networks": {
  "tiny": {
   "l": 1, "p": 6, "input": [2, 2, 1], "classes": 2,
   "train_batch": 4, "eval_batch": 4, "fused_k": 0, "train_size": 16,
   "dataset": "toy",
   "layers": [{"name": "fc1", "kind": "dense", "w_shape": [4, 2],
               "w_offset": 0, "w_len": 4, "b_offset": 4, "b_len": 2,
               "n_macs": 8, "in_dim": 4, "out_dim": 2}]
  }
 }
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.schema_version, 0);
        let net = m.network("tiny").unwrap();
        assert_eq!(net.version, 1);
        assert!(net.is_legacy());
        assert_eq!(net.eval_batch_k, 0);
        assert_eq!(net.l, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Stamped fields parse when present.
    #[test]
    fn stamped_manifest_fields_parse() {
        let nj = Json::parse(
            r#"{
   "l": 1, "p": 6, "input": [2, 2, 1], "classes": 2,
   "train_batch": 4, "eval_batch": 4, "fused_k": 0, "train_size": 16,
   "dataset": "toy", "version": 3,
   "sha256": {"tiny_train.hlo.txt": "ab", "tiny_eval.hlo.txt": "cd"},
   "layers": [{"name": "fc1", "kind": "dense", "w_shape": [4, 2],
               "w_offset": 0, "w_len": 4, "b_offset": 4, "b_len": 2,
               "n_macs": 8, "in_dim": 4, "out_dim": 2}]
  }"#,
        )
        .unwrap();
        let net = NetworkMeta::from_json("tiny", &nj).unwrap();
        assert_eq!(net.version, 3);
        assert!(!net.is_legacy());
        assert_eq!(net.sha256.len(), 2);
        assert_eq!(net.sha256["tiny_train.hlo.txt"], "ab");
    }

    /// Integration with the real artifacts (skipped if `make artifacts` has
    /// not been run).
    #[test]
    fn loads_real_manifest() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.networks.len(), 7);
        let lenet = m.network("lenet").unwrap();
        assert_eq!(lenet.l, 4);
        assert_eq!(lenet.layers.len(), 4);
        // flat layout invariants: offsets are contiguous and within p
        let mut expect = 0usize;
        for layer in &lenet.layers {
            assert_eq!(layer.w_offset, expect);
            expect = layer.b_offset + layer.b_len;
        }
        assert_eq!(expect, lenet.p);
        // resnet20 must expose the paper's 20-layer episode
        assert_eq!(m.network("resnet20").unwrap().l, 20);
        assert_eq!(m.network("mobilenet").unwrap().l, 28);
        assert!(m.agent.p_lstm > m.agent.p_fc);
        // the AOT compiler bakes the lockstep lane count = the PPO batch
        assert_eq!(m.agent.act_batch, m.agent.episodes_per_update);
        // the megabatch evaluator rides the fused family: a batch artifact
        // implies a fused one (holds for stale manifests too, where the
        // eval_batch_k fallback reads 0 everywhere)
        for net in &m.networks {
            assert!(net.eval_batch_k == 0 || net.fused_k > 0, "{}", net.name);
        }
        if lenet.eval_batch_k == 0 {
            // pre-megabatch artifacts are a supported configuration (the
            // runtime degrades to the scalar paths); only the coupling
            // above is checkable against them
            eprintln!("note: artifacts predate the megabatch evaluator — re-run `make artifacts`");
        } else {
            for net in &m.networks {
                assert_eq!(net.eval_batch_k > 0, net.fused_k > 0, "{}", net.name);
            }
        }
    }
}
