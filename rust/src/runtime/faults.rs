//! Deterministic fault injection, typed failure classification, and bounded
//! retry — the robustness seam under every execution path.
//!
//! ReLeQ searches are long loops of device executions; a single transient
//! PJRT failure used to kill the whole job. This module provides the three
//! primitives the runtime and serve layers build fault tolerance from:
//!
//! * [`FaultPlan`] — a deterministic, PCG-seeded fault injector configured
//!   via `$RELEQ_FAULTS` (inline DSL or a rules file). A plan makes the Nth
//!   execution of a named artifact fail, stall, or delay, so every retry /
//!   watchdog / quarantine behavior is exercised in the always-run stub
//!   tier. An absent plan is an `Option::None` check on the hot path —
//!   nothing else.
//! * [`FaultError`] / [`classify`] — typed transient / permanent
//!   classification. Errors injected by a plan carry their class; real PJRT
//!   errors are classified by status-code heuristics (conservatively:
//!   unknown errors are permanent, so retry never loops on a programming
//!   bug). The third class, cancellation, stays where it always was — the
//!   `Cancelled` downcast in `coordinator::search` — and the serve
//!   scheduler folds both sources into one verdict.
//! * [`RetryPolicy`] / [`retry_transient`] — bounded exponential backoff
//!   with deterministic jitter (per-callsite PCG stream) around any
//!   fallible operation; only transient failures are retried.
//! * [`Health`] — a shared healthy/unhealthy flag with a trip counter. The
//!   dispatch watchdog trips it on a hung execution; a completed execution
//!   clears it; `releq serve` surfaces it through `GET /v1/health` and the
//!   circuit breaker sheds load while it is tripped.
//!
//! # Fault DSL
//!
//! A plan is a comma-separated rule list; each rule is
//! `artifact:trigger:action`:
//!
//! ```text
//! seed=7,lenet_retrain_eval:nth=3:fail,*:prob=0.01:delay=5
//! ```
//!
//! * `artifact` — exact name, `*` (all), or a `prefix*` glob;
//! * trigger — `nth=N` (exactly the Nth matching execution, 1-based),
//!   `every=N` (every Nth), or `prob=P` (each execution with probability
//!   `P`, drawn from the rule's own PCG stream derived from `seed`);
//! * action — `fail` (transient error), `perm` (permanent error),
//!   `delay=MS` (sleep, then proceed normally), or `stall=MS` (sleep — a
//!   hang, as the watchdog sees it — then fail transient).
//!
//! `$RELEQ_FAULTS` may also name a file: one rule (or `seed=N`) per line,
//! `#` comments allowed.

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::fnv::Fnv;
use crate::util::rng::Pcg32;

/// Name of the environment variable holding a fault plan (inline DSL or a
/// path to a rules file).
pub const FAULTS_ENV: &str = "RELEQ_FAULTS";

// ---- typed classification ----------------------------------------------------

/// A typed execution failure. Injected faults carry their class explicitly;
/// [`classify`] recovers it from an `anyhow` chain.
#[derive(Debug, Clone)]
pub enum FaultError {
    /// Worth retrying: the same operation may well succeed (injected
    /// transient faults, PJRT UNAVAILABLE/RESOURCE_EXHAUSTED, watchdog
    /// timeouts).
    Transient(String),
    /// Retrying is pointless: the operation will fail the same way again.
    Permanent(String),
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultError::Transient(m) => write!(f, "transient failure: {m}"),
            FaultError::Permanent(m) => write!(f, "permanent failure: {m}"),
        }
    }
}

impl std::error::Error for FaultError {}

/// The retry verdict for a failure. `Cancelled` is never produced by
/// [`classify`] itself (cancellation is a coordinator-level concept — the
/// `Cancelled` type in `coordinator::search`); the serve scheduler folds
/// the two sources into this one enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    Transient,
    Permanent,
    Cancelled,
}

/// Patterns in real backend error messages that indicate a retryable
/// condition (PJRT/absl status codes + the dispatch watchdog's marker).
const TRANSIENT_MARKERS: [&str; 4] =
    ["UNAVAILABLE", "RESOURCE_EXHAUSTED", "ABORTED", "watchdog"];

/// Classify an execution error as transient or permanent. A typed
/// [`FaultError`] anywhere in the chain wins; otherwise the rendered chain
/// is scanned for transient status markers, and anything unrecognized is
/// permanent — retry must never loop on a deterministic bug.
pub fn classify(err: &anyhow::Error) -> FaultClass {
    for cause in err.chain() {
        if let Some(f) = cause.downcast_ref::<FaultError>() {
            return match f {
                FaultError::Transient(_) => FaultClass::Transient,
                FaultError::Permanent(_) => FaultClass::Permanent,
            };
        }
    }
    let msg = format!("{err:#}");
    if TRANSIENT_MARKERS.iter().any(|m| msg.contains(m)) {
        return FaultClass::Transient;
    }
    FaultClass::Permanent
}

// ---- fault plan --------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum Trigger {
    /// exactly the Nth matching execution (1-based)
    Nth(u64),
    /// every Nth matching execution
    Every(u64),
    /// each matching execution independently, with this probability
    Prob(f64),
}

#[derive(Debug, Clone, Copy)]
enum Action {
    /// fail with a transient error
    Fail,
    /// fail with a permanent error
    Perm,
    /// sleep this many ms, then proceed normally (added latency)
    Delay(u64),
    /// sleep this many ms (a hang, as the watchdog sees it), then fail
    /// transient
    Stall(u64),
}

struct Rule {
    pat: String,
    trigger: Trigger,
    action: Action,
    /// matching executions seen (drives `nth`/`every`)
    count: AtomicU64,
    /// faults this rule has injected
    fired: AtomicU64,
    /// the rule's own PCG stream (drives `prob`)
    rng: Mutex<Pcg32>,
}

fn pat_matches(pat: &str, name: &str) -> bool {
    pat == "*"
        || pat == name
        || pat.strip_suffix('*').is_some_and(|p| name.starts_with(p))
}

/// A deterministic fault-injection plan: an ordered rule list evaluated on
/// every execution of a named artifact. Empty plans never exist — the
/// engine holds `Option<Arc<FaultPlan>>` and the no-plan hot path is a
/// single `None` check.
///
/// Pool scope: the engine hands ONE `Arc<FaultPlan>` to every `Exe` on
/// every device, so each rule's execution counter observes the pool-wide
/// execution stream — `every=N`/`nth=N` triggers and [`FaultPlan::injected`]
/// totals are identical at any device count, which is what keeps the
/// chaos-tier `exec_retries == faults_injected` invariant device-agnostic.
/// A per-device plan clone would silently split the counters; don't.
pub struct FaultPlan {
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// Parse an inline DSL spec (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed: u64 = 0x5eed_f417;
        let mut raw: Vec<(String, Trigger, Action)> = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some((k, v)) = item.split_once('=') {
                if k.trim() == "seed" {
                    seed = v
                        .trim()
                        .parse()
                        .with_context(|| format!("fault seed `{v}` is not a u64"))?;
                    continue;
                }
            }
            let mut parts = item.splitn(3, ':');
            let (pat, trig, act) = match (parts.next(), parts.next(), parts.next()) {
                (Some(p), Some(t), Some(a)) => (p.trim(), t.trim(), a.trim()),
                _ => anyhow::bail!(
                    "fault rule `{item}` is not `artifact:trigger:action`"
                ),
            };
            let trigger = match trig.split_once('=') {
                Some(("nth", n)) => Trigger::Nth(
                    n.parse().with_context(|| format!("bad nth in `{item}`"))?,
                ),
                Some(("every", n)) => Trigger::Every(
                    n.parse().with_context(|| format!("bad every in `{item}`"))?,
                ),
                Some(("prob", p)) => {
                    let p: f64 =
                        p.parse().with_context(|| format!("bad prob in `{item}`"))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&p),
                        "prob {p} outside [0, 1] in `{item}`"
                    );
                    Trigger::Prob(p)
                }
                _ => anyhow::bail!(
                    "fault trigger `{trig}` is not nth=N | every=N | prob=P"
                ),
            };
            let action = match (act, act.split_once('=')) {
                ("fail", _) => Action::Fail,
                ("perm", _) => Action::Perm,
                (_, Some(("delay", ms))) => Action::Delay(
                    ms.parse().with_context(|| format!("bad delay in `{item}`"))?,
                ),
                (_, Some(("stall", ms))) => Action::Stall(
                    ms.parse().with_context(|| format!("bad stall in `{item}`"))?,
                ),
                _ => anyhow::bail!(
                    "fault action `{act}` is not fail | perm | delay=MS | stall=MS"
                ),
            };
            raw.push((pat.to_string(), trigger, action));
        }
        // seed the rule streams only once the (position-independent) seed is
        // known: rule i draws from stream i+1 of the plan seed
        let rules = raw
            .into_iter()
            .enumerate()
            .map(|(i, (pat, trigger, action))| Rule {
                pat,
                trigger,
                action,
                count: AtomicU64::new(0),
                fired: AtomicU64::new(0),
                rng: Mutex::new(Pcg32::new(seed).derive(i as u64 + 1)),
            })
            .collect();
        Ok(FaultPlan { rules })
    }

    /// Parse an inline spec, or — when the string names an existing file —
    /// a rules file (one rule or `seed=N` per line, `#` comments).
    pub fn load(spec_or_path: &str) -> Result<FaultPlan> {
        let p = Path::new(spec_or_path);
        if p.is_file() {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading fault plan {p:?}"))?;
            let spec: Vec<String> = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .map(|l| l.to_string())
                .collect();
            return FaultPlan::parse(&spec.join(","));
        }
        FaultPlan::parse(spec_or_path)
    }

    /// The process-wide plan from `$RELEQ_FAULTS`, if any. `None` (the
    /// overwhelmingly common case) keeps fault checks off the decision
    /// path entirely.
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>> {
        match std::env::var(FAULTS_ENV) {
            Ok(s) if !s.trim().is_empty() => {
                Ok(Some(Arc::new(FaultPlan::load(s.trim()).with_context(
                    || format!("parsing ${FAULTS_ENV}"),
                )?)))
            }
            _ => Ok(None),
        }
    }

    /// Consult the plan for one execution of `name`. Returns `Ok(())` to
    /// proceed (possibly after an injected delay) or the injected typed
    /// error. The first firing fail/stall rule wins; delay rules compose.
    pub fn on_exec(&self, name: &str) -> Result<()> {
        for r in &self.rules {
            if !pat_matches(&r.pat, name) {
                continue;
            }
            let n = r.count.fetch_add(1, Ordering::Relaxed) + 1;
            let fire = match r.trigger {
                Trigger::Nth(k) => n == k,
                Trigger::Every(k) => k > 0 && n % k == 0,
                Trigger::Prob(p) => {
                    let mut g = match r.rng.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    g.next_f64() < p
                }
            };
            if !fire {
                continue;
            }
            r.fired.fetch_add(1, Ordering::Relaxed);
            match r.action {
                Action::Delay(ms) => std::thread::sleep(Duration::from_millis(ms)),
                Action::Stall(ms) => {
                    std::thread::sleep(Duration::from_millis(ms));
                    return Err(FaultError::Transient(format!(
                        "injected stall ({ms} ms) on `{name}` (matching exec #{n})"
                    ))
                    .into());
                }
                Action::Fail => {
                    return Err(FaultError::Transient(format!(
                        "injected transient fault on `{name}` (matching exec #{n})"
                    ))
                    .into())
                }
                Action::Perm => {
                    return Err(FaultError::Permanent(format!(
                        "injected permanent fault on `{name}` (matching exec #{n})"
                    ))
                    .into())
                }
            }
        }
        Ok(())
    }

    /// Total faults injected so far (fail + perm + delay + stall firings),
    /// for the balance assertions in stats/chaos tests.
    pub fn injected(&self) -> u64 {
        self.rules.iter().map(|r| r.fired.load(Ordering::Relaxed)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

// ---- retry -------------------------------------------------------------------

/// Bounded exponential backoff with deterministic jitter. The delay before
/// retry `k` (0-based) is `min(cap_ms, base_ms << k)`, scaled by a jitter
/// factor in `[0.5, 1.0)` drawn from a PCG stream seeded by
/// `seed ^ fnv(callsite name)` — so a retry schedule replays bit-exactly.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// retries after the first attempt (0 disables retrying)
    pub max_retries: u32,
    pub base_ms: u64,
    pub cap_ms: u64,
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_retries: 3, base_ms: 25, cap_ms: 1000, seed: 0x0b5e_55ed }
    }
}

impl RetryPolicy {
    /// No retries (failures propagate on the first attempt).
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_retries: 0, ..RetryPolicy::default() }
    }

    /// The default policy, overridable via `$RELEQ_EXEC_RETRIES` and
    /// `$RELEQ_RETRY_BASE_MS`.
    pub fn from_env() -> Result<RetryPolicy> {
        let mut p = RetryPolicy::default();
        if let Ok(v) = std::env::var("RELEQ_EXEC_RETRIES") {
            p.max_retries =
                v.parse().with_context(|| format!("$RELEQ_EXEC_RETRIES=`{v}`"))?;
        }
        if let Ok(v) = std::env::var("RELEQ_RETRY_BASE_MS") {
            p.base_ms =
                v.parse().with_context(|| format!("$RELEQ_RETRY_BASE_MS=`{v}`"))?;
        }
        Ok(p)
    }

    /// Backoff before retry `attempt` (0-based), jittered from `rng`.
    pub fn backoff(&self, attempt: u32, rng: &mut Pcg32) -> Duration {
        let exp = self.base_ms.saturating_shl(attempt).min(self.cap_ms.max(1));
        let jittered = (exp as f64 * (0.5 + 0.5 * rng.next_f64())) as u64;
        Duration::from_millis(jittered.max(1))
    }
}

trait SatShl {
    fn saturating_shl(self, k: u32) -> u64;
}

impl SatShl for u64 {
    fn saturating_shl(self, k: u32) -> u64 {
        if k >= 63 {
            return u64::MAX;
        }
        self.checked_shl(k).unwrap_or(u64::MAX)
    }
}

/// Run `op`, retrying transient failures per `policy` with jittered
/// backoff. Permanent and unclassified failures propagate immediately;
/// each retry bumps `counter` (when given). `what` names the operation in
/// logs and seeds the jitter stream.
pub fn retry_transient<T>(
    policy: &RetryPolicy,
    what: &str,
    counter: Option<&AtomicU64>,
    mut op: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut rng = Pcg32::new(policy.seed ^ Fnv::new().write_str(what).finish());
    let mut attempt: u32 = 0;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                if attempt >= policy.max_retries
                    || classify(&e) != FaultClass::Transient
                {
                    return Err(e);
                }
                let d = policy.backoff(attempt, &mut rng);
                eprintln!(
                    "[retry] `{what}` failed transiently (attempt {}/{}): {e:#}; \
                     backing off {d:?}",
                    attempt + 1,
                    policy.max_retries + 1,
                );
                if let Some(c) = counter {
                    c.fetch_add(1, Ordering::Relaxed);
                }
                std::thread::sleep(d);
                attempt += 1;
            }
        }
    }
}

// ---- health ------------------------------------------------------------------

/// A shared healthy/unhealthy flag with a trip counter. The dispatch
/// watchdog trips it when an execution hangs past its budget; a completed
/// execution clears it (the backend demonstrably works again). The serve
/// circuit breaker and `GET /v1/health` read it.
#[derive(Default)]
pub struct Health {
    unhealthy: AtomicBool,
    trips: AtomicU64,
}

impl Health {
    pub fn new() -> Health {
        Health::default()
    }

    /// Mark the backend unhealthy (one watchdog trip).
    pub fn trip(&self) {
        self.unhealthy.store(true, Ordering::Relaxed);
        self.trips.fetch_add(1, Ordering::Relaxed);
    }

    /// Record evidence of a working backend (a completed execution). The
    /// load-before-store keeps the healthy hot path read-only.
    pub fn ok(&self) {
        if self.unhealthy.load(Ordering::Relaxed) {
            self.unhealthy.store(false, Ordering::Relaxed);
        }
    }

    pub fn is_healthy(&self) -> bool {
        !self.unhealthy.load(Ordering::Relaxed)
    }

    /// Total watchdog trips over the process lifetime (monotonic).
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nth_fires_exactly_once() {
        let p = FaultPlan::parse("op:nth=3:fail").unwrap();
        assert!(p.on_exec("op").is_ok());
        assert!(p.on_exec("op").is_ok());
        let err = p.on_exec("op").unwrap_err();
        assert_eq!(classify(&err), FaultClass::Transient);
        assert!(p.on_exec("op").is_ok(), "nth fires once, not from N on");
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn every_fires_periodically_and_only_on_matches() {
        let p = FaultPlan::parse("net_*:every=2:perm").unwrap();
        assert!(p.on_exec("agent_act").is_ok()); // no match, no count
        assert!(p.on_exec("net_train").is_ok());
        let err = p.on_exec("net_eval").unwrap_err();
        assert_eq!(classify(&err), FaultClass::Permanent);
        assert!(p.on_exec("net_train").is_ok());
        assert!(p.on_exec("net_train").is_err());
        assert_eq!(p.injected(), 2);
    }

    #[test]
    fn prob_is_deterministic_across_identical_plans() {
        let spec = "seed=99,*:prob=0.5:fail";
        let a = FaultPlan::parse(spec).unwrap();
        let b = FaultPlan::parse(spec).unwrap();
        let run = |p: &FaultPlan| -> Vec<bool> {
            (0..64).map(|_| p.on_exec("x").is_err()).collect()
        };
        let ra = run(&a);
        assert_eq!(ra, run(&b), "same seed must inject the same schedule");
        assert!(ra.iter().any(|&f| f) && !ra.iter().all(|&f| f));
    }

    #[test]
    fn seed_position_does_not_matter() {
        let a = FaultPlan::parse("seed=5,*:prob=0.3:fail").unwrap();
        let b = FaultPlan::parse("*:prob=0.3:fail,seed=5").unwrap();
        let run = |p: &FaultPlan| -> Vec<bool> {
            (0..32).map(|_| p.on_exec("x").is_err()).collect()
        };
        assert_eq!(run(&a), run(&b));
    }

    #[test]
    fn delay_injects_latency_but_no_error() {
        let p = FaultPlan::parse("op:every=1:delay=10").unwrap();
        let t0 = std::time::Instant::now();
        assert!(p.on_exec("op").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(10));
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn bad_specs_are_loud() {
        assert!(FaultPlan::parse("op:nth=3").is_err());
        assert!(FaultPlan::parse("op:sometimes:fail").is_err());
        assert!(FaultPlan::parse("op:nth=3:explode").is_err());
        assert!(FaultPlan::parse("op:prob=1.5:fail").is_err());
        assert!(FaultPlan::parse("seed=xyzzy,op:nth=1:fail").is_err());
    }

    #[test]
    fn rules_file_round_trips() {
        let dir = std::env::temp_dir().join("releq_fault_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.txt");
        std::fs::write(&path, "# chaos plan\nseed=3\nop:nth=1:fail\n").unwrap();
        let p = FaultPlan::load(path.to_str().unwrap()).unwrap();
        assert!(p.on_exec("op").is_err());
        assert!(p.on_exec("op").is_ok());
    }

    #[test]
    fn classify_typed_and_heuristic() {
        let t: anyhow::Error = FaultError::Transient("x".into()).into();
        let p: anyhow::Error = FaultError::Permanent("x".into()).into();
        assert_eq!(classify(&t), FaultClass::Transient);
        assert_eq!(classify(&p), FaultClass::Permanent);
        // typed errors win through context wrapping
        assert_eq!(classify(&t.context("executing `lenet_train`")), FaultClass::Transient);
        let real = anyhow::anyhow!("UNAVAILABLE: backend channel reset");
        assert_eq!(classify(&real), FaultClass::Transient);
        let bug = anyhow::anyhow!("shape mismatch: [4] vs [8]");
        assert_eq!(classify(&bug), FaultClass::Permanent);
    }

    #[test]
    fn backoff_is_bounded_and_deterministic() {
        let pol = RetryPolicy { max_retries: 8, base_ms: 10, cap_ms: 80, seed: 1 };
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for k in 0..8 {
            let da = pol.backoff(k, &mut a);
            assert_eq!(da, pol.backoff(k, &mut b));
            assert!(da <= Duration::from_millis(80), "cap violated at retry {k}");
            assert!(da >= Duration::from_millis(1));
        }
    }

    #[test]
    fn retry_recovers_transient_and_propagates_permanent() {
        let pol = RetryPolicy { max_retries: 3, base_ms: 1, cap_ms: 2, seed: 7 };
        let counter = AtomicU64::new(0);
        let mut calls = 0u32;
        let out = retry_transient(&pol, "t", Some(&counter), || {
            calls += 1;
            if calls < 3 {
                Err(FaultError::Transient("flaky".into()).into())
            } else {
                Ok(calls)
            }
        });
        assert_eq!(out.unwrap(), 3);
        assert_eq!(counter.load(Ordering::Relaxed), 2);

        let mut calls = 0u32;
        let out: Result<u32> = retry_transient(&pol, "p", None, || {
            calls += 1;
            Err(FaultError::Permanent("broken".into()).into())
        });
        assert!(out.is_err());
        assert_eq!(calls, 1, "permanent failures must fail fast");
    }

    #[test]
    fn retry_budget_is_bounded() {
        let pol = RetryPolicy { max_retries: 2, base_ms: 1, cap_ms: 1, seed: 7 };
        let mut calls = 0u32;
        let out: Result<u32> = retry_transient(&pol, "b", None, || {
            calls += 1;
            Err(FaultError::Transient("always".into()).into())
        });
        assert!(out.is_err());
        assert_eq!(calls, 3, "1 attempt + 2 retries");
    }

    #[test]
    fn health_trips_and_recovers() {
        let h = Health::new();
        assert!(h.is_healthy());
        h.trip();
        assert!(!h.is_healthy());
        assert_eq!(h.trips(), 1);
        h.ok();
        assert!(h.is_healthy());
        assert_eq!(h.trips(), 1, "trip count is monotonic across recovery");
    }
}
