//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them once,
//! and runs them from the coordinator hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! emits serialized protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).
//! Every artifact is lowered with `return_tuple=True`, so execution returns a
//! single tuple literal that [`Exe::run`] decomposes.
//!
//! The engine is `Send + Sync`: the compile cache sits behind an `RwLock`,
//! execution counters are atomics, and one `Engine` is shared across the
//! sharded drivers in `crate::parallel` (PJRT clients serialize access to
//! their internal state; concurrent `Execute` calls on a CPU client are part
//! of the PJRT API contract).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::faults::{retry_transient, FaultPlan, Health, RetryPolicy};

/// A compiled artifact plus execution statistics.
///
/// Counters are atomics so `&Exe` can be shared across shard threads; the
/// relaxed ordering is fine because they are only read for reporting.
///
/// Timing is split into two components so the async dispatcher's wins are
/// attributable: `exec_ns` covers the PJRT `Execute` call (device work and
/// its dispatch), `download_ns` covers `to_literal_sync` + tuple
/// decomposition (the device→host result download, which is also where an
/// asynchronous backend's completion wait would land).
pub struct Exe {
    pub name: String,
    inner: PjRtLoadedExecutable,
    pub exec_count: AtomicU64,
    /// device-exec component (the `Execute` call itself)
    pub exec_ns: AtomicU64,
    /// literal-download component (`to_literal_sync` + `to_tuple`)
    pub download_ns: AtomicU64,
    /// the engine's fault-injection plan (`None` — the common case — is a
    /// single branch on the hot path)
    faults: Option<Arc<FaultPlan>>,
    /// transient-failure retry policy shared with the owning engine
    retry: RetryPolicy,
    /// engine health flag: completed executions clear it
    health: Arc<Health>,
    /// engine-wide retry counter (shared across all `Exe`s)
    retries: Arc<AtomicU64>,
}

// SAFETY: `PjRtLoadedExecutable` wraps an immutable compiled program; the
// PJRT C API specifies that `Execute` may be called concurrently from
// multiple threads on the same executable (the CPU client locks internally).
// The remaining fields are atomics/plain data.
//
// REQUIREMENT on the vendored `xla` binding (applies to every unsafe impl in
// this file): the wrapper types must hold no non-atomic shared state of their
// own (e.g. an internal `Rc` client handle cloned per call). The offline
// build vendors a binding whose handles are plain FFI pointers; if the
// binding is swapped for one with `Rc`-based internals, these impls are
// unsound and must be replaced with a mutex-per-client wrapper.
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

impl Exe {
    /// `t0` = execute start, `t1` = execute returned / download started.
    fn record(&self, t0: Instant, t1: Instant) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        self.exec_ns
            .fetch_add(t1.duration_since(t0).as_nanos() as u64, Ordering::Relaxed);
        self.download_ns
            .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Execute with host literals; returns the decomposed output tuple.
    /// Accepts `&[&Literal]` (or owned) so callers can reuse cached operands.
    /// Transient failures (injected or backend-reported) are retried per the
    /// engine's [`RetryPolicy`]; the programs are pure functions of their
    /// operands, so a retried execution returns bit-identical results.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        if self.retry.max_retries == 0 {
            return self.attempt(args);
        }
        retry_transient(&self.retry, &self.name, Some(&self.retries), || self.attempt(args))
    }

    fn attempt<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        if let Some(f) = &self.faults {
            f.on_exec(&self.name)?;
        }
        let t0 = Instant::now();
        let mut out = self
            .inner
            .execute::<L>(args)
            .with_context(|| format!("executing `{}`", self.name))?;
        let buf = out
            .first_mut()
            .and_then(|d| d.pop())
            .with_context(|| format!("`{}` returned no outputs", self.name))?;
        let t1 = Instant::now();
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        self.record(t0, t1);
        self.health.ok();
        Ok(parts)
    }

    /// Execute with device-resident buffers (perf hot path: persistent
    /// operands like the training set or agent parameters are uploaded once
    /// and reused across thousands of executions). Same retry semantics as
    /// [`Exe::run`].
    pub fn run_b<B: std::borrow::Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Literal>> {
        if self.retry.max_retries == 0 {
            return self.attempt_b(args);
        }
        retry_transient(&self.retry, &self.name, Some(&self.retries), || self.attempt_b(args))
    }

    fn attempt_b<B: std::borrow::Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Literal>> {
        if let Some(f) = &self.faults {
            f.on_exec(&self.name)?;
        }
        let t0 = Instant::now();
        let mut out = self
            .inner
            .execute_b::<B>(args)
            .with_context(|| format!("executing `{}` (buffers)", self.name))?;
        let buf = out
            .first_mut()
            .and_then(|d| d.pop())
            .with_context(|| format!("`{}` returned no outputs", self.name))?;
        let t1 = Instant::now();
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        self.record(t0, t1);
        self.health.ok();
        Ok(parts)
    }

    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    /// Mean device-exec time per execution (the `Execute` call only).
    pub fn mean_exec_ms(&self) -> f64 {
        let n = self.exec_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.exec_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }

    /// Mean result-download time per execution (`to_literal_sync` + tuple
    /// decomposition). `mean_exec_ms + mean_download_ms` reproduces the
    /// pre-split conflated per-exec mean.
    pub fn mean_download_ms(&self) -> f64 {
        let n = self.exec_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.download_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }
}

/// One row of [`Engine::exec_stats`]: per-artifact execution count and the
/// split per-exec means (device-exec vs result-download).
#[derive(Debug, Clone)]
pub struct ExeStat {
    pub name: String,
    pub execs: u64,
    pub mean_exec_ms: f64,
    pub mean_download_ms: f64,
}

/// A device-resident operand. Wraps `PjRtBuffer` so persistent operands can
/// be held by `Send + Sync` owners (`QuantEnv` shards, the PPO agent).
pub struct DeviceBuf(PjRtBuffer);

// SAFETY: a `PjRtBuffer` is immutable once the host->device transfer
// completes (all uploads here are synchronous), and PJRT permits passing the
// same buffer as an input to concurrent executions. We never alias a
// donated/aliased output buffer.
unsafe impl Send for DeviceBuf {}
unsafe impl Sync for DeviceBuf {}

impl DeviceBuf {
    pub fn raw(&self) -> &PjRtBuffer {
        &self.0
    }
}

/// Reusable host-side staging buffer for operands assembled fresh on every
/// execution — the K×L candidate-bits matrix and K-lane cursor vector of
/// the batched accuracy query. The allocation survives across executions
/// (cleared, capacity retained), so the K-ary hot path stages thousands of
/// uploads with zero steady-state heap churn.
///
/// On *device*-side reuse: PJRT input donation (aliasing an input buffer
/// into an output) is not exposed by the vendored `xla` binding, and the
/// staged operands here are tiny (K×L f32s) next to the resident train/val
/// sets, so the per-execution host→device transfer is the whole cost — and
/// it is negligible against the execution itself (EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct Stage {
    buf: Vec<f32>,
}

impl Stage {
    pub fn new() -> Stage {
        Stage::default()
    }

    /// Clear and hand out the staging vector for refilling. Capacity from
    /// previous executions is retained.
    pub fn start(&mut self) -> &mut Vec<f32> {
        self.buf.clear();
        &mut self.buf
    }

    /// Upload the staged contents as a device buffer of logical shape
    /// `dims` (must cover the staged length exactly).
    pub fn upload(&self, engine: &Engine, dims: &[usize]) -> Result<DeviceBuf> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(
            n == self.buf.len(),
            "staged {} f32s but shape {dims:?} wants {n}",
            self.buf.len()
        );
        engine.buffer_f32(&self.buf, dims)
    }
}

/// An immutable host literal that may be shared across shard threads (e.g.
/// the validation-set operands held by the shared env core).
///
/// SAFETY: a `Literal` is a plain host-memory buffer; after construction it
/// is only ever read (`Exe::run` borrows it immutably to stage the transfer).
/// The same vendored-binding requirement as `Exe`/`DeviceBuf` applies: the
/// wrapper must hold no non-atomic shared internals.
pub struct HostLit(Literal);

unsafe impl Send for HostLit {}
unsafe impl Sync for HostLit {}

impl HostLit {
    pub fn new(lit: Literal) -> HostLit {
        HostLit(lit)
    }

    pub fn raw(&self) -> &Literal {
        &self.0
    }
}

/// Engine: one PJRT CPU client + a compile-once executable cache keyed by
/// artifact name (`lenet_train`, `agent_lstm_act`, ...).
///
/// `Send + Sync`: share it as `Arc<Engine>` across shard threads. Two threads
/// racing on the same uncached artifact may both compile it; the first insert
/// wins and both receive the same cached `Arc<Exe>` (see the compile-cache
/// race test in `rust/tests/parallel_concurrency.rs`).
pub struct Engine {
    pub client: PjRtClient,
    pub dir: PathBuf,
    cache: RwLock<HashMap<String, Arc<Exe>>>,
    /// fault-injection plan handed to every compiled `Exe` (`None` = no
    /// fault checks on the hot path)
    faults: Option<Arc<FaultPlan>>,
    /// transient-failure retry policy handed to every compiled `Exe`
    retry: RetryPolicy,
    /// healthy/unhealthy flag shared with the dispatch watchdog and serve
    health: Arc<Health>,
    /// total transient-failure retries across all artifacts
    exec_retries: Arc<AtomicU64>,
}

// SAFETY: `PjRtClient` (CPU) is thread-safe per the PJRT API contract —
// compilation and buffer creation take the client's internal lock. The cache
// is behind an `RwLock`.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Standard constructor: fault plan from `$RELEQ_FAULTS` (usually none)
    /// and retry policy from `$RELEQ_EXEC_RETRIES`/`$RELEQ_RETRY_BASE_MS`.
    pub fn new(artifacts_dir: PathBuf) -> Result<Engine> {
        Engine::with_faults(artifacts_dir, FaultPlan::from_env()?, RetryPolicy::from_env()?)
    }

    /// Constructor with an explicit fault plan and retry policy (chaos
    /// tests and the `--faults` CLI seam).
    pub fn with_faults(
        artifacts_dir: PathBuf,
        faults: Option<Arc<FaultPlan>>,
        retry: RetryPolicy,
    ) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: artifacts_dir,
            cache: RwLock::new(HashMap::new()),
            faults: faults.filter(|f| !f.is_empty()),
            retry,
            health: Arc::new(Health::new()),
            exec_retries: Arc::new(AtomicU64::new(0)),
        })
    }

    /// The engine's healthy/unhealthy flag (shared with watchdogs + serve).
    pub fn health(&self) -> Arc<Health> {
        self.health.clone()
    }

    /// Transient-failure retries spent across all artifacts.
    pub fn exec_retries(&self) -> u64 {
        self.exec_retries.load(Ordering::Relaxed)
    }

    /// Faults injected by the active plan (0 without a plan).
    pub fn faults_injected(&self) -> u64 {
        self.faults.as_ref().map_or(0, |f| f.injected())
    }

    /// Fetch (compiling on first use) the executable for `artifacts/<name>.hlo.txt`.
    pub fn exe(&self, name: &str) -> Result<Arc<Exe>> {
        if let Some(e) = self.cache.read().unwrap().get(name) {
            return Ok(e.clone());
        }
        // Compile outside the lock: compilation can take seconds and must not
        // serialize unrelated shards. A concurrent thread may compile the
        // same artifact; `entry().or_insert_with` below keeps exactly one.
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .with_context(|| format!("artifact path {path:?} is not valid UTF-8"))?;
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("loading {path:?} — run `make artifacts`"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{name}`"))?;
        let e = Arc::new(Exe {
            name: name.to_string(),
            inner: exe,
            exec_count: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            download_ns: AtomicU64::new(0),
            faults: self.faults.clone(),
            retry: self.retry.clone(),
            health: self.health.clone(),
            retries: self.exec_retries.clone(),
        });
        let e = self
            .cache
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert(e)
            .clone();
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.5 {
            eprintln!("[engine] compiled `{name}` in {dt:.1}s");
        }
        Ok(e)
    }

    /// Per-executable timing summary (perf instrumentation), name-sorted.
    pub fn exec_stats(&self) -> Vec<ExeStat> {
        let mut v: Vec<ExeStat> = self
            .cache
            .read()
            .unwrap()
            .values()
            .map(|e| ExeStat {
                name: e.name.clone(),
                execs: e.exec_count(),
                mean_exec_ms: e.mean_exec_ms(),
                mean_download_ms: e.mean_download_ms(),
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Number of compiled artifacts currently cached.
    pub fn cached_exes(&self) -> usize {
        self.cache.read().unwrap().len()
    }
}

impl Engine {
    /// Upload an f32 tensor to the device (persistent operand).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuf> {
        Ok(DeviceBuf(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?))
    }

    /// Upload an f32 scalar to the device.
    pub fn buffer_scalar(&self, x: f32) -> Result<DeviceBuf> {
        self.buffer_f32(&[x], &[])
    }
}

// ---- literal helpers ---------------------------------------------------------

/// Build an f32 literal of the given logical shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    if dims.len() == 1 {
        return Ok(Literal::vec1(data));
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Extract the f32 payload of a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_send_sync<T: Send + Sync>() {}

    /// Compile-time assertion: the runtime types cross shard threads.
    #[test]
    fn engine_types_are_send_sync() {
        assert_send_sync::<Engine>();
        assert_send_sync::<Exe>();
        assert_send_sync::<DeviceBuf>();
        assert_send_sync::<Arc<Engine>>();
        assert_send_sync::<Arc<Exe>>();
        assert_send_sync::<std::sync::Mutex<Stage>>();
    }

    #[test]
    fn stage_clears_but_keeps_capacity() {
        let mut s = Stage::new();
        s.start().extend_from_slice(&[1.0; 64]);
        let cap = {
            let b = s.start();
            assert!(b.is_empty(), "start() must clear the previous staging");
            b.capacity()
        };
        assert!(cap >= 64, "capacity must survive restaging");
    }
}
