//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them once,
//! and runs them from the coordinator hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! emits serialized protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).
//! Every artifact is lowered with `return_tuple=True`, so execution returns a
//! single tuple literal that [`Exe::run`] decomposes.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// A compiled artifact plus execution statistics.
pub struct Exe {
    pub name: String,
    inner: PjRtLoadedExecutable,
    pub exec_count: RefCell<u64>,
    pub exec_ns: RefCell<u128>,
}

impl Exe {
    /// Execute with host literals; returns the decomposed output tuple.
    /// Accepts `&[&Literal]` (or owned) so callers can reuse cached operands.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let mut out = self
            .inner
            .execute::<L>(args)
            .with_context(|| format!("executing `{}`", self.name))?;
        let buf = out
            .first_mut()
            .and_then(|d| d.pop())
            .with_context(|| format!("`{}` returned no outputs", self.name))?;
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        *self.exec_count.borrow_mut() += 1;
        *self.exec_ns.borrow_mut() += t0.elapsed().as_nanos();
        Ok(parts)
    }

    /// Execute with device-resident buffers (perf hot path: persistent
    /// operands like the training set or agent parameters are uploaded once
    /// and reused across thousands of executions).
    pub fn run_b<B: std::borrow::Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let mut out = self
            .inner
            .execute_b::<B>(args)
            .with_context(|| format!("executing `{}` (buffers)", self.name))?;
        let buf = out
            .first_mut()
            .and_then(|d| d.pop())
            .with_context(|| format!("`{}` returned no outputs", self.name))?;
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        *self.exec_count.borrow_mut() += 1;
        *self.exec_ns.borrow_mut() += t0.elapsed().as_nanos();
        Ok(parts)
    }

    pub fn mean_exec_ms(&self) -> f64 {
        let n = *self.exec_count.borrow();
        if n == 0 {
            return 0.0;
        }
        *self.exec_ns.borrow() as f64 / n as f64 / 1e6
    }
}

/// Engine: one PJRT CPU client + a compile-once executable cache keyed by
/// artifact name (`lenet_train`, `agent_lstm_act`, ...).
pub struct Engine {
    pub client: PjRtClient,
    pub dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
}

impl Engine {
    pub fn new(artifacts_dir: PathBuf) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, dir: artifacts_dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Fetch (compiling on first use) the executable for `artifacts/<name>.hlo.txt`.
    pub fn exe(&self, name: &str) -> Result<Rc<Exe>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("loading {path:?} — run `make artifacts`"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{name}`"))?;
        let e = Rc::new(Exe {
            name: name.to_string(),
            inner: exe,
            exec_count: RefCell::new(0),
            exec_ns: RefCell::new(0),
        });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.5 {
            eprintln!("[engine] compiled `{name}` in {dt:.1}s");
        }
        Ok(e)
    }

    /// Per-executable timing summary (perf instrumentation).
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .cache
            .borrow()
            .values()
            .map(|e| (e.name.clone(), *e.exec_count.borrow(), e.mean_exec_ms()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

impl Engine {
    /// Upload an f32 tensor to the device (persistent operand).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }
}

// ---- literal helpers ---------------------------------------------------------

/// Build an f32 literal of the given logical shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    if dims.len() == 1 {
        return Ok(Literal::vec1(data));
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Extract the f32 payload of a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
