//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them once,
//! and runs them from the coordinator hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! emits serialized protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).
//! Every artifact is lowered with `return_tuple=True`, so execution returns a
//! single tuple literal that [`Exe::run`] decomposes.
//!
//! # Device pool
//!
//! The engine is a pool of N PJRT devices. On the CPU backend each pool slot
//! is its own `PjRtClient::cpu()` instance — the Rust-side analogue of
//! forcing `xla_force_host_platform_device_count=N`, so N > 1 is testable on
//! any host. Each slot owns its compile cache (an executable and its buffers
//! are bound to the client that created them, so the cache is effectively
//! keyed by `(artifact, device)`), an in-flight counter and a health flag;
//! the fault-injection plan, retry policy, `exec_retries` counter and the
//! aggregate health flag are **pool-global** — one `$RELEQ_FAULTS` plan
//! drives every device, so `every=N` triggers count executions across the
//! whole pool and the `exec_retries == faults_injected` invariant from the
//! fault-tolerance suite holds at any device count.
//!
//! Device 0 is the default: `exe`/`buffer_f32` are exactly the pre-pool
//! single-client paths, which is what makes `--devices 1` replay the
//! single-engine behavior byte for byte. Placement helpers
//! ([`Engine::place_chunk`], [`Engine::least_loaded_device`],
//! [`Engine::pin_thread`]) let the megabatch evaluator stripe chunks across
//! devices, `run_replicas`/Pareto shards pin one device per shard thread,
//! and the dispatcher's speculative work land on the least-loaded healthy
//! device.
//!
//! The engine is `Send + Sync`: caches sit behind `RwLock`s, execution
//! counters are atomics, and one `Engine` is shared across the sharded
//! drivers in `crate::parallel` (PJRT clients serialize access to their
//! internal state; concurrent `Execute` calls on a CPU client are part of
//! the PJRT API contract).

use std::cell::Cell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::faults::{retry_transient, FaultPlan, Health, RetryPolicy};

/// Environment knob for the pool size (`releq --devices` overrides upward
/// via [`Engine::ensure_devices`]). The CPU analogue of JAX's
/// `xla_force_host_platform_device_count`.
pub const DEVICES_ENV: &str = "RELEQ_DEVICES";

fn devices_from_env() -> usize {
    std::env::var(DEVICES_ENV)
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

thread_local! {
    /// Per-thread device pin (see [`Engine::pin_thread`]). `None` = unpinned:
    /// chunk placement round-robins across the pool.
    static DEVICE_PIN: Cell<Option<usize>> = Cell::new(None);
}

/// The thread's currently pinned device, if any.
pub fn thread_pin() -> Option<usize> {
    DEVICE_PIN.with(|p| p.get())
}

/// RAII guard from [`Engine::pin_thread`]: restores the previous pin (usually
/// `None`) on drop, so dispatcher worker threads and shard pools can borrow a
/// pin for one task without leaking it into the next.
pub struct DevicePin {
    prev: Option<usize>,
}

impl Drop for DevicePin {
    fn drop(&mut self) {
        DEVICE_PIN.with(|p| p.set(self.prev));
    }
}

/// Decrement-on-drop in-flight guard: covers the whole execution attempt
/// (including injected stalls), so a wedged device keeps its depth elevated
/// and the least-loaded placement routes around it.
struct InflightGuard<'a>(&'a AtomicU64);

impl<'a> InflightGuard<'a> {
    fn enter(counter: &'a AtomicU64) -> InflightGuard<'a> {
        counter.fetch_add(1, Ordering::Relaxed);
        InflightGuard(counter)
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A compiled artifact plus execution statistics.
///
/// Counters are atomics so `&Exe` can be shared across shard threads; the
/// relaxed ordering is fine because they are only read for reporting.
///
/// Timing is split into two components so the async dispatcher's wins are
/// attributable: `exec_ns` covers the PJRT `Execute` call (device work and
/// its dispatch), `download_ns` covers `to_literal_sync` + tuple
/// decomposition (the device→host result download, which is also where an
/// asynchronous backend's completion wait would land).
pub struct Exe {
    pub name: String,
    inner: PjRtLoadedExecutable,
    /// pool device this executable (and every buffer passed to it) lives on
    device: usize,
    pub exec_count: AtomicU64,
    /// device-exec component (the `Execute` call itself)
    pub exec_ns: AtomicU64,
    /// literal-download component (`to_literal_sync` + `to_tuple`)
    pub download_ns: AtomicU64,
    /// the engine's fault-injection plan (`None` — the common case — is a
    /// single branch on the hot path). Pool-global: every device's `Exe`s
    /// hold the SAME `Arc`, so rule counters fire across the whole pool.
    faults: Option<Arc<FaultPlan>>,
    /// transient-failure retry policy shared with the owning engine
    retry: RetryPolicy,
    /// pool-aggregate health flag: completed executions clear it
    health: Arc<Health>,
    /// this device's health flag (watchdog aborts trip it; completions
    /// clear it) — a sick device degrades placement, not the whole pool
    device_health: Arc<Health>,
    /// this device's in-flight execution depth (shared by the device's exes)
    inflight: Arc<AtomicU64>,
    /// pool-global retry counter (shared across all `Exe`s on all devices)
    retries: Arc<AtomicU64>,
}

// SAFETY: `PjRtLoadedExecutable` wraps an immutable compiled program; the
// PJRT C API specifies that `Execute` may be called concurrently from
// multiple threads on the same executable (the CPU client locks internally).
// The remaining fields are atomics/plain data.
//
// REQUIREMENT on the vendored `xla` binding (applies to every unsafe impl in
// this file): the wrapper types must hold no non-atomic shared state of their
// own (e.g. an internal `Rc` client handle cloned per call). The offline
// build vendors a binding whose handles are plain FFI pointers; if the
// binding is swapped for one with `Rc`-based internals, these impls are
// unsound and must be replaced with a mutex-per-client wrapper.
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

impl Exe {
    /// `t0` = execute start, `t1` = execute returned / download started.
    fn record(&self, t0: Instant, t1: Instant) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        self.exec_ns
            .fetch_add(t1.duration_since(t0).as_nanos() as u64, Ordering::Relaxed);
        self.download_ns
            .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Execute with host literals; returns the decomposed output tuple.
    /// Accepts `&[&Literal]` (or owned) so callers can reuse cached operands.
    /// Transient failures (injected or backend-reported) are retried per the
    /// engine's [`RetryPolicy`]; the programs are pure functions of their
    /// operands, so a retried execution returns bit-identical results.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        if self.retry.max_retries == 0 {
            return self.attempt(args);
        }
        retry_transient(&self.retry, &self.name, Some(&*self.retries), || self.attempt(args))
    }

    fn attempt<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        // the guard spans the fault hook too: an injected stall models a
        // wedged execution and must keep the device's in-flight depth up
        let _load = InflightGuard::enter(&self.inflight);
        if let Some(f) = &self.faults {
            f.on_exec(&self.name)?;
        }
        let t0 = Instant::now();
        let mut out = self
            .inner
            .execute::<L>(args)
            .with_context(|| format!("executing `{}`", self.name))?;
        let buf = out
            .first_mut()
            .and_then(|d| d.pop())
            .with_context(|| format!("`{}` returned no outputs", self.name))?;
        let t1 = Instant::now();
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        self.record(t0, t1);
        self.health.ok();
        self.device_health.ok();
        Ok(parts)
    }

    /// Execute with device-resident buffers (perf hot path: persistent
    /// operands like the training set or agent parameters are uploaded once
    /// and reused across thousands of executions). Same retry semantics as
    /// [`Exe::run`]. Buffers must live on this exe's device (they do by
    /// construction: every `buffer_*_on` caller uses the device it compiled
    /// for).
    pub fn run_b<B: std::borrow::Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Literal>> {
        if self.retry.max_retries == 0 {
            return self.attempt_b(args);
        }
        retry_transient(&self.retry, &self.name, Some(&*self.retries), || self.attempt_b(args))
    }

    fn attempt_b<B: std::borrow::Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Literal>> {
        let _load = InflightGuard::enter(&self.inflight);
        if let Some(f) = &self.faults {
            f.on_exec(&self.name)?;
        }
        let t0 = Instant::now();
        let mut out = self
            .inner
            .execute_b::<B>(args)
            .with_context(|| format!("executing `{}` (buffers)", self.name))?;
        let buf = out
            .first_mut()
            .and_then(|d| d.pop())
            .with_context(|| format!("`{}` returned no outputs", self.name))?;
        let t1 = Instant::now();
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        self.record(t0, t1);
        self.health.ok();
        self.device_health.ok();
        Ok(parts)
    }

    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    /// Pool device index this executable is compiled for.
    pub fn device(&self) -> usize {
        self.device
    }

    /// The owning device's health flag (the dispatcher's watchdog trips it
    /// on a hung dispatched execution; any completed execution clears it).
    pub fn device_health(&self) -> Arc<Health> {
        self.device_health.clone()
    }

    /// Mean device-exec time per execution (the `Execute` call only).
    pub fn mean_exec_ms(&self) -> f64 {
        let n = self.exec_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.exec_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }

    /// Mean result-download time per execution (`to_literal_sync` + tuple
    /// decomposition). `mean_exec_ms + mean_download_ms` reproduces the
    /// pre-split conflated per-exec mean.
    pub fn mean_download_ms(&self) -> f64 {
        let n = self.exec_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.download_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }
}

/// One row of [`Engine::exec_stats`]: per-`(artifact, device)` execution
/// count and the split per-exec means (device-exec vs result-download).
/// Summing `execs` over rows gives the pool total (each execution is
/// counted on exactly one device) — the accounting
/// `rust/tests/serve_daemon.rs` and `device_pool_parity.rs` pin.
#[derive(Debug, Clone)]
pub struct ExeStat {
    pub name: String,
    /// pool device the executions ran on
    pub device: usize,
    pub execs: u64,
    pub mean_exec_ms: f64,
    pub mean_download_ms: f64,
}

/// A device-resident operand. Wraps `PjRtBuffer` so persistent operands can
/// be held by `Send + Sync` owners (`QuantEnv` shards, the PPO agent).
pub struct DeviceBuf(PjRtBuffer);

// SAFETY: a `PjRtBuffer` is immutable once the host->device transfer
// completes (all uploads here are synchronous), and PJRT permits passing the
// same buffer as an input to concurrent executions. We never alias a
// donated/aliased output buffer.
unsafe impl Send for DeviceBuf {}
unsafe impl Sync for DeviceBuf {}

impl DeviceBuf {
    pub fn raw(&self) -> &PjRtBuffer {
        &self.0
    }
}

/// Reusable host-side staging buffer for operands assembled fresh on every
/// execution — the K×L candidate-bits matrix and K-lane cursor vector of
/// the batched accuracy query. The allocation survives across executions
/// (cleared, capacity retained), so the K-ary hot path stages thousands of
/// uploads with zero steady-state heap churn.
///
/// On *device*-side reuse: PJRT input donation (aliasing an input buffer
/// into an output) is not exposed by the vendored `xla` binding, and the
/// staged operands here are tiny (K×L f32s) next to the resident train/val
/// sets, so the per-execution host→device transfer is the whole cost — and
/// it is negligible against the execution itself (EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct Stage {
    buf: Vec<f32>,
}

impl Stage {
    pub fn new() -> Stage {
        Stage::default()
    }

    /// Clear and hand out the staging vector for refilling. Capacity from
    /// previous executions is retained.
    pub fn start(&mut self) -> &mut Vec<f32> {
        self.buf.clear();
        &mut self.buf
    }

    /// Upload the staged contents as a device buffer of logical shape
    /// `dims` (must cover the staged length exactly). Device 0.
    pub fn upload(&self, engine: &Engine, dims: &[usize]) -> Result<DeviceBuf> {
        self.upload_on(engine, dims, 0)
    }

    /// Upload the staged contents to pool device `dev`.
    pub fn upload_on(&self, engine: &Engine, dims: &[usize], dev: usize) -> Result<DeviceBuf> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(
            n == self.buf.len(),
            "staged {} f32s but shape {dims:?} wants {n}",
            self.buf.len()
        );
        engine.buffer_f32_on(&self.buf, dims, dev)
    }
}

/// An immutable host literal that may be shared across shard threads (e.g.
/// the validation-set operands held by the shared env core).
///
/// SAFETY: a `Literal` is a plain host-memory buffer; after construction it
/// is only ever read (`Exe::run` borrows it immutably to stage the transfer).
/// The same vendored-binding requirement as `Exe`/`DeviceBuf` applies: the
/// wrapper must hold no non-atomic shared internals.
pub struct HostLit(Literal);

unsafe impl Send for HostLit {}
unsafe impl Sync for HostLit {}

impl HostLit {
    pub fn new(lit: Literal) -> HostLit {
        HostLit(lit)
    }

    pub fn raw(&self) -> &Literal {
        &self.0
    }
}

/// One pool slot: a PJRT CPU client plus everything bound to it — the
/// compile-once executable cache (client-bound, so the pool's caches are
/// jointly keyed by `(artifact, device)`), the device's in-flight counter,
/// and its health flag.
struct DeviceSlot {
    client: PjRtClient,
    cache: RwLock<HashMap<String, Arc<Exe>>>,
    health: Arc<Health>,
    inflight: Arc<AtomicU64>,
}

// SAFETY: `PjRtClient` (CPU) is thread-safe per the PJRT API contract —
// compilation and buffer creation take the client's internal lock. The cache
// is behind an `RwLock`; the rest is atomics. Same vendored-binding
// requirement as `Exe` above.
unsafe impl Send for DeviceSlot {}
unsafe impl Sync for DeviceSlot {}

impl DeviceSlot {
    fn new() -> Result<DeviceSlot> {
        Ok(DeviceSlot {
            client: PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: RwLock::new(HashMap::new()),
            health: Arc::new(Health::new()),
            inflight: Arc::new(AtomicU64::new(0)),
        })
    }
}

/// Engine: a pool of PJRT CPU devices with per-device compile caches keyed
/// by artifact name (`lenet_train`, `agent_lstm_act`, ...). See the module
/// docs for the pool/placement model; device 0 is the default and replays
/// the pre-pool single-client engine exactly.
///
/// `Send + Sync`: share it as `Arc<Engine>` across shard threads. Two threads
/// racing on the same uncached `(artifact, device)` may both compile it; the
/// first insert wins and both receive the same cached `Arc<Exe>` (see the
/// compile-cache race test in `rust/tests/parallel_concurrency.rs`).
pub struct Engine {
    /// pool slots; grows monotonically via [`Engine::ensure_devices`]
    devices: RwLock<Vec<Arc<DeviceSlot>>>,
    pub dir: PathBuf,
    /// artifact-name → path overrides for registry-installed networks:
    /// `lenet2@a1b2c3d4e5f6_train` resolves to the content-addressed install
    /// dir instead of `dir/<name>.hlo.txt`. Because compile caches are keyed
    /// by the (qualified) artifact name, a qualified alias simultaneously
    /// gives every installed version its own cache entries — the compile
    /// cache key "gains the manifest digest" with no cache rekeying.
    aliases: RwLock<HashMap<String, PathBuf>>,
    /// fault-injection plan handed to every compiled `Exe` on every device
    /// (`None` = no fault checks on the hot path). POOL-GLOBAL on purpose:
    /// one plan's rule counters observe the execution stream of the whole
    /// pool, so `every=N`/`nth=N` triggers and the `injected()` total behave
    /// identically at any device count.
    faults: Option<Arc<FaultPlan>>,
    /// transient-failure retry policy handed to every compiled `Exe`
    retry: RetryPolicy,
    /// pool-aggregate healthy/unhealthy flag shared with the dispatch
    /// watchdog and serve
    health: Arc<Health>,
    /// total transient-failure retries across all artifacts and devices
    exec_retries: Arc<AtomicU64>,
}

// SAFETY: all fields are locks, atomics, `Arc`s and plain data; `DeviceSlot`
// carries its own justification above.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Standard constructor: fault plan from `$RELEQ_FAULTS` (usually none),
    /// retry policy from `$RELEQ_EXEC_RETRIES`/`$RELEQ_RETRY_BASE_MS`, pool
    /// size from `$RELEQ_DEVICES` (default 1; `--devices` grows it later
    /// through [`Engine::ensure_devices`]).
    pub fn new(artifacts_dir: PathBuf) -> Result<Engine> {
        Engine::with_faults(artifacts_dir, FaultPlan::from_env()?, RetryPolicy::from_env()?)
    }

    /// Constructor with an explicit pool size (parity tests and drivers that
    /// resolve `--devices` before bring-up); fault plan/retry still come
    /// from the environment like [`Engine::new`].
    pub fn with_devices(artifacts_dir: PathBuf, devices: usize) -> Result<Engine> {
        let e =
            Engine::with_faults(artifacts_dir, FaultPlan::from_env()?, RetryPolicy::from_env()?)?;
        e.ensure_devices(devices)?;
        Ok(e)
    }

    /// Constructor with an explicit fault plan and retry policy (chaos
    /// tests and the `--faults` CLI seam). The ONE plan passed here is
    /// shared by every device the pool ever grows to — per-device plans
    /// would silently split `every=N` rule counters and break the
    /// `exec_retries == faults_injected` invariant.
    pub fn with_faults(
        artifacts_dir: PathBuf,
        faults: Option<Arc<FaultPlan>>,
        retry: RetryPolicy,
    ) -> Result<Engine> {
        let n = devices_from_env();
        let mut slots = Vec::with_capacity(n);
        for _ in 0..n {
            slots.push(Arc::new(DeviceSlot::new()?));
        }
        Ok(Engine {
            devices: RwLock::new(slots),
            dir: artifacts_dir,
            aliases: RwLock::new(HashMap::new()),
            faults: faults.filter(|f| !f.is_empty()),
            retry,
            health: Arc::new(Health::new()),
            exec_retries: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Grow the pool to at least `n` devices (never shrinks — compiled
    /// executables and resident buffers on existing devices stay valid).
    /// `--devices`/job-config `devices` land here after config resolution.
    pub fn ensure_devices(&self, n: usize) -> Result<()> {
        anyhow::ensure!(n >= 1, "device pool needs at least 1 device");
        let mut slots = self.devices.write().unwrap();
        while slots.len() < n {
            slots.push(Arc::new(DeviceSlot::new()?));
        }
        Ok(())
    }

    /// Current pool size.
    pub fn n_devices(&self) -> usize {
        self.devices.read().unwrap().len()
    }

    fn slot(&self, dev: usize) -> Result<Arc<DeviceSlot>> {
        let slots = self.devices.read().unwrap();
        slots
            .get(dev)
            .cloned()
            .with_context(|| format!("device {dev} not in pool (size {})", slots.len()))
    }

    /// The pool-aggregate healthy/unhealthy flag (shared with watchdogs +
    /// serve).
    pub fn health(&self) -> Arc<Health> {
        self.health.clone()
    }

    /// Device `dev`'s own health flag (sick-device quarantine: the
    /// least-loaded placement skips unhealthy devices).
    pub fn device_health(&self, dev: usize) -> Result<Arc<Health>> {
        Ok(self.slot(dev)?.health.clone())
    }

    /// Per-device in-flight execution depth snapshot.
    pub fn device_loads(&self) -> Vec<u64> {
        self.devices
            .read()
            .unwrap()
            .iter()
            .map(|s| s.inflight.load(Ordering::Relaxed))
            .collect()
    }

    /// Per-device health snapshot (same order as [`Engine::device_loads`]).
    pub fn devices_healthy(&self) -> Vec<bool> {
        self.devices
            .read()
            .unwrap()
            .iter()
            .map(|s| s.health.is_healthy())
            .collect()
    }

    /// The device a deterministic work chunk `idx` belongs on: the calling
    /// thread's pin when one is set (replica / Pareto shards), else
    /// round-robin striping — at `n_devices == 1` this is always 0, which
    /// is what keeps `--devices 1` byte-for-byte identical.
    pub fn place_chunk(&self, idx: usize) -> usize {
        let n = self.n_devices().max(1);
        match thread_pin() {
            Some(d) if d < n => d,
            _ => idx % n,
        }
    }

    /// Least-loaded healthy device (ties break toward the lowest index;
    /// when every device is sick, fall back to the least-loaded overall so
    /// the pool degrades instead of deadlocking). See
    /// [`super::dispatch::pick_device`] for the policy itself.
    pub fn least_loaded_device(&self) -> usize {
        let (loads, healthy) = {
            let slots = self.devices.read().unwrap();
            (
                slots
                    .iter()
                    .map(|s| s.inflight.load(Ordering::Relaxed))
                    .collect::<Vec<u64>>(),
                slots.iter().map(|s| s.health.is_healthy()).collect::<Vec<bool>>(),
            )
        };
        super::dispatch::pick_device(&loads, &healthy, 0)
    }

    /// Pin the calling thread to device `dev % n_devices` until the returned
    /// guard drops. Pinned threads route all their chunk placement (and any
    /// device-defaulting compiles/uploads done through `current_device`) to
    /// that device — `run_replicas` pins shard `i` to device `i % N`.
    pub fn pin_thread(&self, dev: usize) -> DevicePin {
        let n = self.n_devices().max(1);
        let prev = DEVICE_PIN.with(|p| p.replace(Some(dev % n)));
        DevicePin { prev }
    }

    /// Pin the calling thread to the least-loaded healthy device (the
    /// dispatcher's speculative-prefetch placement).
    pub fn pin_least_loaded(&self) -> DevicePin {
        let d = self.least_loaded_device();
        self.pin_thread(d)
    }

    /// The device new compiles/uploads should default to on this thread:
    /// the thread's pin, else device 0.
    pub fn current_device(&self) -> usize {
        let n = self.n_devices().max(1);
        thread_pin().filter(|&d| d < n).unwrap_or(0)
    }

    /// Transient-failure retries spent across all artifacts and devices
    /// (pool-global counter).
    pub fn exec_retries(&self) -> u64 {
        self.exec_retries.load(Ordering::Relaxed)
    }

    /// Faults injected by the active plan across the whole pool (0 without
    /// a plan).
    pub fn faults_injected(&self) -> u64 {
        self.faults.as_ref().map_or(0, |f| f.injected())
    }

    /// Fetch (compiling on first use) the executable for
    /// `artifacts/<name>.hlo.txt` on device 0 — the pre-pool path, byte
    /// compatible with the single-engine behavior.
    pub fn exe(&self, name: &str) -> Result<Arc<Exe>> {
        self.exe_on(name, 0)
    }

    /// Fetch (compiling on first use) the executable for
    /// `artifacts/<name>.hlo.txt` on pool device `dev`. The compile cache is
    /// per-slot, so each artifact compiles at most once per device.
    pub fn exe_on(&self, name: &str, dev: usize) -> Result<Arc<Exe>> {
        let slot = self.slot(dev)?;
        if let Some(e) = slot.cache.read().unwrap().get(name) {
            return Ok(e.clone());
        }
        // Compile outside the lock: compilation can take seconds and must not
        // serialize unrelated shards. A concurrent thread may compile the
        // same artifact; `entry().or_insert` below keeps exactly one.
        // Registry aliases resolve first; everything else is `dir`-relative.
        let path = self
            .aliases
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_else(|| self.dir.join(format!("{name}.hlo.txt")));
        let path_str = path
            .to_str()
            .with_context(|| format!("artifact path {path:?} is not valid UTF-8"))?;
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("loading {path:?} — run `make artifacts`"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = slot
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{name}` for device {dev}"))?;
        let e = Arc::new(Exe {
            name: name.to_string(),
            inner: exe,
            device: dev,
            exec_count: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            download_ns: AtomicU64::new(0),
            faults: self.faults.clone(),
            retry: self.retry.clone(),
            health: self.health.clone(),
            device_health: slot.health.clone(),
            inflight: slot.inflight.clone(),
            retries: self.exec_retries.clone(),
        });
        let e = slot
            .cache
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert(e)
            .clone();
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.5 {
            eprintln!("[engine] compiled `{name}` for device {dev} in {dt:.1}s");
        }
        Ok(e)
    }

    /// Per-executable timing summary (perf instrumentation): one row per
    /// `(artifact, device)` that has been compiled, sorted by name then
    /// device. Summing `execs` over rows is the pool-total execution count.
    pub fn exec_stats(&self) -> Vec<ExeStat> {
        let slots: Vec<Arc<DeviceSlot>> = self.devices.read().unwrap().clone();
        let mut v: Vec<ExeStat> = Vec::new();
        for (dev, slot) in slots.iter().enumerate() {
            v.extend(slot.cache.read().unwrap().values().map(|e| ExeStat {
                name: e.name.clone(),
                device: dev,
                execs: e.exec_count(),
                mean_exec_ms: e.mean_exec_ms(),
                mean_download_ms: e.mean_download_ms(),
            }));
        }
        v.sort_by(|a, b| a.name.cmp(&b.name).then(a.device.cmp(&b.device)));
        v
    }

    /// Per-artifact stats aggregated across devices (execs summed, means
    /// exec-weighted): the rows whose `execs` sum is the same total a
    /// single-device engine would report — `/v1/stats` keeps its `engine`
    /// rows on this aggregate so `total_execs` accounting is unchanged by
    /// the pool.
    pub fn exec_stats_agg(&self) -> Vec<ExeStat> {
        let slots: Vec<Arc<DeviceSlot>> = self.devices.read().unwrap().clone();
        let mut agg: HashMap<String, (u64, u64, u64)> = HashMap::new();
        for slot in &slots {
            for e in slot.cache.read().unwrap().values() {
                let a = agg.entry(e.name.clone()).or_insert((0, 0, 0));
                a.0 += e.exec_count.load(Ordering::Relaxed);
                a.1 += e.exec_ns.load(Ordering::Relaxed);
                a.2 += e.download_ns.load(Ordering::Relaxed);
            }
        }
        let mut v: Vec<ExeStat> = agg
            .into_iter()
            .map(|(name, (execs, exec_ns, download_ns))| ExeStat {
                name,
                device: 0,
                execs,
                mean_exec_ms: if execs == 0 { 0.0 } else { exec_ns as f64 / execs as f64 / 1e6 },
                mean_download_ms: if execs == 0 {
                    0.0
                } else {
                    download_ns as f64 / execs as f64 / 1e6
                },
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Register a path override: `exe_on(name, ..)` will load `path` instead
    /// of `dir/<name>.hlo.txt`. The registry aliases every artifact of an
    /// installed network under its digest-qualified name
    /// (`<net>@<digest12>_<suffix>`), pointing into the content-addressed
    /// cache. Re-aliasing an existing name replaces the path (idempotent
    /// re-installs alias to the same path anyway).
    pub fn alias(&self, name: &str, path: PathBuf) {
        self.aliases.write().unwrap().insert(name.to_string(), path);
    }

    /// Drop every alias whose name starts with `prefix` AND purge the
    /// matching compiled executables from every device slot's cache —
    /// eviction of a retired registry version. In-flight holders of the
    /// `Arc<Exe>` keep running (the Arc keeps the executable alive); the
    /// engine just stops handing it out. Returns the number of aliases
    /// removed.
    pub fn unalias_prefix(&self, prefix: &str) -> usize {
        let mut aliases = self.aliases.write().unwrap();
        let before = aliases.len();
        aliases.retain(|name, _| !name.starts_with(prefix));
        let removed = before - aliases.len();
        drop(aliases);
        for slot in self.devices.read().unwrap().iter() {
            slot.cache.write().unwrap().retain(|name, _| !name.starts_with(prefix));
        }
        removed
    }

    /// Number of registered artifact aliases (registry-installed networks).
    pub fn alias_count(&self) -> usize {
        self.aliases.read().unwrap().len()
    }

    /// Number of compiled `(artifact, device)` entries currently cached
    /// across the pool.
    pub fn cached_exes(&self) -> usize {
        self.devices
            .read()
            .unwrap()
            .iter()
            .map(|s| s.cache.read().unwrap().len())
            .sum()
    }
}

impl Engine {
    /// Upload an f32 tensor to device 0 (persistent operand).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuf> {
        self.buffer_f32_on(data, dims, 0)
    }

    /// Upload an f32 scalar to device 0.
    pub fn buffer_scalar(&self, x: f32) -> Result<DeviceBuf> {
        self.buffer_f32(&[x], &[])
    }

    /// Upload an f32 tensor to pool device `dev` (per-device residency:
    /// callers replicate persistent operands on first use per device).
    pub fn buffer_f32_on(&self, data: &[f32], dims: &[usize], dev: usize) -> Result<DeviceBuf> {
        let slot = self.slot(dev)?;
        Ok(DeviceBuf(slot.client.buffer_from_host_buffer::<f32>(data, dims, None)?))
    }

    /// Upload an f32 scalar to pool device `dev`.
    pub fn buffer_scalar_on(&self, x: f32, dev: usize) -> Result<DeviceBuf> {
        self.buffer_f32_on(&[x], &[], dev)
    }
}

// ---- literal helpers ---------------------------------------------------------

/// Build an f32 literal of the given logical shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    if dims.len() == 1 {
        return Ok(Literal::vec1(data));
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Extract the f32 payload of a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_send_sync<T: Send + Sync>() {}

    /// Compile-time assertion: the runtime types cross shard threads.
    #[test]
    fn engine_types_are_send_sync() {
        assert_send_sync::<Engine>();
        assert_send_sync::<Exe>();
        assert_send_sync::<DeviceBuf>();
        assert_send_sync::<Arc<Engine>>();
        assert_send_sync::<Arc<Exe>>();
        assert_send_sync::<std::sync::Mutex<Stage>>();
    }

    #[test]
    fn stage_clears_but_keeps_capacity() {
        let mut s = Stage::new();
        s.start().extend_from_slice(&[1.0; 64]);
        let cap = {
            let b = s.start();
            assert!(b.is_empty(), "start() must clear the previous staging");
            b.capacity()
        };
        assert!(cap >= 64, "capacity must survive restaging");
    }

    /// The pin guard is purely thread-local bookkeeping (no PJRT needed):
    /// nesting restores the outer pin, dropping restores None.
    #[test]
    fn device_pin_nests_and_restores() {
        assert_eq!(thread_pin(), None);
        {
            let _outer = DevicePin { prev: DEVICE_PIN.with(|p| p.replace(Some(1))) };
            assert_eq!(thread_pin(), Some(1));
            {
                let _inner = DevicePin { prev: DEVICE_PIN.with(|p| p.replace(Some(0))) };
                assert_eq!(thread_pin(), Some(0));
            }
            assert_eq!(thread_pin(), Some(1), "inner guard must restore the outer pin");
        }
        assert_eq!(thread_pin(), None, "outer guard must restore unpinned");
    }
}
