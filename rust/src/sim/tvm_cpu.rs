//! Bit-serial CPU cost model (paper §4.4): stands in for TVM's bit-serial
//! vector kernels measured on an Intel i7-4790.
//!
//! Substitution note (DESIGN.md §7): TVM with autotuned bit-serial schedules
//! is not available offline, so we model the documented execution scheme of
//! TVM's popcount-based bit-serial GEMM (Cowan et al. / the TVM `bitserial`
//! topi operators): weights are decomposed into `bits_w` bit-planes and
//! activations into `bits_a` planes; each (wp, ap) plane pair costs one
//! AND+popcount+accumulate pass over the MACs, vectorized over AVX2 lanes.
//! Latency is therefore ~linear in `bits_w` (activations stay at 8 bits, as
//! in the paper which quantizes weights only), plus a bitwidth-independent
//! per-layer overhead (im2col/packing/loop bookkeeping) that makes the
//! speedup sub-linear — matching Fig 8's avg 2.2x (not 8/avg_bits).

use crate::runtime::NetworkMeta;

#[derive(Debug, Clone)]
pub struct TvmCpuConfig {
    /// activation bitwidth (paper: activations are not deep-quantized)
    pub bits_a: f64,
    /// bit-ops per cycle: AVX2 256-bit AND+popcount pipeline
    pub bitops_per_cycle: f64,
    /// clock (Hz) — i7-4790 nominal
    pub freq_hz: f64,
    /// per-layer packing/im2col overhead, as a fraction of the layer's
    /// 8-bit compute time
    pub pack_frac: f64,
    /// bytes/s of sustained memory bandwidth (weight streaming)
    pub mem_bw: f64,
    pub baseline_bits: u32,
}

impl Default for TvmCpuConfig {
    fn default() -> Self {
        TvmCpuConfig {
            bits_a: 8.0,
            bitops_per_cycle: 256.0,
            freq_hz: 3.6e9,
            pack_frac: 0.18,
            mem_bw: 20e9,
            baseline_bits: 8,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TvmLayerTime {
    pub name: String,
    pub bits: u32,
    pub seconds: f64,
}

pub struct TvmCpu {
    pub cfg: TvmCpuConfig,
}

impl TvmCpu {
    pub fn new(cfg: TvmCpuConfig) -> TvmCpu {
        TvmCpu { cfg }
    }

    /// Inference latency (seconds) for one example at the given bitwidths.
    pub fn latency(&self, net: &NetworkMeta, bits: &[u32]) -> (f64, Vec<TvmLayerTime>) {
        assert_eq!(bits.len(), net.layers.len());
        let c = &self.cfg;
        let mut layers = Vec::with_capacity(bits.len());
        let mut total = 0.0;
        for (lm, &b) in net.layers.iter().zip(bits) {
            let b = b as f64;
            // bit-plane passes: bits_w x bits_a, each a popcount pass over MACs
            let bitops = lm.n_macs as f64 * b * c.bits_a;
            let compute_s = bitops / (c.bitops_per_cycle * c.freq_hz);
            // weight streaming at b bits per weight
            let mem_s = lm.w_len as f64 * b / 8.0 / c.mem_bw;
            // packing overhead calibrated to the layer's own 8-bit compute
            let base_compute =
                lm.n_macs as f64 * c.baseline_bits as f64 * c.bits_a
                    / (c.bitops_per_cycle * c.freq_hz);
            let t = compute_s.max(mem_s) + c.pack_frac * base_compute;
            layers.push(TvmLayerTime { name: lm.name.clone(), bits: b as u32, seconds: t });
            total += t;
        }
        (total, layers)
    }

    /// Speedup of `bits` vs the uniform 8-bit baseline (Fig 8's metric).
    pub fn speedup(&self, net: &NetworkMeta, bits: &[u32]) -> f64 {
        let base = vec![self.cfg.baseline_bits; bits.len()];
        self.latency(net, &base).0 / self.latency(net, bits).0
    }
}

/// Geometric mean over per-network speedups (Fig 8 reports gmean).
pub fn gmean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::cost::tests_support::toy_net;

    fn net() -> crate::runtime::NetworkMeta {
        toy_net(&[(5_000, 2_000_000), (50_000, 8_000_000), (1_000, 200_000)])
    }

    #[test]
    fn baseline_speedup_is_one() {
        let t = TvmCpu::new(TvmCpuConfig::default());
        assert!((t.speedup(&net(), &[8, 8, 8]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sublinear_speedup() {
        let t = TvmCpu::new(TvmCpuConfig::default());
        let sp = t.speedup(&net(), &[2, 2, 2]);
        // ideal 4x, packing overhead keeps it well below
        assert!(sp > 1.5 && sp < 4.0, "speedup {sp}");
    }

    #[test]
    fn monotone_in_bits() {
        let t = TvmCpu::new(TvmCpuConfig::default());
        let mut last = 0.0;
        for b in (2..=8).rev() {
            let sp = t.speedup(&net(), &[b, b, b]);
            assert!(sp >= last, "bits {b}: {sp} < {last}");
            last = sp;
        }
    }

    #[test]
    fn gmean_basic() {
        assert!((gmean(&[1.0, 4.0]) - 2.0).abs() < 1e-9);
        assert!((gmean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-9);
    }
}
