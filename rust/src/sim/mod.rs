//! Hardware evaluation substrates (paper §4.4, §4.5): the Stripes bit-serial
//! accelerator simulator and the TVM-style bit-serial CPU cost model.

pub mod stripes;
pub mod tvm_cpu;

pub use stripes::{SimReport, Stripes, StripesConfig};
pub use tvm_cpu::{gmean, TvmCpu, TvmCpuConfig};
