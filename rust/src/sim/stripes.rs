//! Stripes bit-serial accelerator simulator (paper §4.5, [23]/[24]).
//!
//! Substitution note (DESIGN.md §7): the paper evaluates on the Stripes
//! cycle/energy model from Judd et al.; that RTL model is not available, so
//! this module implements the documented *mechanism*: compute is bit-serial,
//! so a layer's MACs take cycles proportional to the weight bitwidth, and
//! weight memory traffic shrinks linearly with the bitwidth.  Fig 9 and
//! Table 4 report *ratios* vs an 8-bit run of the same engine, which this
//! model reproduces by construction of the mechanism rather than by copying
//! the paper's numbers.
//!
//! The paper notes Stripes "does not support or benefit from deep
//! quantization of activations and it only leverages the quantization of
//! weights" — hence activation traffic/compute is bitwidth-independent here.

use crate::runtime::NetworkMeta;

#[derive(Debug, Clone)]
pub struct StripesConfig {
    /// parallel bit-serial MAC lanes (tiles x units x lanes)
    pub lanes: f64,
    /// clock (Hz) — only scales absolute numbers, never the ratios
    pub freq_hz: f64,
    /// energy per 1-bit MAC slice (pJ)
    pub e_mac_bit: f64,
    /// energy per weight byte from on-chip SRAM (pJ)
    pub e_sram_byte: f64,
    /// energy per weight byte from DRAM (pJ)
    pub e_dram_byte: f64,
    /// bitwidth-independent activation/control overhead as a fraction of the
    /// 8-bit runtime (pipeline fill, activation movement, off-chip latency)
    pub overhead_frac: f64,
    /// baseline bitwidth the paper compares against
    pub baseline_bits: u32,
}

impl Default for StripesConfig {
    fn default() -> Self {
        StripesConfig {
            lanes: 4096.0,
            freq_hz: 600e6,
            e_mac_bit: 0.04,
            e_sram_byte: 1.2,
            e_dram_byte: 80.0,
            overhead_frac: 0.04,
            baseline_bits: 8,
        }
    }
}

/// Per-layer simulation record.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub name: String,
    pub bits: u32,
    pub cycles: f64,
    pub energy_pj: f64,
}

#[derive(Debug, Clone)]
pub struct SimReport {
    pub layers: Vec<LayerSim>,
    pub total_cycles: f64,
    pub total_energy_pj: f64,
    pub runtime_s: f64,
}

pub struct Stripes {
    pub cfg: StripesConfig,
}

impl Stripes {
    pub fn new(cfg: StripesConfig) -> Stripes {
        Stripes { cfg }
    }

    /// Simulate one inference at the given per-layer weight bitwidths.
    pub fn simulate(&self, net: &NetworkMeta, bits: &[u32]) -> SimReport {
        assert_eq!(bits.len(), net.layers.len());
        let c = &self.cfg;
        let mut layers = Vec::with_capacity(bits.len());
        let mut total_cycles = 0.0;
        let mut total_energy = 0.0;
        for (lm, &b) in net.layers.iter().zip(bits) {
            let b = b as f64;
            // bit-serial compute: one bit-slice of every MAC per cycle pass
            let mac_cycles = (lm.n_macs as f64 / c.lanes).ceil() * b;
            // weight fetch: n_w * b bits streamed over a 64 B/cycle bus
            let w_bytes = lm.w_len as f64 * b / 8.0;
            let fetch_cycles = w_bytes / 64.0;
            // bitwidth-independent overhead, calibrated against the layer's
            // own 8-bit runtime
            let base_cycles = (lm.n_macs as f64 / c.lanes).ceil() * c.baseline_bits as f64;
            let overhead = c.overhead_frac * base_cycles;
            let cycles = mac_cycles.max(fetch_cycles) + overhead;

            let energy = lm.n_macs as f64 * b * c.e_mac_bit
                + w_bytes * (c.e_sram_byte + c.e_dram_byte)
                + c.overhead_frac * lm.n_macs as f64 * c.baseline_bits as f64 * c.e_mac_bit;
            layers.push(LayerSim { name: lm.name.clone(), bits: b as u32, cycles, energy_pj: energy });
            total_cycles += cycles;
            total_energy += energy;
        }
        SimReport {
            layers,
            total_cycles,
            total_energy_pj: total_energy,
            runtime_s: total_cycles / c.freq_hz,
        }
    }

    /// (speedup, energy-reduction) of `bits` vs the uniform 8-bit baseline —
    /// exactly what Fig 9 plots.
    pub fn speedup_energy(&self, net: &NetworkMeta, bits: &[u32]) -> (f64, f64) {
        let base = vec![self.cfg.baseline_bits; bits.len()];
        let b = self.simulate(net, &base);
        let q = self.simulate(net, bits);
        (b.total_cycles / q.total_cycles, b.total_energy_pj / q.total_energy_pj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::cost::tests_support::toy_net;

    fn net() -> crate::runtime::NetworkMeta {
        toy_net(&[(5_000, 2_000_000), (50_000, 8_000_000), (1_000, 200_000)])
    }

    #[test]
    fn baseline_is_identity() {
        let s = Stripes::new(StripesConfig::default());
        let (sp, en) = s.speedup_energy(&net(), &[8, 8, 8]);
        assert!((sp - 1.0).abs() < 1e-9);
        assert!((en - 1.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_roughly_bit_linear() {
        let s = Stripes::new(StripesConfig::default());
        let (sp, _) = s.speedup_energy(&net(), &[2, 2, 2]);
        // 8/2 = 4x ideal, minus constant overhead -> within (2.5, 4.0)
        assert!(sp > 2.5 && sp <= 4.0, "speedup {sp}");
    }

    #[test]
    fn monotone_in_bits() {
        // more bits -> strictly more cycles (bit-serial mechanism)
        let s = Stripes::new(StripesConfig::default());
        let mut last = 0.0;
        for b in 2..=8 {
            let r = s.simulate(&net(), &[b, b, b]);
            assert!(r.total_cycles > last, "bits {b}");
            last = r.total_cycles;
        }
    }

    #[test]
    fn heavier_layer_dominates() {
        let s = Stripes::new(StripesConfig::default());
        // quantizing only the heavy middle layer helps much more
        let (sp_mid, _) = s.speedup_energy(&net(), &[8, 2, 8]);
        let (sp_ends, _) = s.speedup_energy(&net(), &[2, 8, 2]);
        assert!(sp_mid > sp_ends, "{sp_mid} vs {sp_ends}");
    }

    #[test]
    fn energy_reduction_positive_for_deep_quant() {
        let s = Stripes::new(StripesConfig::default());
        let (_, en) = s.speedup_energy(&net(), &[3, 3, 3]);
        assert!(en > 1.5, "energy reduction {en}");
    }
}
