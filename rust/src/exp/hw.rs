//! Fig 8 (bit-serial CPU speedup over 8-bit, per network + gmean) and
//! Fig 9 (Stripes speedup + energy reduction over 8-bit).
//!
//! Uses the Table-2 solutions stored by `exp table2` when available, else the
//! paper's published bitwidths — so these figures can be regenerated without
//! re-running the search.

use anyhow::Result;

use crate::sim::{gmean, Stripes, StripesConfig, TvmCpu, TvmCpuConfig};

use super::table2::stored_solution;
use super::{Ctx, ALL_NETS};

/// Paper Fig 9 reference points (speedup) quoted in §5.4.
fn paper_fig9(net: &str) -> Option<f64> {
    match net {
        "mobilenet" => Some(1.2),
        "resnet20" => Some(3.0),
        "lenet" => Some(4.0),
        _ => None,
    }
}

pub fn fig8(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig 8: bit-serial CPU (TVM-style) speedup over 8-bit ===");
    let tvm = TvmCpu::new(TvmCpuConfig::default());
    let mut speedups = Vec::new();
    let mut csv = String::from("network,speedup\n");
    println!("{:<10} {:>9}  bits", "network", "speedup");
    for net in ctx.selected(&ALL_NETS) {
        let meta = ctx.manifest.network(&net)?;
        let Some(bits) = stored_solution(ctx, &net) else { continue };
        if bits.len() != meta.l {
            continue;
        }
        let sp = tvm.speedup(meta, &bits);
        println!("{:<10} {:>8.2}x  {:?}", net, sp, bits);
        csv.push_str(&format!("{net},{sp:.4}\n"));
        speedups.push(sp);
    }
    let g = gmean(&speedups);
    println!("{:<10} {:>8.2}x  (paper reports 2.2x average)", "gmean", g);
    csv.push_str(&format!("gmean,{g:.4}\n"));
    std::fs::write(ctx.out.join("fig8.csv"), csv)?;
    println!("-> {}", ctx.out.join("fig8.csv").display());
    Ok(())
}

pub fn fig9(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig 9: Stripes accelerator speedup + energy reduction over 8-bit ===");
    let stripes = Stripes::new(StripesConfig::default());
    let mut sps = Vec::new();
    let mut ens = Vec::new();
    let mut csv = String::from("network,speedup,energy_reduction,paper_speedup\n");
    println!("{:<10} {:>9} {:>9} {:>13}", "network", "speedup", "energy", "paper speedup");
    for net in ctx.selected(&ALL_NETS) {
        let meta = ctx.manifest.network(&net)?;
        let Some(bits) = stored_solution(ctx, &net) else { continue };
        if bits.len() != meta.l {
            continue;
        }
        let (sp, en) = stripes.speedup_energy(meta, &bits);
        let pref = paper_fig9(&net)
            .map(|p| format!("{p:.1}x"))
            .unwrap_or_else(|| "-".into());
        println!("{:<10} {:>8.2}x {:>8.2}x {:>13}", net, sp, en, pref);
        csv.push_str(&format!(
            "{net},{sp:.4},{en:.4},{}\n",
            paper_fig9(&net).map(|p| p.to_string()).unwrap_or_default()
        ));
        sps.push(sp);
        ens.push(en);
    }
    println!(
        "{:<10} {:>8.2}x {:>8.2}x  (paper reports 2.0x speedup, 2.7x energy)",
        "gmean",
        gmean(&sps),
        gmean(&ens)
    );
    csv.push_str(&format!("gmean,{:.4},{:.4},\n", gmean(&sps), gmean(&ens)));
    std::fs::write(ctx.out.join("fig9.csv"), csv)?;
    println!("-> {}", ctx.out.join("fig9.csv").display());
    Ok(())
}
