//! Fig 5 (action-probability evolution), Fig 6 (Pareto fronts), Fig 7
//! (learning curves), Fig 10 (reward-formulation ablation).

use anyhow::Result;

use crate::coordinator::{EnvConfig, QuantEnv, RewardKind};
use crate::metrics::{sparkline, SearchLog};
use crate::pareto;

use super::Ctx;

/// Fig 5: evolution of the per-layer bitwidth-selection probabilities over
/// training episodes for LeNet.
pub fn fig5(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig 5: action-probability evolution (LeNet) ===");
    let r = ctx.search("lenet")?;
    let n_layers = r.bits.len();
    let n_actions = r.final_probs[0].len();
    // per layer: probability of the finally-chosen bitwidth across episodes
    for l in 0..n_layers {
        let series: Vec<f64> = r
            .log
            .episodes
            .iter()
            .map(|e| e.probs[l][(r.bits[l] - 1) as usize] as f64)
            .collect();
        println!(
            "layer {l}: P(bits={}) over episodes: {}  (final {:.2})",
            r.bits[l],
            sparkline(&series, 50),
            series.last().copied().unwrap_or(0.0)
        );
    }
    // full probability matrix -> CSV (episode x (layer, action))
    let mut csv = String::from("episode");
    for l in 0..n_layers {
        for a in 0..n_actions {
            csv.push_str(&format!(",l{l}_b{}", a + 1));
        }
    }
    csv.push('\n');
    for e in &r.log.episodes {
        csv.push_str(&e.episode.to_string());
        for l in 0..n_layers {
            for a in 0..n_actions {
                csv.push_str(&format!(",{:.4}", e.probs[l][a]));
            }
        }
        csv.push('\n');
    }
    std::fs::write(ctx.out.join("fig5.csv"), csv)?;
    println!("final policy bits: {:?} -> {}", r.bits, ctx.out.join("fig5.csv").display());
    Ok(())
}

/// Fig 6: quantization space + Pareto frontier for the four moderate nets.
pub fn fig6(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig 6: quantization space + Pareto frontier ===");
    for net in ctx.selected(&["simplenet", "lenet", "svhn10", "vgg11"]) {
        let meta = ctx.manifest.network(&net)?;
        let mut env_cfg = EnvConfig::default();
        env_cfg.pretrain_steps = crate::config::preset(&net).env.pretrain_steps;
        env_cfg.seed = ctx.seed;
        // one shared-core env: every shard queries the same pretrained
        // snapshot, and its warm memo serves the stored-solution probe below
        // without re-running retrains
        let env = QuantEnv::new(
            ctx.engine.clone(),
            meta,
            ctx.manifest.bits_max,
            ctx.manifest.fp_bits,
            env_cfg,
        )?;
        let mut ecfg = pareto::EnumConfig::default();
        // keep the evaluation budget proportional to the ctx scale
        ecfg.max_points = ((1200.0 * ctx.episodes_scale) as usize).max(150);
        ecfg.seed = ctx.seed;
        let shards = crate::parallel::default_shards(ecfg.max_points);
        let (points, exhaustive) = pareto::enumerate_sharded(&env, &ecfg, shards)?;
        let frontier = pareto::pareto_frontier(&points);
        // where does the (stored) ReLeQ solution sit relative to the frontier?
        let releq = super::table2::stored_solution(ctx, &net);
        let mut csv = String::from("state_q,state_acc,on_frontier,is_releq,bits\n");
        for (i, p) in points.iter().enumerate() {
            csv.push_str(&format!(
                "{:.6},{:.6},{},{},{}\n",
                p.state_q,
                p.state_acc,
                frontier.contains(&i) as u8,
                (releq.as_ref() == Some(&p.bits)) as u8,
                p.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(" ")
            ));
        }
        std::fs::write(ctx.out.join(format!("fig6_{net}.csv")), csv)?;
        let f_accs: Vec<f64> = frontier.iter().map(|&i| points[i].state_acc).collect();
        println!(
            "{net}: {} points ({}), frontier {} points, acc range {:.2}..{:.2} -> fig6_{net}.csv",
            points.len(),
            if exhaustive { "exhaustive" } else { "sampled" },
            frontier.len(),
            f_accs.first().copied().unwrap_or(0.0),
            f_accs.last().copied().unwrap_or(0.0),
        );
        if let Some(rb) = &releq {
            if rb.len() == meta.l {
                let sq = env.state_q(rb);
                let sa = env.state_acc(rb)?;
                // distance to the frontier in state_q at comparable accuracy
                let frontier_q_at_acc = frontier
                    .iter()
                    .map(|&i| &points[i])
                    .filter(|p| p.state_acc >= sa - 0.02)
                    .map(|p| p.state_q)
                    .fold(f64::INFINITY, f64::min);
                println!(
                    "  ReLeQ point: state_q {sq:.3}, state_acc {sa:.3} \
                     (best frontier state_q at >= this accuracy: {frontier_q_at_acc:.3})"
                );
            }
        }
    }
    Ok(())
}

/// Fig 7: evolution of State-of-Relative-Accuracy / State-of-Quantization /
/// reward as the agent learns.
pub fn fig7(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig 7: learning-curve evolution ===");
    for net in ctx.selected(&["simplenet", "svhn10", "mobilenet"]) {
        let r = ctx.search(&net)?;
        let ma = |s: &[f64]| SearchLog::moving_average(s, 20);
        println!("{net} ({} episodes):", r.episodes_run);
        println!("  state_acc: {}", sparkline(&ma(&r.log.state_accs()), 60));
        println!("  state_q  : {}", sparkline(&ma(&r.log.state_qs()), 60));
        println!("  reward   : {}", sparkline(&ma(&r.log.rewards()), 60));
        r.log.write_csv(&ctx.out.join(format!("fig7_{net}.csv")))?;
        // the paper's claim: the moving averages trend up (acc, reward) and
        // down (state_q) from the first quarter to the last quarter
        let quarter = |s: &[f64], last: bool| {
            let n = s.len().max(4);
            let q = n / 4;
            let slice = if last { &s[n - q..] } else { &s[..q] };
            slice.iter().sum::<f64>() / slice.len() as f64
        };
        let acc = r.log.state_accs();
        let qs = r.log.state_qs();
        let rw = r.log.rewards();
        println!(
            "  trend: acc {:.3}->{:.3}, state_q {:.3}->{:.3}, reward {:.3}->{:.3}",
            quarter(&acc, false),
            quarter(&acc, true),
            quarter(&qs, false),
            quarter(&qs, true),
            quarter(&rw, false),
            quarter(&rw, true)
        );
    }
    Ok(())
}

/// Fig 10: three reward formulations vs State-of-Relative-Accuracy evolution.
pub fn fig10(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig 10: reward-formulation ablation ===");
    for net in ctx.selected(&["simplenet", "lenet", "svhn10"]) {
        println!("{net}:");
        let mut csv = String::from("episode,proposed,ratio,diff\n");
        let mut series = Vec::new();
        for kind in [RewardKind::Proposed, RewardKind::Ratio, RewardKind::Diff] {
            let mut cfg = ctx.search_cfg(&net);
            cfg.reward.kind = kind;
            cfg.patience = 0; // run all episodes so the curves are comparable
            let r = ctx.search_with(&net, cfg)?;
            let ma = SearchLog::moving_average(&r.log.state_accs(), 20);
            println!("  {kind:?}: {}  (final MA {:.3})", sparkline(&ma, 56),
                     ma.last().copied().unwrap_or(0.0));
            series.push(ma);
        }
        let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
        for i in 0..n {
            csv.push_str(&format!(
                "{i},{:.4},{:.4},{:.4}\n",
                series[0][i], series[1][i], series[2][i]
            ));
        }
        std::fs::write(ctx.out.join(format!("fig10_{net}.csv")), csv)?;
    }
    println!("(paper: the proposed shaping keeps State_Accuracy consistently higher)");
    Ok(())
}
