//! Table 4 (ReLeQ vs ADMM on TVM-CPU and Stripes) and Table 5 (PPO clipping
//! parameter sensitivity).

use anyhow::Result;

use crate::baselines::{paper_solution, AdmmConfig, AdmmSelector};
use crate::coordinator::{EnvConfig, QuantEnv};
use crate::sim::{Stripes, StripesConfig, TvmCpu, TvmCpuConfig};

use super::table2::stored_solution;
use super::Ctx;

pub fn table4(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 4: ReLeQ vs ADMM (speedup / energy on simulators) ===");
    println!(
        "{:<9} {:<22} {:<22} {:>9} {:>11} {:>11}",
        "network", "releq bits", "admm bits", "tvm", "stripes", "energy"
    );
    let stripes = Stripes::new(StripesConfig::default());
    let tvm = TvmCpu::new(TvmCpuConfig::default());
    let mut csv =
        String::from("network,releq_bits,admm_bits,tvm_speedup,stripes_speedup,energy_improvement,paper_tvm,paper_stripes,paper_energy\n");
    // the paper's ADMM comparison exists for AlexNet and LeNet only
    for (net, paper_tvm, paper_str, paper_en) in
        [("alexnet", 1.20, 1.22, 1.25), ("lenet", 1.42, 1.86, 1.87)]
    {
        if !ctx.selected(&[net]).contains(&net.to_string()) {
            continue;
        }
        let meta = ctx.manifest.network(net)?;
        let releq_bits = stored_solution(ctx, net).unwrap();
        // prefer the paper's published ADMM vector; our own selector is used
        // when it is missing (and validated against it in tests)
        let admm_bits = match paper_solution(net) {
            Some(b) => b,
            None => {
                let mut env_cfg = EnvConfig::default();
                env_cfg.pretrain_steps = crate::config::preset(net).env.pretrain_steps;
                let env = QuantEnv::new(
                    ctx.engine.clone(),
                    meta,
                    ctx.manifest.bits_max,
                    ctx.manifest.fp_bits,
                    env_cfg,
                )?;
                AdmmSelector::new(AdmmConfig::default()).select(meta, &env.pretrained, 5.0)
            }
        };
        let (sp_r, en_r) = stripes.speedup_energy(meta, &releq_bits);
        let (sp_a, en_a) = stripes.speedup_energy(meta, &admm_bits);
        let tvm_ratio = tvm.speedup(meta, &releq_bits) / tvm.speedup(meta, &admm_bits);
        let stripes_ratio = sp_r / sp_a;
        let energy_ratio = en_r / en_a;
        println!(
            "{:<9} {:<22} {:<22} {:>8.2}x {:>10.2}x {:>10.2}x",
            net,
            format!("{releq_bits:?}"),
            format!("{admm_bits:?}"),
            tvm_ratio,
            stripes_ratio,
            energy_ratio
        );
        println!(
            "{:<9} {:<22} {:<22} {:>8.2}x {:>10.2}x {:>10.2}x   (paper)",
            "", "", "", paper_tvm, paper_str, paper_en
        );
        csv.push_str(&format!(
            "{net},{},{},{tvm_ratio:.4},{stripes_ratio:.4},{energy_ratio:.4},{paper_tvm},{paper_str},{paper_en}\n",
            releq_bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(" "),
            admm_bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(" "),
        ));
    }
    std::fs::write(ctx.out.join("table4.csv"), csv)?;
    println!("-> {}", ctx.out.join("table4.csv").display());
    Ok(())
}

pub fn table5(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 5: PPO clipping-parameter sensitivity (avg normalized reward) ===");
    let nets = ctx.selected(&["lenet", "simplenet", "svhn10"]);
    let epsilons = [0.1f32, 0.2, 0.3];
    let mut rows: Vec<(f32, Vec<f64>)> = Vec::new();
    for &eps in &epsilons {
        let mut vals = Vec::new();
        for net in &nets {
            let mut cfg = ctx.search_cfg(net);
            cfg.ppo.clip_eps = eps;
            // Table 5 measures reward during learning, not the final solution:
            // average the per-episode reward over the whole run, normalized by
            // episode length.
            let r = ctx.search_with(net, cfg)?;
            let meta = ctx.manifest.network(net)?;
            let avg_norm_reward = r.log.rewards().iter().sum::<f64>()
                / (r.log.episodes.len().max(1) as f64)
                / meta.l as f64;
            vals.push(avg_norm_reward);
        }
        rows.push((eps, vals));
    }
    print!("{:<8}", "eps");
    for net in &nets {
        print!(" {net:>10}");
    }
    println!();
    let mut csv = format!("eps,{}\n", nets.join(","));
    for (eps, vals) in &rows {
        print!("{eps:<8}");
        let mut line = format!("{eps}");
        for v in vals {
            print!(" {v:>10.3}");
            line.push_str(&format!(",{v:.4}"));
        }
        println!();
        csv.push_str(&line);
        csv.push('\n');
    }
    println!("(paper: eps=0.1 gives the highest average reward on all three)");
    std::fs::write(ctx.out.join("table5.csv"), csv)?;
    println!("-> {}", ctx.out.join("table5.csv").display());
    Ok(())
}
