//! Experiment harness: one module per paper table/figure (DESIGN.md §5).
//!
//! Every experiment prints the same rows/series the paper reports and writes
//! machine-readable results under `results/`. Shape-level agreement (who
//! wins, by roughly what factor) is the reproduction target — the substrate
//! is a simulator, not the authors' testbed (DESIGN.md §6).

pub mod ablations;
pub mod figs;
pub mod hw;
pub mod table2;
pub mod table45;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config;
use crate::coordinator::{SearchConfig, SearchResult, Searcher};
use crate::runtime::{Engine, Manifest};
use crate::util::cli::Args;

/// Shared experiment context.
pub struct Ctx {
    pub manifest: Manifest,
    pub engine: Arc<Engine>,
    pub out: PathBuf,
    /// scale factor on episode counts (`--fast` = 0.25, `--episodes-scale X`)
    pub episodes_scale: f64,
    /// network filter (`--nets a,b,c`)
    pub nets: Option<Vec<String>>,
    pub seed: u64,
}

impl Ctx {
    pub fn new(args: &Args) -> Result<Ctx> {
        let (manifest, engine) = crate::launcher::bringup()?;
        let out = PathBuf::from(args.str_of("out", "results"));
        std::fs::create_dir_all(&out)?;
        let mut episodes_scale = args.f64_of("episodes-scale", 1.0);
        if args.has("fast") {
            episodes_scale *= 0.25;
        }
        let nets = args
            .opt_str("nets")
            .map(|s| s.split(',').map(|t| t.trim().to_string()).collect());
        Ok(Ctx { manifest, engine, out, episodes_scale, nets, seed: args.u64_of("seed", 23) })
    }

    pub fn selected(&self, all: &[&str]) -> Vec<String> {
        match &self.nets {
            Some(list) => all
                .iter()
                .filter(|n| list.iter().any(|x| x == *n))
                .map(|s| s.to_string())
                .collect(),
            None => all.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Preset config for a network with the ctx's scaling + seed applied.
    pub fn search_cfg(&self, net: &str) -> SearchConfig {
        let mut cfg = config::preset(net);
        cfg.episodes = ((cfg.episodes as f64 * self.episodes_scale).round() as usize).max(16);
        cfg.seed = self.seed;
        cfg
    }

    /// Run one search with an explicit config.
    pub fn search_with(&self, net: &str, cfg: SearchConfig) -> Result<SearchResult> {
        let meta = self.manifest.network(net)?;
        let mut searcher = Searcher::new(self.engine.clone(), &self.manifest, meta, cfg)?;
        searcher.run()
    }

    /// Run one search with the preset config.
    pub fn search(&self, net: &str) -> Result<SearchResult> {
        self.search_with(net, self.search_cfg(net))
    }
}

/// Dispatch `releq exp <id>`.
pub fn run(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let ctx = Ctx::new(args)?;
    let t0 = std::time::Instant::now();
    match which {
        "table2" => table2::run(&ctx)?,
        "table4" => table45::table4(&ctx)?,
        "table5" => table45::table5(&ctx)?,
        "fig5" => figs::fig5(&ctx)?,
        "fig6" => figs::fig6(&ctx)?,
        "fig7" => figs::fig7(&ctx)?,
        "fig8" => hw::fig8(&ctx)?,
        "fig9" => hw::fig9(&ctx)?,
        "fig10" => figs::fig10(&ctx)?,
        "ablation-action" => ablations::action_space(&ctx)?,
        "ablation-lstm" => ablations::lstm_vs_fc(&ctx)?,
        "all" => {
            table2::run(&ctx)?;
            table45::table4(&ctx)?;
            table45::table5(&ctx)?;
            figs::fig5(&ctx)?;
            figs::fig6(&ctx)?;
            figs::fig7(&ctx)?;
            hw::fig8(&ctx)?;
            hw::fig9(&ctx)?;
            figs::fig10(&ctx)?;
            ablations::action_space(&ctx)?;
            ablations::lstm_vs_fc(&ctx)?;
        }
        other => anyhow::bail!(
            "unknown experiment `{other}` \
             (table2|table4|table5|fig5|fig6|fig7|fig8|fig9|fig10|ablation-action|ablation-lstm|all)"
        ),
    }
    eprintln!("[exp {which}] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// All seven benchmark networks, in Table 2 order.
pub const ALL_NETS: [&str; 7] =
    ["alexnet", "simplenet", "lenet", "mobilenet", "resnet20", "svhn10", "vgg11"];
