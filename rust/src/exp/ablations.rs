//! Design-decision ablations the paper reports in prose:
//! §2.5 — flexible vs restricted action space (Fig 2): flexible converges faster.
//! §2.7 — LSTM vs FC-only agent: LSTM converges ~1.33x faster.
//!
//! Convergence here = episodes until the moving-average reward first reaches
//! 95% of its final plateau (and stays there), a standard convergence proxy.

use anyhow::Result;

use crate::coordinator::{ActionSpace, AgentKind};
use crate::metrics::SearchLog;

use super::Ctx;

/// Episodes until the 20-episode moving average of reward reaches 95% of the
/// mean of its final quarter.
pub fn convergence_episode(rewards: &[f64]) -> usize {
    if rewards.is_empty() {
        return 0;
    }
    let ma = SearchLog::moving_average(rewards, 20);
    let n = ma.len();
    let tail = &ma[n - (n / 4).max(1)..];
    let plateau = tail.iter().sum::<f64>() / tail.len() as f64;
    let lo = ma.iter().cloned().fold(f64::INFINITY, f64::min);
    let threshold = lo + 0.95 * (plateau - lo);
    ma.iter().position(|&x| x >= threshold).unwrap_or(n - 1)
}

pub fn action_space(ctx: &Ctx) -> Result<()> {
    println!("\n=== Ablation (paper §2.5): flexible vs restricted action space, LeNet ===");
    let mut rows = Vec::new();
    for space in [ActionSpace::Flexible, ActionSpace::Restricted] {
        let mut cfg = ctx.search_cfg("lenet");
        cfg.action_space = space;
        cfg.patience = 0;
        let r = ctx.search_with("lenet", cfg)?;
        let conv = convergence_episode(&r.log.rewards());
        let final_reward = {
            let rw = r.log.rewards();
            let n = rw.len();
            rw[n - (n / 4).max(1)..].iter().sum::<f64>() / (n / 4).max(1) as f64
        };
        println!(
            "{space:?}: converged at episode ~{conv}, final reward {final_reward:.3}, bits {:?}",
            r.bits
        );
        rows.push((format!("{space:?}"), conv, final_reward));
    }
    let mut csv = String::from("action_space,convergence_episode,final_reward\n");
    for (s, c, f) in &rows {
        csv.push_str(&format!("{s},{c},{f:.4}\n"));
    }
    std::fs::write(ctx.out.join("ablation_action.csv"), csv)?;
    println!(
        "(paper: restricted movement converges much slower; flexible is used in ReLeQ)"
    );
    Ok(())
}

pub fn lstm_vs_fc(ctx: &Ctx) -> Result<()> {
    println!("\n=== Ablation (paper §2.7): LSTM vs FC-only agent, LeNet ===");
    let mut convs = Vec::new();
    for kind in [AgentKind::Lstm, AgentKind::Fc] {
        let mut cfg = ctx.search_cfg("lenet");
        cfg.agent_kind = kind;
        cfg.patience = 0;
        let r = ctx.search_with("lenet", cfg)?;
        let conv = convergence_episode(&r.log.rewards()).max(1);
        println!("{kind:?}: converged at episode ~{conv}, bits {:?}", r.bits);
        convs.push(conv);
    }
    let ratio = convs[1] as f64 / convs[0] as f64;
    println!(
        "FC/LSTM convergence ratio: {ratio:.2} (paper: LSTM converges ~1.33x faster)"
    );
    std::fs::write(
        ctx.out.join("ablation_lstm.csv"),
        format!("agent,convergence_episode\nlstm,{}\nfc,{}\nratio,{ratio:.3}\n", convs[0], convs[1]),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convergence_detects_rise_point() {
        // flat low, then jump to plateau at index 100
        let mut r = vec![0.0; 100];
        r.extend(vec![1.0; 100]);
        let c = convergence_episode(&r);
        assert!((100..=125).contains(&c), "c = {c}");
    }

    #[test]
    fn convergence_zero_for_flat() {
        let r = vec![0.5; 50];
        assert_eq!(convergence_episode(&r), 0);
    }
}
