//! Table 2: per-network quantization bitwidths found by ReLeQ, average
//! bitwidth, and accuracy loss after the final long retrain.

use std::io::Write;

use anyhow::Result;

use super::{Ctx, ALL_NETS};

/// Paper's Table 2 rows for side-by-side comparison (avg bitwidth, acc loss %).
fn paper_row(net: &str) -> (f64, f64) {
    match net {
        "alexnet" => (5.0, 0.08),
        "simplenet" => (5.0, 0.30),
        "lenet" => (2.25, 0.00),
        "mobilenet" => (6.43, 0.26),
        "resnet20" => (2.81, 0.12),
        "svhn10" => (4.80, 0.00),
        "vgg11" => (6.44, 0.17),
        _ => (f64::NAN, f64::NAN),
    }
}

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 2: ReLeQ deep-quantization solutions ===");
    println!(
        "{:<10} {:>8} {:>12} {:>12} {:>10} {:>12}  bitwidths",
        "network", "episodes", "avg bits", "paper avg", "acc loss%", "paper loss%"
    );
    let mut csv = String::from("network,episodes,avg_bits,paper_avg_bits,acc_loss_pct,paper_loss_pct,bits\n");
    for net in ctx.selected(&ALL_NETS) {
        let r = ctx.search(&net)?;
        let (pavg, ploss) = paper_row(&net);
        println!(
            "{:<10} {:>8} {:>12.2} {:>12.2} {:>10.2} {:>12.2}  {:?}",
            net, r.episodes_run, r.avg_bits, pavg, r.acc_loss_pct, ploss, r.bits
        );
        csv.push_str(&format!(
            "{},{},{:.3},{:.3},{:.3},{:.3},{}\n",
            net,
            r.episodes_run,
            r.avg_bits,
            pavg,
            r.acc_loss_pct,
            ploss,
            r.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(" ")
        ));
        // persist the solution for the hardware experiments (fig8/fig9/table4)
        let sol = ctx.out.join(format!("solution_{net}.txt"));
        let mut f = std::fs::File::create(sol)?;
        writeln!(
            f,
            "{}",
            r.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
        )?;
        r.log.write_csv(&ctx.out.join(format!("search_{net}.csv")))?;
        r.log.write_json(&ctx.out.join(format!("search_{net}.json")))?;
    }
    std::fs::write(ctx.out.join("table2.csv"), csv)?;
    println!("-> {}", ctx.out.join("table2.csv").display());
    Ok(())
}

/// Load a previously saved Table-2 solution, falling back to the paper's.
pub fn stored_solution(ctx: &Ctx, net: &str) -> Option<Vec<u32>> {
    let path = ctx.out.join(format!("solution_{net}.txt"));
    if let Ok(text) = std::fs::read_to_string(&path) {
        let bits: Vec<u32> = text
            .trim()
            .split(',')
            .filter_map(|t| t.parse().ok())
            .collect();
        if !bits.is_empty() {
            return Some(bits);
        }
    }
    crate::baselines::paper_releq_solution(net)
}
