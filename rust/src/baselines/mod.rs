//! Baseline bitwidth-selection methods the paper compares against (§4.6):
//! the ADMM-style selector of Ye et al. [46] and homogeneous baselines.

pub mod admm;
pub mod uniform;

pub use admm::{paper_releq_solution, paper_solution, AdmmConfig, AdmmSelector};
