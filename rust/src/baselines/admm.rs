//! ADMM-style bitwidth selection baseline (paper §4.6, comparing against
//! Ye et al. [46] "A unified framework of DNN weight pruning and weight
//! clustering/quantization using ADMM").
//!
//! [46] decides per-layer bitwidths by a binary search that minimizes the
//! total square quantization error, then fine-tunes iteratively. We implement
//! that selection faithfully on our substrate:
//!
//! * per layer l and bitwidth k: `err_l(k) = Σ (Q_k(w) - w)²` over the
//!   pretrained weights (WRPN mid-tread quantizer, same as the training path);
//! * a Lagrangian knob λ trades error against cost: each layer picks
//!   `argmin_k err_l(k) + λ · cost_l · k` where `cost_l` is the same
//!   memory+compute cost weight used by State_Q;
//! * binary search on λ hits a target average bitwidth (the paper's ADMM
//!   solutions average 5.25 bits on AlexNet, 3.25 on LeNet).
//!
//! The published ADMM bitwidth vectors for AlexNet/LeNet are also provided
//! verbatim so Table 4 can be regenerated against the paper's own numbers.

use crate::quant::sq_error;
use crate::runtime::NetworkMeta;

/// Published ADMM solutions from the paper (Table 4).
pub fn paper_solution(net: &str) -> Option<Vec<u32>> {
    match net {
        "alexnet" => Some(vec![8, 5, 5, 5, 5, 3, 3, 8]),
        "lenet" => Some(vec![5, 3, 2, 3]),
        _ => None,
    }
}

/// Published ReLeQ solutions from the paper (Table 2/4), for comparison runs.
/// resnet20/mobilenet are adapted to this repo's layer counts (20/28 vs the
/// paper's 23/30 rows — see models.py docstring): the leading/trailing 8-bit
/// layers and the low-bit interior pattern are preserved.
pub fn paper_releq_solution(net: &str) -> Option<Vec<u32>> {
    match net {
        "alexnet" => Some(vec![8, 4, 4, 4, 4, 4, 4, 8]),
        "lenet" => Some(vec![2, 2, 3, 2]),
        "simplenet" => Some(vec![5, 5, 5, 5, 5]),
        "mobilenet" => Some(vec![
            8, 5, 6, 6, 4, 4, 7, 8, 4, 6, 8, 5, 5, 8, 6, 7, 7, 7, 6, 8, 6, 8, 8, 6, 7, 5, 5, 7,
        ]),
        "resnet20" => Some(vec![8, 2, 2, 3, 2, 2, 2, 3, 2, 3, 3, 3, 2, 2, 2, 2, 3, 2, 2, 8]),
        "svhn10" => Some(vec![8, 4, 4, 4, 4, 4, 4, 4, 4, 8]),
        "vgg11" => Some(vec![8, 5, 8, 5, 6, 6, 6, 6, 8]),
        _ => None,
    }
}

#[derive(Debug, Clone)]
pub struct AdmmConfig {
    pub min_bits: u32,
    pub max_bits: u32,
    /// λ binary-search iterations
    pub iters: usize,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig { min_bits: 2, max_bits: 8, iters: 40 }
    }
}

pub struct AdmmSelector {
    pub cfg: AdmmConfig,
}

impl AdmmSelector {
    pub fn new(cfg: AdmmConfig) -> AdmmSelector {
        AdmmSelector { cfg }
    }

    /// Per-layer square quantization error at each candidate bitwidth.
    fn error_table(&self, net: &NetworkMeta, weights: &[f32]) -> Vec<Vec<f64>> {
        net.layers
            .iter()
            .map(|lm| {
                let w = &weights[lm.w_offset..lm.w_offset + lm.w_len];
                (self.cfg.min_bits..=self.cfg.max_bits)
                    .map(|k| sq_error(w, k as f32))
                    .collect()
            })
            .collect()
    }

    /// Bitwidths minimizing Σ err_l(k_l) + λ Σ cost_l·k_l for a fixed λ.
    fn select_lambda(&self, errs: &[Vec<f64>], costs: &[f64], lambda: f64) -> Vec<u32> {
        errs.iter()
            .zip(costs)
            .map(|(e, &c)| {
                let mut best = (f64::INFINITY, self.cfg.max_bits);
                for (i, &err) in e.iter().enumerate() {
                    let k = self.cfg.min_bits + i as u32;
                    let obj = err + lambda * c * k as f64;
                    if obj < best.0 {
                        best = (obj, k);
                    }
                }
                best.1
            })
            .collect()
    }

    /// Binary-search λ to meet `target_avg_bits` (plain mean over layers).
    pub fn select(&self, net: &NetworkMeta, weights: &[f32], target_avg_bits: f64)
                  -> Vec<u32> {
        let errs = self.error_table(net, weights);
        // normalize layer cost so λ has a stable scale across networks
        let total: f64 = net
            .layers
            .iter()
            .map(|l| l.w_len as f64 * crate::quant::E_MEM_OVER_E_MAC + l.n_macs as f64)
            .sum();
        let costs: Vec<f64> = net
            .layers
            .iter()
            .map(|l| (l.w_len as f64 * crate::quant::E_MEM_OVER_E_MAC + l.n_macs as f64) / total)
            .collect();
        let avg = |bits: &[u32]| {
            bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64
        };
        // λ = 0 -> max bits everywhere; large λ -> min bits everywhere
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        // grow hi until it forces below-target average
        for _ in 0..60 {
            if avg(&self.select_lambda(&errs, &costs, hi)) <= target_avg_bits {
                break;
            }
            hi *= 4.0;
        }
        let mut best = self.select_lambda(&errs, &costs, hi);
        for _ in 0..self.cfg.iters {
            let mid = 0.5 * (lo + hi);
            let bits = self.select_lambda(&errs, &costs, mid);
            if avg(&bits) <= target_avg_bits {
                best = bits;
                hi = mid;
            } else {
                lo = mid;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::cost::tests_support::toy_net;
    use crate::util::rng::Pcg32;

    fn weights(n: usize, std: f32, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::new(seed);
        (0..n).map(|_| r.gaussian() * std).collect()
    }

    #[test]
    fn paper_vectors_present() {
        assert_eq!(paper_solution("lenet").unwrap(), vec![5, 3, 2, 3]);
        assert_eq!(paper_solution("alexnet").unwrap().len(), 8);
        assert!(paper_solution("vgg11").is_none());
        assert_eq!(paper_releq_solution("lenet").unwrap(), vec![2, 2, 3, 2]);
        assert_eq!(paper_releq_solution("mobilenet").unwrap().len(), 28);
        assert_eq!(paper_releq_solution("resnet20").unwrap().len(), 20);
    }

    #[test]
    fn meets_target_average() {
        let net = toy_net(&[(2000, 100_000), (4000, 400_000), (500, 20_000)]);
        let mut w = weights(2000, 0.3, 1);
        w.extend(weights(4000, 0.2, 2));
        w.extend(weights(500, 0.5, 3));
        // toy_net has w_offset 0 everywhere; patch offsets
        let mut net = net;
        net.layers[0].w_offset = 0;
        net.layers[1].w_offset = 2000;
        net.layers[2].w_offset = 6000;
        let sel = AdmmSelector::new(AdmmConfig::default());
        let bits = sel.select(&net, &w, 4.0);
        let avg: f64 = bits.iter().map(|&b| b as f64).sum::<f64>() / 3.0;
        assert!(avg <= 4.0 + 1e-9, "avg {avg} bits {bits:?}");
        assert!(bits.iter().all(|&b| (2..=8).contains(&b)));
    }

    #[test]
    fn wide_distribution_gets_more_bits() {
        // a layer with wider weight distribution quantizes worse -> ADMM
        // should give it more bits than an equally-sized narrow layer
        let net = toy_net(&[(4000, 100_000), (4000, 100_000)]);
        let mut net = net;
        net.layers[0].w_offset = 0;
        net.layers[1].w_offset = 4000;
        let mut w = weights(4000, 0.9, 1); // wide
        w.extend(weights(4000, 0.05, 2)); // narrow
        let sel = AdmmSelector::new(AdmmConfig::default());
        let bits = sel.select(&net, &w, 5.0);
        assert!(bits[0] >= bits[1], "{bits:?}");
    }

    #[test]
    fn lambda_zero_gives_max_bits() {
        let net = toy_net(&[(100, 1000)]);
        let w = weights(100, 0.3, 9);
        let sel = AdmmSelector::new(AdmmConfig::default());
        let errs = sel.error_table(&net, &w);
        let bits = sel.select_lambda(&errs, &[1.0], 0.0);
        assert_eq!(bits, vec![8]);
    }
}
