//! Homogeneous-bitwidth baselines.
//!
//! The paper's §2.1 argues heterogeneity beats any uniform assignment; these
//! helpers provide the uniform comparators: the fixed 8-bit baseline all
//! hardware numbers normalize against, and a "smallest uniform bitwidth that
//! stays within an accuracy budget" search (the strongest homogeneous rival,
//! used by the Pareto and ablation experiments).

use anyhow::Result;

use crate::coordinator::QuantEnv;

/// The uniform assignment `[bits; L]`.
pub fn uniform(bits: u32, l: usize) -> Vec<u32> {
    vec![bits; l]
}

/// Smallest uniform bitwidth whose (short-retrain) relative accuracy stays
/// above `min_state_acc`. Scans downward from `from_bits`; returns the last
/// bitwidth that met the budget (falling back to `from_bits`).
pub fn best_uniform(env: &QuantEnv, from_bits: u32, min_bits: u32,
                    min_state_acc: f64) -> Result<(u32, f64)> {
    let l = env.net.l;
    let mut best = (from_bits, env.state_acc(&uniform(from_bits, l))?);
    for b in (min_bits..=from_bits).rev() {
        let sa = env.state_acc(&uniform(b, l))?;
        if sa >= min_state_acc {
            best = (b, sa);
        } else {
            break;
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_shape() {
        assert_eq!(uniform(4, 3), vec![4, 4, 4]);
        assert_eq!(uniform(8, 0), Vec::<u32>::new());
    }
}
