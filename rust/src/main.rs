//! ReLeQ CLI launcher.
//!
//! Subcommands (see README):
//!   search       run the ReLeQ search on one network
//!   pretrain     pretrain a network and report the full-precision accuracy
//!   pareto       enumerate the quantization space + Pareto frontier (Fig 6)
//!   hw-eval      run Stripes + bit-serial CPU simulators on a solution
//!   admm         run the ADMM baseline bitwidth selection
//!   serve        run the quantization-as-a-service daemon (HTTP/JSON)
//!   fleet        front-end router over N serve workers (consistent-hash
//!                routing, work stealing, archive replication)
//!   exp <id>     regenerate a paper table/figure (table2|table4|table5|fig5..fig10|ablation-*)
//!   stats        dump manifest / artifact info

use anyhow::Result;
use releq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args());
    match args.subcommand.as_str() {
        "" | "help" => {
            print_help();
            Ok(())
        }
        "stats" => releq::launcher::cmd_stats(&args),
        "pretrain" => releq::launcher::cmd_pretrain(&args),
        "search" => releq::launcher::cmd_search(&args),
        "pareto" => releq::launcher::cmd_pareto(&args),
        "hw-eval" => releq::launcher::cmd_hw_eval(&args),
        "admm" => releq::launcher::cmd_admm(&args),
        "serve" => releq::launcher::cmd_serve(&args),
        "fleet" => releq::launcher::cmd_fleet(&args),
        "exp" => releq::exp::run(&args),
        other => {
            eprintln!("unknown subcommand `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "releq — RL-driven deep quantization (paper reproduction)\n\
         \n\
         usage: releq <subcommand> [--flags]\n\
         \n\
         subcommands:\n\
         \x20 search    --net <name> [--episodes N] [--seed S] [--reward proposed|ratio|diff]\n\
         \x20           [--agent lstm|fc] [--action-space flexible|restricted] [--out dir]\n\
         \x20           [--rollout batched|serial] [--lanes N]  (lockstep batched rollouts)\n\
         \x20           [--pipeline N]   (async depth: double-buffered chunks + speculative\n\
         \x20                             accuracy prefetch; 0 = synchronous)\n\
         \x20           [--replicas N]   (N parallel multi-seed searches; best wins)\n\
         \x20           [--watchdog-ms N] (per-execution wall-clock budget for the pipelined\n\
         \x20                             dispatcher; 0 = no watchdog)\n\
         \x20           [--devices N]    (PJRT device pool size; rollout lanes, megabatch eval\n\
         \x20                             chunks and replicas stripe across devices. On CPU the\n\
         \x20                             pool forces N host devices, one client per slot, so\n\
         \x20                             N>1 is testable anywhere; RELEQ_DEVICES=N presizes\n\
         \x20                             the pool at bring-up; 1 = exact pre-pool behavior)\n\
         \x20           [--checkpoint file.ckpt.json] (durable search: checkpoint at PPO\n\
         \x20                             update boundaries; re-run the same command after a\n\
         \x20                             crash to resume bit-identically)\n\
         \x20           [--checkpoint-every N] (episodes between checkpoint writes; default 8)\n\
         \x20 pretrain  --net <name> [--steps N] [--lr F] [--verbose]\n\
         \x20 pareto    --net <name> [--samples N] [--shards N] [--out dir]\n\
         \x20 hw-eval   --net <name> --bits 8,4,4,8\n\
         \x20 admm      --net <name> [--target-bits F]\n\
         \x20 serve     [--addr host:port] [--workers N] [--queue-cap N] [--archive file.json]\n\
         \x20           [--log-tail N] [--memo-persist N]   (see examples/serve_client.rs)\n\
         \x20           [--job-retries N] [--quarantine-k N] [--breaker-fails N]\n\
         \x20                             (transient-failure retries per job; consecutive env\n\
         \x20                             failures before quarantine; failures to open breaker)\n\
         \x20           [--registry-dir dir] (content-addressed install cache; enables hot\n\
         \x20                             network registration via POST /v1/networks)\n\
         \x20           [--wal file.wal] (write-ahead job journal: incomplete jobs are\n\
         \x20                             recovered and re-enqueued on restart)\n\
         \x20           [--checkpoint-dir dir] [--checkpoint-every N] (durable searches:\n\
         \x20                             recovered jobs resume from their last checkpoint)\n\
         \x20           [--access-log]   (structured JSON access-log lines on stderr)\n\
         \x20 fleet     [--addr host:port] [--spawn-workers N] [--worker-addrs h:p,h:p,...]\n\
         \x20           [--archive file.json] (merged fleet archive; spawned worker i\n\
         \x20                             writes <stem>.w<i>.json beside it)\n\
         \x20           [--merge-interval-ms N] (0 = merge on demand/shutdown only)\n\
         \x20           [--health-interval-ms N] [--steal-budget N]\n\
         \x20           [--durable]      (per-worker job WALs + checkpoint dirs, checkpoint\n\
         \x20                             replication each merge round, and failover of\n\
         \x20                             in-flight jobs when a worker dies)\n\
         \x20           [--worker-threads N] [--worker-queue-cap N] [--access-log]\n\
         \x20 exp       <table2|table4|table5|fig5|fig6|fig7|fig8|fig9|fig10|ablation-action|ablation-lstm|all>\n\
         \x20 stats\n"
    );
}
