//! Quantization design-space enumeration + Pareto analysis (paper §5.2,
//! Fig 6).
//!
//! Each point is one bitwidth assignment; axes are State-of-Quantization
//! (x, lower = cheaper) and relative accuracy (y, higher = better). For small
//! networks the space is enumerated exhaustively (LeNet: 7^4 = 2401 points,
//! as the paper did); for larger ones a seeded uniform sample is drawn and
//! the limitation is reported (the paper itself calls full enumeration
//! infeasible beyond moderate sizes).

use anyhow::Result;

use crate::coordinator::QuantEnv;
use crate::parallel;
use crate::util::rng::Pcg32;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct Point {
    pub bits: Vec<u32>,
    pub state_q: f64,
    pub state_acc: f64,
}

/// Indices of the Pareto-optimal points (maximize acc, minimize state_q),
/// sorted by increasing state_q.
pub fn pareto_frontier(points: &[Point]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // total_cmp: a NaN accuracy (degenerate eval) must not panic frontier
    // extraction; NaN state_acc sorts above +inf and then loses every
    // `> best_acc` comparison below, so such points never enter the frontier
    idx.sort_by(|&a, &b| {
        points[a]
            .state_q
            .total_cmp(&points[b].state_q)
            .then(points[b].state_acc.total_cmp(&points[a].state_acc))
    });
    let mut frontier = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for &i in &idx {
        // a NaN state_q sorts after +inf under total_cmp and would otherwise
        // slip into the frontier on accuracy alone; a degenerate cost point
        // can never be Pareto-optimal
        if points[i].state_q.is_nan() {
            continue;
        }
        if points[i].state_acc > best_acc {
            frontier.push(i);
            best_acc = points[i].state_acc;
        }
    }
    frontier
}

/// Enumeration plan for one network.
#[derive(Debug, Clone)]
pub struct EnumConfig {
    pub min_bits: u32,
    pub max_bits: u32,
    /// point budget; exhaustive when the full space fits, else seeded sampling
    pub max_points: usize,
    pub seed: u64,
}

impl Default for EnumConfig {
    fn default() -> Self {
        EnumConfig { min_bits: 2, max_bits: 8, max_points: 2500, seed: 5 }
    }
}

/// Number of assignments in the full space: (max-min+1)^L (saturating).
pub fn space_size(cfg: &EnumConfig, l: usize) -> u128 {
    let base = (cfg.max_bits - cfg.min_bits + 1) as u128;
    let mut n: u128 = 1;
    for _ in 0..l {
        n = n.saturating_mul(base);
    }
    n
}

/// Generate the bitwidth assignments to evaluate (exhaustive or sampled).
pub fn assignments(cfg: &EnumConfig, l: usize) -> (Vec<Vec<u32>>, bool) {
    let total = space_size(cfg, l);
    let exhaustive = total <= cfg.max_points as u128;
    if exhaustive {
        let base = cfg.max_bits - cfg.min_bits + 1;
        let mut out = Vec::with_capacity(total as usize);
        let mut cur = vec![cfg.min_bits; l];
        loop {
            out.push(cur.clone());
            // odometer increment
            let mut i = 0;
            loop {
                if i == l {
                    return (out, true);
                }
                cur[i] += 1;
                if cur[i] < cfg.min_bits + base {
                    break;
                }
                cur[i] = cfg.min_bits;
                i += 1;
            }
        }
    }
    let mut rng = Pcg32::new(cfg.seed);
    let span = (cfg.max_bits - cfg.min_bits + 1) as usize;
    let mut out = Vec::with_capacity(cfg.max_points);
    // include the uniform corners so the frontier endpoints are present
    for b in cfg.min_bits..=cfg.max_bits {
        out.push(vec![b; l]);
    }
    while out.len() < cfg.max_points {
        out.push((0..l).map(|_| cfg.min_bits + rng.below(span) as u32).collect());
    }
    (out, false)
}

/// Evaluate one contiguous run of assignments against the (shared-core)
/// env. Batch-capable envs hand the **whole run** to `accuracy_batch` in
/// one call, so the memo's batch protocol sees every assignment at once
/// and repacks the actual misses into full-width chunks — pre-chunking
/// here would pad every group whose hits are scattered through a
/// partially warm memo (e.g. fig6 follow-up scoring). Width-1 envs keep
/// per-point scalar queries: `accuracy_batch` would fan their misses
/// across shard threads, nesting a pool under `enumerate_sharded`'s own
/// workers. Points come back in assignment order.
fn eval_points(env: &QuantEnv, assigns: &[Vec<u32>]) -> Result<Vec<Point>> {
    if env.eval_batch_width() > 1 {
        let accs = env.accuracy_batch(assigns)?;
        return Ok(assigns
            .iter()
            .zip(accs)
            .map(|(bits, acc)| Point {
                state_q: env.state_q(bits),
                state_acc: env.state_acc_of(acc),
                bits: bits.clone(),
            })
            .collect());
    }
    assigns
        .iter()
        .map(|bits| {
            Ok(Point {
                state_q: env.state_q(bits),
                state_acc: env.state_acc(bits)?,
                bits: bits.clone(),
            })
        })
        .collect()
}

/// Evaluate the space through the environment (short-retrain accuracy).
/// Returns (points, exhaustive?).
pub fn enumerate(env: &QuantEnv, cfg: &EnumConfig) -> Result<(Vec<Point>, bool)> {
    let (assigns, exhaustive) = assignments(cfg, env.net.l);
    Ok((eval_points(env, &assigns)?, exhaustive))
}

/// Sharded enumeration over a **shared-core env**: split the assignment list
/// into contiguous chunks and evaluate them on `n_shards` worker threads,
/// every shard querying the same pretrained [`QuantEnv`] core (one pretrain
/// total — pre-refactor, each shard paid its own env bring-up) and
/// deduplicating accuracy queries through its single-flight memo.
///
/// The merge is deterministic: chunks are contiguous and concatenate in
/// shard-index order, so the returned points carry the bitwidth assignments
/// in exactly the sequence the sequential [`enumerate`] would produce.
/// Accuracy *values* are also identical to a sequential run at any shard
/// count: `EnvCore::accuracy` is a pure function of the bits vector (the
/// retrain start-batch derives from the bits, not from a shared cursor), and
/// the single-flight memo guarantees each distinct vector is evaluated
/// exactly once no matter how chunks or duplicated sampled vectors race.
///
/// The memo stays warm on the caller's env afterwards — score follow-up
/// points (e.g. a stored ReLeQ solution, `exp::figs::fig6`) on the same env
/// without re-running their retrains.
///
/// Each shard megabatches its contiguous chunk (`eval_points`): its
/// uncached assignments repack into full `eval_batch_k`-lane executions —
/// one device execution per 8 points at the default width instead of one
/// per point — and the batch single-flight protocol keeps duplicate
/// sampled assignments racing across shards down to one evaluation each.
/// Batch size, not shard count, is the first-order throughput lever
/// (EXPERIMENTS.md §Perf 7).
pub fn enumerate_sharded(env: &QuantEnv, cfg: &EnumConfig, n_shards: usize)
                         -> Result<(Vec<Point>, bool)> {
    let (assigns, exhaustive) = assignments(cfg, env.net.l);
    let n_shards = n_shards.clamp(1, assigns.len().max(1));
    let chunks = parallel::chunk_evenly(assigns, n_shards);
    let per_shard = parallel::run_sharded(chunks, |i, chunk| {
        // pin shard i to device i % N so shards spread over the engine pool;
        // accuracy values are device-independent, so this is placement only
        // (on a 1-device pool every shard pins to device 0, unchanged)
        let _pin = env.engine().pin_thread(i);
        eval_points(env, &chunk)
    })?;
    Ok((per_shard.into_iter().flatten().collect(), exhaustive))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(q: f64, a: f64) -> Point {
        Point { bits: vec![], state_q: q, state_acc: a }
    }

    #[test]
    fn frontier_filters_dominated() {
        let pts = vec![pt(0.2, 0.5), pt(0.4, 0.9), pt(0.3, 0.4), pt(0.8, 1.0), pt(0.5, 0.8)];
        let f = pareto_frontier(&pts);
        // 0.3/0.4 dominated by 0.2/0.5; 0.5/0.8 dominated by 0.4/0.9
        assert_eq!(f, vec![0, 1, 3]);
    }

    #[test]
    fn frontier_monotone() {
        let pts: Vec<Point> = (0..50)
            .map(|i| pt((i as f64) / 50.0, ((i * 7) % 50) as f64 / 50.0))
            .collect();
        let f = pareto_frontier(&pts);
        for w in f.windows(2) {
            assert!(pts[w[0]].state_q <= pts[w[1]].state_q);
            assert!(pts[w[0]].state_acc < pts[w[1]].state_acc);
        }
    }

    #[test]
    fn frontier_excludes_degenerate_points() {
        // NaN cost: would sort after +inf and win on accuracy alone
        let pts = vec![pt(0.5, 0.9), pt(f64::NAN, 0.95)];
        assert_eq!(pareto_frontier(&pts), vec![0]);
        // NaN accuracy: loses every `> best_acc` comparison
        let pts = vec![pt(0.5, 0.9), pt(0.6, f64::NAN)];
        assert_eq!(pareto_frontier(&pts), vec![0]);
    }

    #[test]
    fn exhaustive_enumeration_count() {
        let cfg = EnumConfig { min_bits: 2, max_bits: 4, max_points: 100, seed: 1 };
        let (a, ex) = assignments(&cfg, 3);
        assert!(ex);
        assert_eq!(a.len(), 27);
        // all distinct
        let mut set = std::collections::HashSet::new();
        for b in &a {
            assert!(set.insert(b.clone()));
        }
    }

    #[test]
    fn sampled_when_space_too_big() {
        let cfg = EnumConfig { min_bits: 2, max_bits: 8, max_points: 100, seed: 1 };
        let (a, ex) = assignments(&cfg, 10);
        assert!(!ex);
        assert_eq!(a.len(), 100);
        // uniform corners included
        assert!(a.contains(&vec![2; 10]));
        assert!(a.contains(&vec![8; 10]));
    }

    #[test]
    fn space_size_saturates() {
        let cfg = EnumConfig::default();
        assert_eq!(space_size(&cfg, 2), 49);
        assert!(space_size(&cfg, 80) > 1u128 << 100);
    }
}
