//! Write-ahead job journal: every submission and status transition appends
//! one checksummed JSON line to `jobs.wal`, fsync'd, so a daemon that dies
//! mid-job can recover its queue on restart.
//!
//! File shape: a header line `{"max_id":N,"schema_version":1}` followed by
//! one record per line (`max_id` is the compaction-time id high-water mark,
//! so finished jobs' ids are never reissued even after their records are
//! compacted away). Two record kinds:
//!
//! ```text
//! {"checksum":"<fnv16>","event":"submit","id":3,"spec":{...original body...}}
//! {"checksum":"<fnv16>","event":"status","id":3,"status":"running"}
//! ```
//!
//! The checksum is FNV-1a over the record's canonical dump with the
//! `checksum` key removed — the same scheme as
//! [`crate::coordinator::checkpoint`] and the solution archive, so one
//! inspection habit covers all three durable formats.
//!
//! Recovery rules ([`Wal::open`]):
//!
//! * a record that fails to parse or fails its checksum is **skipped and
//!   counted**, never a hard error — a torn tail from `kill -9` mid-append
//!   must not take the daemon down with it;
//! * a job whose last status is terminal (`done` / `failed` / `cancelled`)
//!   is complete and dropped;
//! * everything else — submitted, `running`, `interrupted` — is returned as
//!   a [`RecoveredJob`] for re-enqueue under its original id;
//! * the file is then **compacted** (tmp + rename): header plus one fresh
//!   submit record per recovered job, so the journal never grows without
//!   bound across restarts;
//! * a header from a NEWER schema is refused outright — old code must not
//!   guess at records it cannot fully interpret.
//!
//! Append failures after open are surfaced as `Err` but the scheduler treats
//! them as counters, not fatalities: a full disk degrades durability, it
//! does not stop serving.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::fnv::Fnv;
use crate::util::json::Json;

/// Journal format version. Bump on any record-shape change; `open` refuses
/// files stamped with a newer version.
pub const WAL_SCHEMA_VERSION: u64 = 1;

/// Job statuses that mean "finished, nothing to recover".
pub fn is_terminal_status(s: &str) -> bool {
    matches!(s, "done" | "failed" | "cancelled")
}

/// One incomplete job replayed out of the journal: its original id and the
/// verbatim request body it was submitted with (re-decoded through
/// [`crate::config::job_from_json`] at recovery time, so recovered specs
/// pass exactly the validation live ones do).
#[derive(Debug, Clone)]
pub struct RecoveredJob {
    pub id: u64,
    pub spec: Json,
}

/// What [`Wal::open`] found in an existing journal.
#[derive(Debug)]
pub struct WalRecovery {
    /// incomplete jobs, ascending id order
    pub jobs: Vec<RecoveredJob>,
    /// highest job id ever journaled (0 when none) — the scheduler seeds its
    /// id counter above this so recovered and fresh ids never collide
    pub max_id: u64,
    /// torn / corrupt lines skipped during replay
    pub skipped: u64,
}

/// The open journal: an append handle behind a mutex (appends come from
/// every scheduler worker thread) plus append accounting for `/v1/stats`.
pub struct Wal {
    path: PathBuf,
    file: Mutex<File>,
}

fn checksum_hex(payload: &str) -> String {
    format!("{:016x}", Fnv::new().write_bytes(payload.as_bytes()).finish())
}

/// Stamp a record with its checksum: dump the object WITHOUT the checksum
/// key, hash that, insert the key, dump again. Verification is the mirror
/// image, so any canonical-form drift fails closed.
fn sealed_line(mut obj: BTreeMap<String, Json>) -> String {
    obj.remove("checksum");
    let payload = Json::Obj(obj.clone()).dump();
    obj.insert("checksum".to_string(), Json::Str(checksum_hex(&payload)));
    Json::Obj(obj).dump()
}

/// Parse + verify one journal line. `None` = torn or tampered, skip it.
fn verified_record(line: &str) -> Option<Json> {
    let j = Json::parse(line).ok()?;
    let obj = j.as_obj()?;
    let want = obj.get("checksum")?.as_str()?.to_string();
    let mut stripped = obj.clone();
    stripped.remove("checksum");
    if checksum_hex(&Json::Obj(stripped).dump()) == want {
        Some(j)
    } else {
        None
    }
}

fn submit_record(id: u64, spec: &Json) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("event".to_string(), Json::Str("submit".to_string()));
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert("spec".to_string(), spec.clone());
    sealed_line(obj)
}

fn status_record(id: u64, status: &str) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("event".to_string(), Json::Str("status".to_string()));
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert("status".to_string(), Json::Str(status.to_string()));
    sealed_line(obj)
}

impl Wal {
    /// Open (creating if absent) the journal at `path`: replay it, compact
    /// it, and return the append handle plus everything recovered.
    pub fn open(path: &Path) -> Result<(Wal, WalRecovery)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating WAL dir {}", parent.display()))?;
            }
        }
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(e).with_context(|| format!("reading WAL {}", path.display())),
        };

        let mut max_id = 0u64;
        let mut lines = text.lines();
        if let Some(header) = lines.next() {
            let h = Json::parse(header)
                .with_context(|| format!("WAL {} has an unreadable header", path.display()))?;
            let schema = h
                .get("schema_version")
                .and_then(Json::as_f64)
                .context("WAL header missing schema_version")? as u64;
            anyhow::ensure!(
                schema <= WAL_SCHEMA_VERSION,
                "WAL {} has schema_version {} but this build understands {}",
                path.display(),
                schema,
                WAL_SCHEMA_VERSION
            );
            if let Some(n) = h.get("max_id").and_then(Json::as_f64) {
                if n >= 0.0 && n.fract() == 0.0 {
                    max_id = n as u64;
                }
            }
        }

        // Replay: last writer wins per id. A status line for an id with no
        // surviving submit record cannot be recovered (the spec is gone) —
        // it is counted as skipped rather than silently dropped.
        let mut specs: BTreeMap<u64, Json> = BTreeMap::new();
        let mut status: BTreeMap<u64, String> = BTreeMap::new();
        let mut skipped = 0u64;
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let Some(rec) = verified_record(line) else {
                skipped += 1;
                continue;
            };
            let id = match rec.get("id").and_then(Json::as_f64) {
                Some(n) if n >= 0.0 && n.fract() == 0.0 => n as u64,
                _ => {
                    skipped += 1;
                    continue;
                }
            };
            match rec.get("event").and_then(Json::as_str) {
                Some("submit") => match rec.get("spec") {
                    Some(spec) => {
                        specs.insert(id, spec.clone());
                        max_id = max_id.max(id);
                    }
                    None => skipped += 1,
                },
                Some("status") => match rec.get("status").and_then(Json::as_str) {
                    Some(s) => {
                        status.insert(id, s.to_string());
                        max_id = max_id.max(id);
                    }
                    None => skipped += 1,
                },
                _ => skipped += 1,
            }
        }
        for (id, s) in &status {
            if is_terminal_status(s) || !specs.contains_key(id) {
                specs.remove(id);
                if !is_terminal_status(s) {
                    skipped += 1; // orphan non-terminal status: unrecoverable
                }
            }
        }
        let jobs: Vec<RecoveredJob> = specs
            .into_iter()
            .map(|(id, spec)| RecoveredJob { id, spec })
            .collect();

        // Compact: header + one submit record per recovered job, atomically.
        let tmp = path.with_extension("wal.tmp");
        {
            let mut out = String::new();
            out.push_str(&format!(
                "{{\"max_id\":{max_id},\"schema_version\":{WAL_SCHEMA_VERSION}}}\n"
            ));
            for j in &jobs {
                out.push_str(&submit_record(j.id, &j.spec));
                out.push('\n');
            }
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating WAL tmp {}", tmp.display()))?;
            f.write_all(out.as_bytes())?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("installing compacted WAL {}", path.display()))?;

        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("opening WAL {} for append", path.display()))?;
        Ok((
            Wal { path: path.to_path_buf(), file: Mutex::new(file) },
            WalRecovery { jobs, max_id, skipped },
        ))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn append(&self, line: &str) -> Result<()> {
        let mut f = crate::util::lock_recover(&self.file);
        f.write_all(line.as_bytes())
            .and_then(|()| f.write_all(b"\n"))
            .and_then(|()| f.sync_data())
            .with_context(|| format!("appending to WAL {}", self.path.display()))
    }

    /// Journal a fresh submission: id + the verbatim request body.
    pub fn append_submit(&self, id: u64, spec: &Json) -> Result<()> {
        self.append(&submit_record(id, spec))
    }

    /// Journal a status transition (`running`, `done`, `failed`,
    /// `cancelled`, `interrupted`).
    pub fn append_status(&self, id: u64, status: &str) -> Result<()> {
        self.append(&status_record(id, status))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("releq_wal_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!(
            "{}_{}.wal",
            name,
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn spec(net: &str) -> Json {
        Json::obj(vec![("net", Json::Str(net.to_string()))])
    }

    #[test]
    fn replay_recovers_incomplete_jobs_only() {
        let p = tmp("replay");
        {
            let (w, rec) = Wal::open(&p).unwrap();
            assert!(rec.jobs.is_empty());
            assert_eq!((rec.max_id, rec.skipped), (0, 0));
            w.append_submit(1, &spec("lenet")).unwrap();
            w.append_status(1, "running").unwrap();
            w.append_status(1, "done").unwrap();
            w.append_submit(2, &spec("simplenet")).unwrap();
            w.append_status(2, "running").unwrap(); // died mid-run
            w.append_submit(3, &spec("lenet")).unwrap(); // never started
            w.append_submit(4, &spec("lenet")).unwrap();
            w.append_status(4, "interrupted").unwrap(); // graceful shutdown
            w.append_submit(5, &spec("lenet")).unwrap();
            w.append_status(5, "cancelled").unwrap();
        }
        let (_w, rec) = Wal::open(&p).unwrap();
        let ids: Vec<u64> = rec.jobs.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
        assert_eq!(rec.max_id, 5, "terminal ids still fence the id counter");
        assert_eq!(rec.skipped, 0);
        assert_eq!(
            rec.jobs[0].spec.get("net").and_then(Json::as_str),
            Some("simplenet"),
            "spec body survives the journal verbatim"
        );
        // terminal ids were compacted away, but the header's high-water mark
        // keeps fencing the id counter on every subsequent open
        drop(_w);
        let (_w, rec) = Wal::open(&p).unwrap();
        assert_eq!(rec.max_id, 5);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_is_skipped_and_counted() {
        let p = tmp("torn");
        {
            let (w, _) = Wal::open(&p).unwrap();
            w.append_submit(1, &spec("lenet")).unwrap();
            w.append_submit(2, &spec("lenet")).unwrap();
        }
        // simulate kill -9 mid-append: a truncated record on the tail
        let mut text = std::fs::read_to_string(&p).unwrap();
        text.push_str("{\"checksum\":\"0000000000000000\",\"event\":\"status\",\"id\":1,");
        std::fs::write(&p, text).unwrap();
        let (_w, rec) = Wal::open(&p).unwrap();
        assert_eq!(rec.jobs.len(), 2, "intact records all recovered");
        assert_eq!(rec.skipped, 1, "the torn line is counted, not fatal");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn checksum_mismatch_drops_the_record() {
        let p = tmp("tamper");
        {
            let (w, _) = Wal::open(&p).unwrap();
            w.append_submit(1, &spec("lenet")).unwrap();
            w.append_status(1, "done").unwrap();
        }
        // flip the terminal status to a non-terminal one without re-sealing:
        // the checksum no longer matches, so the edit must be ignored and
        // the job treated as done (its last VALID status).
        let text = std::fs::read_to_string(&p).unwrap().replace("\"done\"", "\"running\"");
        std::fs::write(&p, text).unwrap();
        let (_w, rec) = Wal::open(&p).unwrap();
        assert!(rec.jobs.is_empty(), "tampered status line must not resurrect the job");
        assert_eq!(rec.skipped, 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn newer_schema_is_refused() {
        let p = tmp("schema");
        std::fs::write(&p, "{\"schema_version\":99}\n").unwrap();
        let err = Wal::open(&p).unwrap_err().to_string();
        assert!(err.contains("schema_version 99"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn compaction_bounds_the_file() {
        let p = tmp("compact");
        {
            let (w, _) = Wal::open(&p).unwrap();
            for id in 1..=20u64 {
                w.append_submit(id, &spec("lenet")).unwrap();
                w.append_status(id, "done").unwrap();
            }
            w.append_submit(21, &spec("lenet")).unwrap();
        }
        let before = std::fs::metadata(&p).unwrap().len();
        let (_w, rec) = Wal::open(&p).unwrap();
        let after = std::fs::metadata(&p).unwrap().len();
        assert_eq!(rec.jobs.len(), 1);
        assert!(
            after < before / 4,
            "compaction must shed the 20 finished jobs ({before} -> {after} bytes)"
        );
        // and the compacted file replays identically
        let (_w2, rec2) = Wal::open(&p).unwrap();
        assert_eq!(rec2.jobs.len(), 1);
        assert_eq!(rec2.jobs[0].id, 21);
        assert_eq!(rec2.max_id, 21, "max_id survives compaction via the submit record");
        let _ = std::fs::remove_file(&p);
    }
}
