//! Quantization-as-a-service: the `releq serve` daemon.
//!
//! ReLeQ's output — a per-layer bitwidth policy — is consumed by deployment
//! pipelines that need it *on demand*, per network × per constraint set
//! (HAQ makes the same observation: the search re-runs per target). This
//! module turns the one-shot CLI into a long-running service over the
//! substrate PRs 1–2 built (thread-safe engine, shared-core envs,
//! single-flight memo):
//!
//! * [`http`] — dependency-free HTTP/1.1 over `std::net::TcpListener`,
//!   JSON wire format via `util::json`;
//! * [`scheduler`] — bounded job queue + worker pool, per-job cancellation
//!   and deadlines, graceful drain;
//! * [`session`] — one pretrained shared-core env per (network, env
//!   config) for the whole process lifetime, single-flight creation;
//! * [`archive`] — persistent solution store (atomic write-rename):
//!   exact resubmissions are answered with zero accuracy evaluations,
//!   near-duplicates warm-start the accuracy memo.
//!
//! # Endpoints
//!
//! | method | path                  | purpose                                   |
//! |--------|-----------------------|-------------------------------------------|
//! | POST   | `/v1/jobs`            | submit `{net, config?, deadline_ms?}`     |
//! | GET    | `/v1/jobs`            | paged job listing (`?cursor=&limit=`)     |
//! | GET    | `/v1/jobs/{id}`       | status + live episode tail                |
//! | GET    | `/v1/jobs/{id}/result`| bits, accuracy, reward, Pareto points     |
//! | POST   | `/v1/jobs/{id}/cancel`| cooperative cancellation                  |
//! | GET    | `/v1/archive`         | paged archive records (`?cursor=&limit=`) — fleet replication reads this |
//! | POST   | `/v1/archive/merge`   | union-merge replicated records (max hits wins) |
//! | GET    | `/v1/stats`           | queue/session/engine/archive/registry counters |
//! | GET    | `/v1/health`          | engine/session/queue/breaker health (503 when degraded) |
//! | POST   | `/v1/networks`        | register/upgrade a network in the running daemon |
//! | GET    | `/v1/checkpoints`     | list search checkpoints (fleet replication reads this) |
//! | GET    | `/v1/checkpoints/{f}` | one raw checkpoint document                |
//! | POST   | `/v1/checkpoints/{f}` | replicate a checkpoint in (higher episodes wins) |
//! | POST   | `/v1/shutdown`        | drain in-flight jobs, persist, exit       |
//!
//! With `--wal`, job submissions and status transitions are journaled
//! write-ahead ([`wal`]); a daemon restarted over the same journal
//! re-enqueues every incomplete job under its original id. With
//! `--checkpoint-dir`, searches checkpoint at PPO update boundaries and
//! recovered jobs resume bit-identically instead of restarting. SIGTERM /
//! SIGINT trigger the same interrupt path as a crash-with-journal, plus a
//! final checkpoint flush for running jobs.
//!
//! Connections close after one exchange unless the client sends
//! `Connection: keep-alive` (see [`http`] — the fleet router's per-worker
//! connection pools depend on this).

pub mod archive;
pub mod http;
pub mod scheduler;
pub mod session;
pub mod wal;

pub use archive::{
    env_fingerprint, search_fingerprint, Archive, MergeOutcome, MergeStats, Record, Solution,
};
pub use scheduler::{CancelOutcome, Job, JobRunner, JobStatus, Scheduler, SubmitError};
pub use session::{SessionCache, SessionKey, SessionRunner};
pub use wal::{RecoveredJob, Wal, WalRecovery, WAL_SCHEMA_VERSION};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::{self, ServeConfig};
use crate::coordinator::SearchCheckpoint;
use crate::registry::{RegisterError, Registry};
use crate::runtime::{Engine, Manifest};
use crate::util::json::Json;
use crate::util::lock_recover;
use crate::util::signals;

use http::{Request, Response};

/// Default page size for `GET /v1/jobs` / `GET /v1/archive` when the
/// client sends no `limit`.
pub const LIST_LIMIT_DEFAULT: usize = 50;
/// Hard cap on a requested `limit` — a page must stay well under
/// [`http::MAX_BODY`] even with memo-heavy archive records, so
/// fleet-sized listings can never build unbounded JSON bodies.
pub const LIST_LIMIT_MAX: usize = 64;

/// Shared daemon state handed to every connection thread.
pub struct Daemon {
    pub sched: Arc<Scheduler>,
    pub archive: Arc<Archive>,
    pub registry: Arc<Registry>,
    runner: Arc<dyn JobRunner>,
    cfg: ServeConfig,
    local_addr: SocketAddr,
    /// set once a shutdown request finished draining; breaks the accept loop
    shutdown: AtomicBool,
    /// TCP connections accepted (one keep-alive connection counts once)
    connections: AtomicU64,
    /// requests served across all connections
    requests: AtomicU64,
}

/// The bound-but-not-yet-serving daemon. `bind` then `run`; `local_addr`
/// in between is how tests discover the ephemeral port of `--addr :0`.
pub struct Server {
    listener: TcpListener,
    daemon: Arc<Daemon>,
}

impl Server {
    /// Production bring-up: PJRT engine + manifest behind a
    /// [`SessionRunner`].
    pub fn bind(cfg: ServeConfig, manifest: Manifest, engine: Arc<Engine>) -> Result<Server> {
        let archive = Arc::new(Archive::open(&cfg.archive)?);
        let registry = Arc::new(Registry::with_engine(
            manifest.clone(),
            cfg.registry_dir.clone(),
            engine.clone(),
        )?);
        let runner = Arc::new(
            SessionRunner::new(
                manifest,
                engine,
                archive.clone(),
                cfg.memo_persist,
                cfg.quarantine_k,
                registry,
            )
            .with_checkpoints(cfg.checkpoint_dir.clone(), cfg.checkpoint_every),
        );
        Server::bind_with(cfg, runner, archive)
    }

    /// Bring-up over any [`JobRunner`] backend — the seam the integration
    /// tests use to exercise queueing/cancellation/drain without PJRT
    /// artifacts.
    pub fn bind_with(cfg: ServeConfig, runner: Arc<dyn JobRunner>, archive: Arc<Archive>)
                     -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        let sched = Scheduler::new(runner.clone(), archive.clone(), &cfg);
        // journal recovery happens BEFORE workers spawn: every incomplete
        // job is back in the queue (original ids) when execution starts
        if let Some(path) = &cfg.wal {
            let (wal, recovery) = wal::Wal::open(path)?;
            if !recovery.jobs.is_empty() || recovery.skipped > 0 {
                eprintln!(
                    "[serve] WAL {}: recovered {} incomplete job(s), skipped {} torn record(s)",
                    path.display(),
                    recovery.jobs.len(),
                    recovery.skipped
                );
            }
            sched.attach_wal(Arc::new(wal), recovery);
        }
        sched.spawn_workers(cfg.workers);
        // the runner's registry if it has one (the production
        // SessionRunner); otherwise an engine-less registry so stub
        // daemons still answer `POST /v1/networks` and stats rows
        let registry = match runner.registry() {
            Some(r) => r,
            None => Arc::new(Registry::new(None, cfg.registry_dir.clone())?),
        };
        let daemon = Arc::new(Daemon {
            sched,
            archive,
            registry,
            runner,
            cfg,
            local_addr,
            shutdown: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
        });
        Ok(Server { listener, daemon })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.daemon.local_addr
    }

    /// The shared daemon state — tests use this to drive
    /// [`Daemon::interrupt`] without a real signal.
    pub fn daemon(&self) -> Arc<Daemon> {
        self.daemon.clone()
    }

    /// Accept loop: one thread per connection. A connection serves one
    /// request (`Connection: close`, the default) or a bounded keep-alive
    /// sequence when the client opts in (`http::serve_conn`). Returns
    /// after a `POST /v1/shutdown` has drained the scheduler and persisted
    /// the archive, or after SIGTERM/SIGINT ran the interrupt path.
    pub fn run(self) -> Result<()> {
        signals::install();
        let d = self.daemon.clone();
        std::thread::spawn(move || loop {
            if d.shutdown.load(Ordering::SeqCst) {
                return; // normal shutdown already happened
            }
            if signals::triggered() {
                eprintln!("[serve] termination signal: interrupting jobs and persisting");
                d.interrupt();
                return;
            }
            std::thread::sleep(Duration::from_millis(100));
        });
        for conn in self.listener.incoming() {
            if self.daemon.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[serve] accept error: {e}");
                    continue;
                }
            };
            let d = self.daemon.clone();
            // thread-per-connection is proportionate here: requests are
            // tiny JSON exchanges; the expensive work happens on the
            // scheduler's bounded worker pool, not these threads
            std::thread::spawn(move || handle_conn(&d, stream));
        }
        Ok(())
    }
}

impl Daemon {
    /// Graceful termination — SIGTERM/SIGINT and the kill-mid-job tests
    /// both land here. Running searches stop at their next episode
    /// boundary (flushing a final checkpoint, journaled `interrupted`),
    /// queued journaled jobs are abandoned for the next start to recover,
    /// the archive is persisted unconditionally, and the accept loop is
    /// kicked awake to exit. Idempotent.
    pub fn interrupt(&self) {
        self.sched.interrupt();
        if let Err(e) = self.archive.save() {
            eprintln!("[serve] archive save at interrupt failed: {e:#}");
        }
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.local_addr);
    }
}

fn handle_conn(d: &Arc<Daemon>, stream: TcpStream) {
    d.connections.fetch_add(1, Ordering::Relaxed);
    let st = http::serve_conn(stream, d.cfg.access_log, "serve", |req| route(d, req));
    d.requests.fetch_add(st.served, Ordering::Relaxed);
    if st.exit {
        d.shutdown.store(true, Ordering::SeqCst);
        // kick the accept loop out of its blocking accept
        let _ = TcpStream::connect(d.local_addr);
    }
}

/// Dispatch one request. The bool is "exit the accept loop after
/// responding" — true only for a completed shutdown.
pub fn route(d: &Daemon, req: &Request) -> (Response, bool) {
    let path = req.path.split('?').next().unwrap_or("");
    let segs: Vec<&str> = path.trim_matches('/').split('/').collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "jobs"]) => (post_job(d, req), false),
        ("GET", ["v1", "jobs"]) => (list_jobs(d, req), false),
        ("GET", ["v1", "jobs", id]) => (with_job(d, id, |j| Response::ok(j.status_json())), false),
        ("GET", ["v1", "jobs", id, "result"]) => (with_job(d, id, job_result), false),
        ("POST", ["v1", "jobs", id, "cancel"]) => (cancel_job(d, id), false),
        ("GET", ["v1", "archive"]) => (list_archive(d, req), false),
        ("POST", ["v1", "archive", "merge"]) => (merge_archive(d, req), false),
        ("GET", ["v1", "stats"]) => (stats(d), false),
        ("GET", ["v1", "health"]) => (health(d), false),
        ("POST", ["v1", "networks"]) => (post_network(d, req), false),
        ("GET", ["v1", "checkpoints"]) => (list_checkpoints(d), false),
        ("GET", ["v1", "checkpoints", name]) => (get_checkpoint(d, name), false),
        ("POST", ["v1", "checkpoints", name]) => (put_checkpoint(d, name, req), false),
        ("POST", ["v1", "shutdown"]) => shutdown(d),
        _ => {
            // a known path with the wrong method is a 405, not a
            // misleading "no such endpoint"
            let known = matches!(
                segs.as_slice(),
                ["v1", "jobs"]
                    | ["v1", "jobs", _]
                    | ["v1", "jobs", _, "result"]
                    | ["v1", "jobs", _, "cancel"]
                    | ["v1", "archive"]
                    | ["v1", "archive", "merge"]
                    | ["v1", "stats"]
                    | ["v1", "health"]
                    | ["v1", "networks"]
                    | ["v1", "checkpoints"]
                    | ["v1", "checkpoints", _]
                    | ["v1", "shutdown"]
            );
            if known {
                (Response::error(405, "method not allowed for this endpoint"), false)
            } else {
                (Response::error(404, "no such endpoint"), false)
            }
        }
    }
}

fn post_job(d: &Daemon, req: &Request) -> Response {
    let body = match req.json() {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let spec = match config::job_from_json(&body) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    match d.sched.submit(spec) {
        Ok(job) => {
            let (status, from_archive) = {
                let s = lock_recover(&job.state);
                (s.status, s.from_archive)
            };
            // an archive answer is complete right now (200); a queued job
            // is accepted-for-processing (202)
            let code = if from_archive { 200 } else { 202 };
            Response::status(
                code,
                Json::obj(vec![
                    ("id", Json::Num(job.id as f64)),
                    ("status", Json::Str(status.as_str().to_string())),
                    (
                        "source",
                        Json::Str(if from_archive { "archive" } else { "search" }.to_string()),
                    ),
                ]),
            )
        }
        Err(SubmitError::Full) => Response::error(429, "job queue is full; retry later"),
        Err(SubmitError::Draining) => Response::error(503, "daemon is draining"),
        Err(SubmitError::Unavailable(msg)) => Response::error(503, &msg),
        Err(SubmitError::Invalid(e)) => Response::error(400, &format!("{e:#}")),
    }
}

/// `POST /v1/networks`: register or upgrade a network in the running
/// daemon. Body is either `{"source": "/dir"}` (the daemon reads
/// `<dir>/registry.json` and fetches the artifacts from that dir) or an
/// inline manifest with artifact text under `files`. Every artifact is
/// sha256-verified against the manifest before the atomic install; the
/// new version is visible to the next `POST /v1/jobs` — in-flight jobs
/// stay pinned to the version they prepared against.
fn post_network(d: &Daemon, req: &Request) -> Response {
    let body = match req.json() {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // name validation answers 400 even on a registry-less daemon — a bad
    // name is the client's bug regardless of server configuration
    if let Some(name) = body.get("name").and_then(|v| v.as_str()) {
        if let Err(e) = config::validate_net_name(name) {
            return Response::error(400, &format!("{e:#}"));
        }
    }
    if !d.registry.enabled() {
        return Response::error(
            503,
            "network registry disabled; start the daemon with --registry-dir",
        );
    }
    match d.registry.register_json(&body) {
        Ok(ins) => Response::ok(Json::obj(vec![
            ("net", Json::Str(ins.name)),
            ("version", Json::Num(ins.version as f64)),
            ("digest", Json::Str(ins.digest)),
            ("installed", Json::Bool(ins.installed)),
        ])),
        Err(RegisterError::Invalid(msg)) => Response::error(400, &msg),
        Err(RegisterError::Conflict(msg)) => Response::error(409, &msg),
        Err(RegisterError::Internal(e)) => Response::error(500, &format!("{e:#}")),
    }
}

/// Parse `?cursor=&limit=` off a listing request: `Err` is the 400 to
/// answer with. The limit is clamped to [`LIST_LIMIT_MAX`] rather than
/// rejected — a client asking for more simply pages more often. Shared by
/// the daemon's listings and the fleet router's.
pub fn page_params(req: &Request) -> Result<(Option<String>, usize), Response> {
    let q = req.query();
    let cursor = q.get("cursor").cloned().filter(|c| !c.is_empty());
    let limit = match q.get("limit") {
        None => LIST_LIMIT_DEFAULT,
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => n.min(LIST_LIMIT_MAX),
            _ => return Err(Response::error(400, "limit must be a positive integer")),
        },
    };
    Ok((cursor, limit))
}

/// `GET /v1/jobs?cursor=&limit=`: one page of retained job summaries in
/// id order. `next_cursor` is null on the last page.
fn list_jobs(d: &Daemon, req: &Request) -> Response {
    let (cursor, limit) = match page_params(req) {
        Ok(p) => p,
        Err(resp) => return resp,
    };
    let cursor = match cursor {
        None => None,
        Some(c) => match c.parse::<u64>() {
            Ok(n) => Some(n),
            Err(_) => return Response::error(400, "cursor must be a job id"),
        },
    };
    let (jobs, next) = d.sched.jobs_page(cursor, limit);
    Response::ok(Json::obj(vec![
        ("jobs", Json::Arr(jobs.iter().map(|j| j.summary_json()).collect())),
        (
            "next_cursor",
            next.map(|n| Json::Str(n.to_string())).unwrap_or(Json::Null),
        ),
    ]))
}

/// `GET /v1/archive?cursor=&limit=`: one page of archive records in key
/// (fingerprint) order — the fleet pull-merge's read side. The cursor is
/// opaque to clients (it happens to be the last record key).
fn list_archive(d: &Daemon, req: &Request) -> Response {
    let (cursor, limit) = match page_params(req) {
        Ok(p) => p,
        Err(resp) => return resp,
    };
    let (records, next) = d.archive.page(cursor.as_deref(), limit);
    Response::ok(Json::obj(vec![
        ("records", Json::Obj(records.into_iter().collect())),
        (
            "next_cursor",
            next.map(Json::Str).unwrap_or(Json::Null),
        ),
    ]))
}

/// `POST /v1/archive/merge`: union-merge replicated records into this
/// worker's archive (max hit count wins; see `Archive::merge_record`).
/// A merge that changed anything re-warms live session memos and persists
/// (throttled — the drain still saves unconditionally).
fn merge_archive(d: &Daemon, req: &Request) -> Response {
    let body = match req.json() {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    match d.archive.merge_json(&body) {
        Ok(st) => {
            if st.changed() {
                d.runner.absorb_archive(&d.archive);
                if let Err(e) = d.archive.save_throttled(Duration::from_secs(5)) {
                    eprintln!("[serve] archive save after merge failed: {e:#}");
                }
            }
            let mut out = match st.to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("MergeStats::to_json returns an object"),
            };
            out.insert("records".to_string(), Json::Num(d.archive.len() as f64));
            Response::ok(Json::Obj(out))
        }
        Err(e) => Response::error(400, &format!("{e:#}")),
    }
}

fn with_job(d: &Daemon, id: &str, f: impl FnOnce(&Job) -> Response) -> Response {
    let Ok(id) = id.parse::<u64>() else {
        return Response::error(400, "job id must be a number");
    };
    match d.sched.job(id) {
        Some(job) => f(&job),
        None => Response::error(404, "no such job (finished jobs are retained briefly)"),
    }
}

fn job_result(job: &Job) -> Response {
    let status = lock_recover(&job.state).status;
    match status {
        JobStatus::Done => match job.result_json() {
            Some(j) => Response::ok(j),
            None => Response::error(500, "done job has no solution"),
        },
        JobStatus::Failed => {
            let err = lock_recover(&job.state).error.clone().unwrap_or_default();
            Response::error(500, &format!("job failed: {err}"))
        }
        JobStatus::Cancelled => Response::error(409, "job was cancelled"),
        JobStatus::Queued | JobStatus::Running => {
            Response::error(409, "job not finished; poll GET /v1/jobs/{id}")
        }
    }
}

fn cancel_job(d: &Daemon, id: &str) -> Response {
    let Ok(id) = id.parse::<u64>() else {
        return Response::error(400, "job id must be a number");
    };
    match d.sched.cancel(id) {
        CancelOutcome::Accepted => {
            Response::ok(Json::obj(vec![("cancelled", Json::Bool(true))]))
        }
        CancelOutcome::AlreadyFinished => Response::error(409, "job already finished"),
        CancelOutcome::Unknown => Response::error(404, "no such job"),
    }
}

/// Gate a client-supplied checkpoint file name: strict charset, mandatory
/// suffix, so it can never traverse out of the checkpoint dir or name a
/// non-checkpoint file. (The charset excludes `/` and `\`, so `..` is the
/// only traversal vector left — and `.` is allowed in names, hence the
/// explicit check.)
fn checkpoint_name_ok(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && name.ends_with(".ckpt.json")
        && !name.contains("..")
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-')
}

/// `GET /v1/checkpoints`: checkpoint files with their resume positions —
/// the read side of fleet checkpoint replication. Corrupt or torn files
/// are silently unlisted (they fail [`SearchCheckpoint::load`]'s checksum),
/// so a replica never pulls garbage.
fn list_checkpoints(d: &Daemon) -> Response {
    let Some(dir) = &d.cfg.checkpoint_dir else {
        return Response::error(
            503,
            "checkpoints disabled; start the daemon with --checkpoint-dir",
        );
    };
    let mut names: Vec<String> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| checkpoint_name_ok(n))
            .collect(),
        Err(_) => Vec::new(), // dir not created yet = no checkpoints
    };
    names.sort();
    names.truncate(LIST_LIMIT_MAX);
    let mut out = Vec::new();
    for name in names {
        if let Ok(Some(ck)) = SearchCheckpoint::load(&dir.join(&name)) {
            out.push(Json::obj(vec![
                ("file", Json::Str(name)),
                ("net", Json::Str(ck.net.clone())),
                ("search_fp", Json::Str(format!("{:016x}", ck.search_fp))),
                ("episodes_done", Json::Num(ck.episodes_done as f64)),
            ]));
        }
    }
    Response::ok(Json::obj(vec![("checkpoints", Json::Arr(out))]))
}

/// `GET /v1/checkpoints/{file}`: one raw checkpoint document, exactly as
/// stored (the checksum stays valid end to end).
fn get_checkpoint(d: &Daemon, name: &str) -> Response {
    let Some(dir) = &d.cfg.checkpoint_dir else {
        return Response::error(
            503,
            "checkpoints disabled; start the daemon with --checkpoint-dir",
        );
    };
    if !checkpoint_name_ok(name) {
        return Response::error(400, "bad checkpoint name");
    }
    match std::fs::read_to_string(dir.join(name)) {
        Ok(text) => match Json::parse(&text) {
            Ok(j) => Response::ok(j),
            Err(e) => Response::error(500, &format!("unreadable checkpoint: {e:#}")),
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Response::error(404, "no such checkpoint")
        }
        Err(e) => Response::error(500, &format!("{e:#}")),
    }
}

/// `POST /v1/checkpoints/{file}`: replicate a checkpoint in. The body is
/// fully verified (schema gate, checksum, field decode) and installed only
/// when AHEAD of the local copy — replication must never roll a resume
/// position back, and a corrupted payload must never land on disk.
fn put_checkpoint(d: &Daemon, name: &str, req: &Request) -> Response {
    let Some(dir) = &d.cfg.checkpoint_dir else {
        return Response::error(
            503,
            "checkpoints disabled; start the daemon with --checkpoint-dir",
        );
    };
    if !checkpoint_name_ok(name) {
        return Response::error(400, "bad checkpoint name");
    }
    let body = match req.json() {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let ck = match SearchCheckpoint::from_json(&body) {
        Ok(ck) => ck,
        Err(e) => return Response::error(400, &format!("rejected checkpoint: {e:#}")),
    };
    let path = dir.join(name);
    if let Ok(Some(existing)) = SearchCheckpoint::load(&path) {
        if existing.episodes_done >= ck.episodes_done {
            return Response::ok(Json::obj(vec![
                ("installed", Json::Bool(false)),
                ("episodes_done", Json::Num(existing.episodes_done as f64)),
            ]));
        }
    }
    match ck.save(&path, None) {
        Ok(()) => Response::ok(Json::obj(vec![
            ("installed", Json::Bool(true)),
            ("episodes_done", Json::Num(ck.episodes_done as f64)),
        ])),
        Err(e) => Response::error(500, &format!("{e:#}")),
    }
}

fn stats(d: &Daemon) -> Response {
    Response::ok(Json::obj(vec![
        ("workers", Json::Num(d.cfg.workers as f64)),
        ("draining", Json::Bool(d.sched.is_draining())),
        (
            "http",
            Json::obj(vec![
                ("connections", Json::Num(d.connections.load(Ordering::Relaxed) as f64)),
                ("requests", Json::Num(d.requests.load(Ordering::Relaxed) as f64)),
            ]),
        ),
        ("scheduler", d.sched.stats_json()),
        (
            "archive",
            Json::obj(vec![
                ("path", Json::Str(d.archive.path().display().to_string())),
                ("records", Json::Num(d.archive.len() as f64)),
                ("hits", Json::Num(d.archive.hits() as f64)),
            ]),
        ),
        ("registry", d.registry.stats_json()),
        (
            "checkpoints",
            Json::obj(vec![
                ("enabled", Json::Bool(d.cfg.checkpoint_dir.is_some())),
                (
                    "dir",
                    d.cfg
                        .checkpoint_dir
                        .as_ref()
                        .map(|p| Json::Str(p.display().to_string()))
                        .unwrap_or(Json::Null),
                ),
            ]),
        ),
        ("runner", d.runner.stats()),
    ]))
}

/// `GET /v1/health`: 200 while the daemon can make progress, 503 when it
/// is degraded — engine watchdog tripped or circuit breaker open. Load
/// balancers and the chaos smoke key off the status code; the body carries
/// the per-component detail for humans.
fn health(d: &Daemon) -> Response {
    let engine_healthy = d.sched.runner_healthy();
    let breaker_open = d.sched.breaker_open();
    let degraded = !engine_healthy || breaker_open;
    let status = if degraded { "degraded" } else { "ok" };
    let body = Json::obj(vec![
        ("status", Json::Str(status.to_string())),
        ("engine_healthy", Json::Bool(engine_healthy)),
        ("breaker_open", Json::Bool(breaker_open)),
        ("draining", Json::Bool(d.sched.is_draining())),
        ("queue_depth", Json::Num(d.sched.queue_depth() as f64)),
        ("running", Json::Num(d.sched.running() as f64)),
        ("runner", d.runner.stats()),
    ]);
    if degraded {
        Response::status(503, body)
    } else {
        Response::ok(body)
    }
}

fn shutdown(d: &Daemon) -> (Response, bool) {
    // drain first, persist second, respond third: when the client sees the
    // 200, every accepted job has finished and the archive is on disk
    d.sched.drain();
    match d.archive.save() {
        Ok(()) => (
            Response::ok(Json::obj(vec![
                ("drained", Json::Bool(true)),
                ("archived_records", Json::Num(d.archive.len() as f64)),
            ])),
            true,
        ),
        Err(e) => (
            Response::error(500, &format!("drained, but archive save failed: {e:#}")),
            true,
        ),
    }
}
