//! Job scheduler: a bounded queue of quantization-search jobs multiplexed
//! over a fixed worker-thread pool, with per-job cooperative cancellation
//! (through [`SearchCtl`]), live log tails, instant archive answers for
//! exact resubmissions, and a graceful drain for shutdown.
//!
//! The execution backend is abstracted behind [`JobRunner`] so the queue /
//! backpressure / cancellation / drain machinery is testable without PJRT
//! artifacts (`rust/tests/serve_daemon.rs` drives it with a stub runner);
//! the real backend is `session::SessionRunner`.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config::{job_from_json, JobSpec, ServeConfig};
use crate::coordinator::{Cancelled, SearchCtl};
use crate::metrics::{episodes_json, EpisodeLog};
use crate::runtime::{classify, FaultClass, FaultError, RetryPolicy};
use crate::util::json::Json;
use crate::util::lock::lock_recover;
use crate::util::rng::Pcg32;

use super::archive::{Archive, Record, Solution};
use super::wal::{Wal, WalRecovery};

/// Finished jobs retained for status queries after completion. Without a
/// bound the job table is the daemon's second unbounded map (the first
/// being the accuracy memo, bounded in this same PR).
const FINISHED_RETAIN: usize = 256;

/// Minimum interval between per-completion archive saves (each save
/// rewrites the whole file — see [`Archive::save_throttled`]). The
/// shutdown drain persists unconditionally regardless.
const SAVE_INTERVAL: Duration = Duration::from_secs(5);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled)
    }
}

/// Mutable per-job state (behind the job's own mutex, not the scheduler
/// lock — status polls never contend with queue operations).
pub struct JobState {
    pub status: JobStatus,
    pub error: Option<String>,
    pub episodes_run: usize,
    /// bounded live tail of finished episodes (`GET /v1/jobs/{id}`)
    pub tail: VecDeque<EpisodeLog>,
    pub solution: Option<Solution>,
    /// answered from the archive without running a search
    pub from_archive: bool,
}

pub struct Job {
    pub id: u64,
    pub spec: JobSpec,
    pub env_fp: u64,
    pub search_fp: u64,
    /// cancellation + deadline + progress control, shared with the search
    pub ctl: Arc<SearchCtl>,
    pub state: Arc<Mutex<JobState>>,
}

impl Job {
    /// `GET /v1/jobs/{id}` body: status + live `SearchLog` tail (without
    /// the per-layer probability payloads).
    pub fn status_json(&self) -> Json {
        let s = lock_recover(&self.state);
        let tail: Vec<EpisodeLog> = s.tail.iter().cloned().collect();
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("net", Json::Str(self.spec.net.clone())),
            ("status", Json::Str(s.status.as_str().to_string())),
            (
                "source",
                Json::Str(if s.from_archive { "archive" } else { "search" }.to_string()),
            ),
            ("episodes_run", Json::Num(s.episodes_run as f64)),
            ("episodes_total", Json::Num(self.spec.cfg.episodes as f64)),
            ("tail", episodes_json(&tail, false)),
        ];
        if let Some(e) = &s.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        Json::obj(fields)
    }

    /// One row of the `GET /v1/jobs` listing: the status fields without
    /// the episode tail — a page of summaries must stay O(limit), not
    /// O(limit × tail).
    pub fn summary_json(&self) -> Json {
        let s = lock_recover(&self.state);
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("net", Json::Str(self.spec.net.clone())),
            ("status", Json::Str(s.status.as_str().to_string())),
            (
                "source",
                Json::Str(if s.from_archive { "archive" } else { "search" }.to_string()),
            ),
            ("episodes_run", Json::Num(s.episodes_run as f64)),
            ("episodes_total", Json::Num(self.spec.cfg.episodes as f64)),
        ])
    }

    /// `GET /v1/jobs/{id}/result` body, once the job is done.
    pub fn result_json(&self) -> Option<Json> {
        let s = lock_recover(&self.state);
        let sol = s.solution.as_ref()?;
        let mut obj = match sol.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("Solution::to_json returns an object"),
        };
        obj.insert("id".to_string(), Json::Num(self.id as f64));
        obj.insert("net".to_string(), Json::Str(self.spec.net.clone()));
        obj.insert(
            "source".to_string(),
            Json::Str(if s.from_archive { "archive" } else { "search" }.to_string()),
        );
        Some(Json::Obj(obj))
    }
}

/// Execution backend for one job. `Send + Sync`: called concurrently from
/// every worker thread.
pub trait JobRunner: Send + Sync {
    /// Validate a submission (does the network exist? is the config sane?)
    /// and return its `(env, search)` fingerprints — the archive key.
    fn prepare(&self, spec: &JobSpec) -> Result<(u64, u64)>;

    /// Execute the search. Stream episodes through `job.ctl`'s progress
    /// hook, honor `job.ctl.check()`. Returns the solution plus the
    /// (bits, accuracy) memo export to persist for warm-starts —
    /// most-relevant-first, because the scheduler truncates it to
    /// `memo_persist` entries before archiving.
    fn run(&self, job: &Job) -> Result<(Solution, Vec<(Vec<u32>, f64)>)>;

    /// Backend fragment of `GET /v1/stats` (sessions, engine counters).
    fn stats(&self) -> Json {
        Json::Null
    }

    /// Is the execution backend healthy? The real runner reports the
    /// engine's watchdog health flag; stubs default to healthy. Feeds the
    /// circuit breaker and `GET /v1/health`.
    fn healthy(&self) -> bool {
        true
    }

    /// The network registry backing `POST /v1/networks`, if this runner
    /// has one. Defaults to `None` so stub runners keep compiling; the
    /// daemon falls back to a disabled registry (uploads get 503).
    fn registry(&self) -> Option<std::sync::Arc<crate::registry::Registry>> {
        None
    }

    /// The archive gained records out-of-band (a fleet pull-merge via
    /// `POST /v1/archive/merge`). The real runner re-warms live session
    /// memos from them; stubs default to a no-op.
    fn absorb_archive(&self, _archive: &Archive) {}
}

/// What a cancel request actually did (mapped to HTTP statuses by the
/// router — claiming `cancelled: true` for a job that already finished
/// would mislead clients into thinking its solution was not archived).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// the job will stop (queued: already marked; running: at its next
    /// episode boundary) — 200
    Accepted,
    /// the job already reached a terminal state — 409
    AlreadyFinished,
    /// no such job id — 404
    Unknown,
}

/// Why a submission was rejected (mapped to HTTP statuses by the router).
#[derive(Debug)]
pub enum SubmitError {
    /// daemon is shutting down — 503
    Draining,
    /// queue at capacity — 429, retry later
    Full,
    /// bad job spec — 400
    Invalid(anyhow::Error),
    /// backend degraded: circuit breaker open, engine unhealthy, or the
    /// job's session poisoned by quarantine — 503, retry later
    Unavailable(String),
}

struct Sched {
    queue: VecDeque<Arc<Job>>,
    jobs: BTreeMap<u64, Arc<Job>>,
    finished_order: VecDeque<u64>,
    running: usize,
    draining: bool,
    /// idempotency_key -> job id: a resubmission with a known key is
    /// answered with the original job instead of queueing a duplicate.
    /// Entries die with their job's table entry (see [`prune_finished`]).
    idem: HashMap<String, u64>,
}

/// Cumulative outcome counters (survive job-table pruning).
#[derive(Default)]
struct Totals {
    submitted: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    /// submissions answered instantly from the archive
    archived: AtomicU64,
    /// job attempts re-run after a transient failure
    retries: AtomicU64,
    /// times the circuit breaker opened
    breaker_trips: AtomicU64,
    /// submissions answered by idempotency-key dedupe (no new job)
    deduped: AtomicU64,
    /// incomplete jobs re-enqueued from the WAL at startup
    recovered: AtomicU64,
    /// torn / corrupt WAL lines skipped during replay
    wal_skipped: AtomicU64,
    /// WAL appends that failed (durability degraded, job unaffected)
    wal_append_failures: AtomicU64,
}

pub struct Scheduler {
    runner: Arc<dyn JobRunner>,
    pub archive: Arc<Archive>,
    queue_cap: usize,
    log_tail: usize,
    memo_persist: usize,
    /// per-job retry budget for transiently failing attempts (0 = off)
    job_retries: u32,
    /// consecutive-failure threshold opening the circuit breaker (0 = off)
    breaker_fails: u32,
    /// consecutive job failures across the scheduler (any success resets)
    consec_failures: AtomicU64,
    /// breaker state: while open, submissions shed with 503 as long as
    /// jobs are still in flight (an idle daemon always accepts one probe)
    breaker_open: AtomicBool,
    next_id: AtomicU64,
    totals: Totals,
    /// write-ahead job journal (`--wal`); `None` = journaling disabled.
    /// Attached via [`Scheduler::attach_wal`] before workers spawn.
    wal: Mutex<Option<Arc<Wal>>>,
    inner: Mutex<Sched>,
    cv: Condvar,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    pub fn new(runner: Arc<dyn JobRunner>, archive: Arc<Archive>, cfg: &ServeConfig)
               -> Arc<Scheduler> {
        Arc::new(Scheduler {
            runner,
            archive,
            queue_cap: cfg.queue_cap,
            log_tail: cfg.log_tail,
            memo_persist: cfg.memo_persist,
            job_retries: cfg.job_retries,
            breaker_fails: cfg.breaker_fails,
            consec_failures: AtomicU64::new(0),
            breaker_open: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            totals: Totals::default(),
            wal: Mutex::new(None),
            inner: Mutex::new(Sched {
                queue: VecDeque::new(),
                jobs: BTreeMap::new(),
                finished_order: VecDeque::new(),
                running: 0,
                draining: false,
                idem: HashMap::new(),
            }),
            cv: Condvar::new(),
            workers: Mutex::new(Vec::new()),
        })
    }

    pub fn spawn_workers(self: &Arc<Self>, n: usize) {
        let mut handles = lock_recover(&self.workers);
        for i in 0..n {
            let me = self.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("releq-worker-{i}"))
                    .spawn(move || me.worker_loop())
                    .expect("spawning worker thread"),
            );
        }
    }

    /// Construct the in-memory job record: state, progress hook feeding the
    /// live tail, cancellation control, deadline. Shared by [`submit`] and
    /// [`Scheduler::resubmit_recovered`].
    fn build_job(&self, id: u64, spec: JobSpec, env_fp: u64, search_fp: u64) -> Arc<Job> {
        let state = Arc::new(Mutex::new(JobState {
            status: JobStatus::Queued,
            error: None,
            episodes_run: 0,
            tail: VecDeque::new(),
            solution: None,
            from_archive: false,
        }));
        let tail_cap = self.log_tail;
        let st = state.clone();
        let mut ctl = SearchCtl::new().with_progress(move |ep| {
            let mut s = lock_recover(&st);
            s.episodes_run = s.episodes_run.max(ep.episode + 1);
            if tail_cap > 0 {
                if s.tail.len() == tail_cap {
                    s.tail.pop_front();
                }
                // the status endpoint serializes the tail without probs
                // (episodes_json(.., false)), so don't retain the
                // O(layers × actions) probability vectors it will drop
                let mut ep = ep.clone();
                ep.probs = Vec::new();
                s.tail.push_back(ep);
            }
        });
        if let Some(ms) = spec.deadline_ms {
            ctl = ctl.with_deadline(Duration::from_millis(ms));
        }
        Arc::new(Job { id, spec, env_fp, search_fp, ctl: Arc::new(ctl), state })
    }

    /// The attached journal, if any.
    fn wal(&self) -> Option<Arc<Wal>> {
        lock_recover(&self.wal).clone()
    }

    /// Best-effort journal append: a failed append degrades durability
    /// (counted, logged), it never fails the job.
    fn wal_append_submit(&self, id: u64, spec: &Json) {
        if let Some(w) = self.wal() {
            if let Err(e) = w.append_submit(id, spec) {
                self.totals.wal_append_failures.fetch_add(1, Ordering::Relaxed);
                eprintln!("[serve] WAL append failed: {e:#}");
            }
        }
    }

    fn wal_append_status(&self, id: u64, status: &str) {
        if let Some(w) = self.wal() {
            if let Err(e) = w.append_status(id, status) {
                self.totals.wal_append_failures.fetch_add(1, Ordering::Relaxed);
                eprintln!("[serve] WAL append failed: {e:#}");
            }
        }
    }

    /// Attach the write-ahead journal and re-enqueue everything it
    /// recovered, under the original ids. Called once at startup, before
    /// workers spawn. A recovered spec that no longer decodes or prepares
    /// (network since unregistered, say) is journaled `failed` rather than
    /// retried forever.
    pub fn attach_wal(&self, wal: Arc<Wal>, recovery: WalRecovery) {
        // fence the id counter above every id the journal ever issued, so
        // fresh submissions can never collide with recovered (or finished
        // and compacted-away) jobs
        self.next_id.fetch_max(recovery.max_id, Ordering::Relaxed);
        self.totals.wal_skipped.store(recovery.skipped, Ordering::Relaxed);
        *lock_recover(&self.wal) = Some(wal);
        for rec in &recovery.jobs {
            let outcome = job_from_json(&rec.spec)
                .and_then(|spec| self.resubmit_recovered(rec.id, spec));
            if let Err(e) = outcome {
                eprintln!("[serve] recovered job {} cannot be re-enqueued: {e:#}", rec.id);
                self.wal_append_status(rec.id, "failed");
            }
        }
    }

    /// Re-enqueue one WAL-recovered job under its original id. Bypasses
    /// the queue cap, breaker, and draining gates — the job was already
    /// accepted once — and appends no submit record (WAL compaction
    /// rewrote it during [`Wal::open`]).
    pub fn resubmit_recovered(&self, id: u64, spec: JobSpec) -> Result<Arc<Job>> {
        let (env_fp, search_fp) = self.runner.prepare(&spec)?;
        let job = self.build_job(id, spec, env_fp, search_fp);
        let mut g = lock_recover(&self.inner);
        if let Some(k) = &job.spec.idempotency_key {
            g.idem.insert(k.clone(), id);
        }
        self.totals.submitted.fetch_add(1, Ordering::Relaxed);
        self.totals.recovered.fetch_add(1, Ordering::Relaxed);
        if let Some(sol) = self.archive.lookup(&job.spec.net, env_fp, search_fp) {
            // a sibling fleet worker (or a pre-crash completion whose
            // terminal record got torn) already solved it
            {
                let mut s = lock_recover(&job.state);
                s.status = JobStatus::Done;
                s.episodes_run = sol.episodes_run;
                s.solution = Some(sol);
                s.from_archive = true;
            }
            self.totals.archived.fetch_add(1, Ordering::Relaxed);
            g.jobs.insert(id, job.clone());
            g.finished_order.push_back(id);
            Self::prune_finished(&mut g);
            drop(g);
            self.wal_append_status(id, "done");
            return Ok(job);
        }
        g.jobs.insert(id, job.clone());
        g.queue.push_back(job.clone());
        drop(g);
        self.cv.notify_one();
        Ok(job)
    }

    /// SIGTERM/SIGINT path, the journal-aware sibling of [`drain`]: stop
    /// accepting, abandon the queue (journaled queued jobs stay
    /// non-terminal, so the next start recovers them), and ask running
    /// searches to stop at their next episode boundary — each flushes a
    /// final checkpoint and is journaled `interrupted`, not `cancelled`.
    /// Blocks until the worker pool is quiet.
    pub fn interrupt(&self) {
        {
            let mut g = lock_recover(&self.inner);
            g.draining = true;
            g.queue.clear();
            for job in g.jobs.values() {
                if lock_recover(&job.state).status == JobStatus::Running {
                    job.ctl.cancel_for_shutdown();
                }
            }
        }
        self.cv.notify_all();
        self.drain();
    }

    /// Submit a job: validated, fingerprinted, then either answered from
    /// the archive (no queue slot, no accuracy evals), deduplicated on its
    /// idempotency key, or enqueued.
    ///
    /// Known limitation: two *identical* jobs (without idempotency keys)
    /// submitted before the first completes both run (the archive only
    /// answers after a completion). The duplicate's accuracy queries — the
    /// expensive part — all hit the shared session memo, so the waste is
    /// bounded to the agent-side episode work; job-level single-flight
    /// (parking the duplicate on the first job's completion) is
    /// deliberately deferred.
    pub fn submit(&self, spec: JobSpec) -> Result<Arc<Job>, SubmitError> {
        let (env_fp, search_fp) = self.runner.prepare(&spec).map_err(|e| {
            // a typed permanent fault from prepare (a quarantine-poisoned
            // session) is a backend condition, not a bad request: 503
            match e.downcast_ref::<FaultError>() {
                Some(FaultError::Permanent(_)) => SubmitError::Unavailable(format!("{e:#}")),
                _ => SubmitError::Invalid(e),
            }
        })?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let job = self.build_job(id, spec, env_fp, search_fp);

        // one authoritative gate: the draining check precedes the archive
        // lookup so a 503-rejected resubmission can't bump the persistent
        // hit counters, and precedes the enqueue so drain() can never miss
        // a submission. (Lock order inner -> archive/state is safe: no
        // path acquires them in the reverse order while holding either.)
        let mut g = lock_recover(&self.inner);
        if g.draining {
            return Err(SubmitError::Draining);
        }
        // idempotent resubmission: a key we've already accepted answers
        // with the ORIGINAL job — whatever state it is in — so a client
        // retrying a dropped response can never double-run a search
        if let Some(k) = &job.spec.idempotency_key {
            if let Some(prior) = g.idem.get(k).and_then(|pid| g.jobs.get(pid)).cloned() {
                self.totals.deduped.fetch_add(1, Ordering::Relaxed);
                return Ok(prior);
            }
        }
        // graceful degradation: while the breaker is open or the backend
        // reports unhealthy, shed new work — but only while jobs are still
        // in flight. An idle daemon always accepts (the natural half-open
        // probe: its success closes the breaker, and a completed execution
        // clears the engine health flag).
        let busy = g.running > 0 || !g.queue.is_empty();
        if busy {
            if self.breaker_open.load(Ordering::Relaxed) {
                return Err(SubmitError::Unavailable(format!(
                    "circuit breaker open after {} consecutive job failures",
                    self.consec_failures.load(Ordering::Relaxed)
                )));
            }
            if !self.runner.healthy() {
                return Err(SubmitError::Unavailable(
                    "execution backend unhealthy (watchdog tripped)".to_string(),
                ));
            }
        }

        // exact archive hit: the whole point of the archive — answered
        // without a queue slot, a session, or a single accuracy evaluation
        if let Some(sol) = self.archive.lookup(&job.spec.net, env_fp, search_fp) {
            {
                let mut s = lock_recover(&job.state);
                s.status = JobStatus::Done;
                s.episodes_run = sol.episodes_run;
                s.solution = Some(sol);
                s.from_archive = true;
            }
            // counted only once accepted: a 429/503 rejection must not
            // inflate `submitted` in /v1/stats
            self.totals.submitted.fetch_add(1, Ordering::Relaxed);
            self.totals.archived.fetch_add(1, Ordering::Relaxed);
            if let Some(k) = &job.spec.idempotency_key {
                g.idem.insert(k.clone(), id);
            }
            g.jobs.insert(id, job.clone());
            g.finished_order.push_back(id);
            Self::prune_finished(&mut g);
            drop(g);
            self.wal_append_submit(id, &job.spec.raw);
            self.wal_append_status(id, "done");
            return Ok(job);
        }

        if g.queue.len() >= self.queue_cap {
            return Err(SubmitError::Full);
        }
        self.totals.submitted.fetch_add(1, Ordering::Relaxed);
        if let Some(k) = &job.spec.idempotency_key {
            g.idem.insert(k.clone(), id);
        }
        g.jobs.insert(id, job.clone());
        g.queue.push_back(job.clone());
        drop(g);
        self.wal_append_submit(id, &job.spec.raw);
        self.cv.notify_one();
        Ok(job)
    }

    pub fn job(&self, id: u64) -> Option<Arc<Job>> {
        lock_recover(&self.inner).jobs.get(&id).cloned()
    }

    /// One page of retained jobs in id order. `cursor` is the last id of
    /// the previous page (exclusive); returns the page plus the next
    /// cursor (`None` when exhausted). Ids are monotonic, so the cursor is
    /// stable under concurrent submissions — new jobs only ever appear
    /// after it.
    pub fn jobs_page(&self, cursor: Option<u64>, limit: usize) -> (Vec<Arc<Job>>, Option<u64>) {
        let g = lock_recover(&self.inner);
        let start = match cursor {
            Some(c) => std::ops::Bound::Excluded(c),
            None => std::ops::Bound::Unbounded,
        };
        let mut out: Vec<Arc<Job>> = g
            .jobs
            .range((start, std::ops::Bound::Unbounded))
            .take(limit + 1)
            .map(|(_, j)| j.clone())
            .collect();
        let next = if out.len() > limit {
            out.truncate(limit);
            out.last().map(|j| j.id)
        } else {
            None
        };
        (out, next)
    }

    /// Cancel a job: a queued job flips to `Cancelled` immediately and is
    /// removed from the queue (its slot frees up right away — a cancelled
    /// job must not hold a `queue_cap` place or inflate `queue_depth`);
    /// a running one stops at its next episode boundary.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let Some(job) = self.job(id) else { return CancelOutcome::Unknown };
        let was_queued = {
            let mut s = lock_recover(&job.state);
            if s.status.is_terminal() {
                return CancelOutcome::AlreadyFinished;
            }
            job.ctl.cancel();
            if s.status == JobStatus::Queued {
                s.status = JobStatus::Cancelled;
                s.error = Some("cancelled while queued".to_string());
                self.totals.cancelled.fetch_add(1, Ordering::Relaxed);
                true
            } else {
                false
            }
        };
        if was_queued {
            let mut g = lock_recover(&self.inner);
            let before = g.queue.len();
            g.queue.retain(|j| j.id != id);
            // push to finished_order only if we actually removed it — when
            // a worker popped the job in the same instant, the worker's
            // loop records the finish, and a double push would burn a
            // second FINISHED_RETAIN slot and evict an older job early
            if g.queue.len() < before {
                g.finished_order.push_back(id);
                Self::prune_finished(&mut g);
            }
            drop(g);
            // a cancelled-while-queued job is terminal: journal it so a
            // restart does not resurrect work the client explicitly killed
            self.wal_append_status(id, "cancelled");
            // a drain() may be waiting on the queue emptying
            self.cv.notify_all();
        }
        CancelOutcome::Accepted
    }

    fn prune_finished(g: &mut Sched) {
        while g.finished_order.len() > FINISHED_RETAIN {
            if let Some(old) = g.finished_order.pop_front() {
                if let Some(j) = g.jobs.remove(&old) {
                    // the dedupe entry dies with the job it points at (if
                    // the key was reused by a newer job, leave that alone)
                    if let Some(k) = &j.spec.idempotency_key {
                        if g.idem.get(k) == Some(&old) {
                            g.idem.remove(k);
                        }
                    }
                }
            }
        }
    }

    fn worker_loop(self: Arc<Self>) {
        loop {
            let job = {
                let mut g = lock_recover(&self.inner);
                loop {
                    if let Some(j) = g.queue.pop_front() {
                        g.running += 1;
                        break j;
                    }
                    if g.draining {
                        return;
                    }
                    g = match self.cv.wait(g) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            };
            // a panic anywhere in the job path (runner, archive) must not
            // kill the worker with `running` stuck high — that would hang
            // drain()/shutdown forever and strand the job in "running"
            let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.execute(&job)
            }));
            if ran.is_err() {
                eprintln!("[serve] job {} panicked in the runner", job.id);
                // the state mutex is likely poisoned by the panic — recover
                // the guard (the state is a plain field record, valid
                // across any panic) instead of silently skipping the
                // failure bookkeeping
                let newly_failed = {
                    let mut s = lock_recover(&job.state);
                    if !s.status.is_terminal() {
                        s.status = JobStatus::Failed;
                        s.error = Some("job execution panicked".to_string());
                        self.totals.failed.fetch_add(1, Ordering::Relaxed);
                        self.note_failure();
                        true
                    } else {
                        false
                    }
                };
                if newly_failed {
                    self.wal_append_status(job.id, "failed");
                }
            }
            let mut g = lock_recover(&self.inner);
            g.running -= 1;
            g.finished_order.push_back(job.id);
            Self::prune_finished(&mut g);
            drop(g);
            // wake both idle workers and a drain() waiting on running == 0
            self.cv.notify_all();
        }
    }

    /// One job attempt failed for a non-cancellation reason: advance the
    /// consecutive-failure streak and open the breaker at the threshold.
    fn note_failure(&self) {
        let consec = self.consec_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if self.breaker_fails > 0
            && consec >= self.breaker_fails as u64
            && !self.breaker_open.swap(true, Ordering::Relaxed)
        {
            self.totals.breaker_trips.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "[serve] circuit breaker open: {consec} consecutive job failures \
                 (new submissions shed with 503 until a job completes)"
            );
        }
    }

    /// A job completed: clear the streak and close the breaker.
    fn note_success(&self) {
        self.consec_failures.store(0, Ordering::Relaxed);
        if self.breaker_open.swap(false, Ordering::Relaxed) {
            eprintln!("[serve] circuit breaker closed: job completed");
        }
    }

    /// Run the job with a bounded retry budget for transient failures.
    /// Cancellation and permanent failures surface immediately; a
    /// transient attempt backs off (exponential + jitter, same policy
    /// family as the engine's exec-level retries) and re-runs as long as
    /// budget remains and the job was not cancelled in between.
    fn run_with_retries(&self, job: &Arc<Job>) -> Result<(Solution, Vec<(Vec<u32>, f64)>)> {
        let policy = RetryPolicy { max_retries: self.job_retries, ..RetryPolicy::default() };
        let mut rng = Pcg32::new(policy.seed ^ job.id);
        let mut attempt = 0u32;
        loop {
            let err = match self.runner.run(job) {
                Ok(out) => return Ok(out),
                Err(e) => e,
            };
            let transient = err.downcast_ref::<Cancelled>().is_none()
                && classify(&err) == FaultClass::Transient;
            if !transient || attempt >= policy.max_retries || job.ctl.is_cancelled() {
                return Err(err);
            }
            let wait = policy.backoff(attempt, &mut rng);
            attempt += 1;
            self.totals.retries.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "[serve] job {} failed transiently (attempt {attempt}/{}); retrying in \
                 {wait:?}: {err:#}",
                job.id,
                policy.max_retries + 1
            );
            std::thread::sleep(wait);
        }
    }

    fn execute(&self, job: &Arc<Job>) {
        {
            let mut s = lock_recover(&job.state);
            if s.status.is_terminal() {
                return; // cancelled while queued
            }
            if job.ctl.is_cancelled() {
                // deadline elapsed in the queue
                s.status = JobStatus::Cancelled;
                s.error = Some("deadline exceeded while queued".to_string());
                self.totals.cancelled.fetch_add(1, Ordering::Relaxed);
                drop(s);
                self.wal_append_status(job.id, "cancelled");
                return;
            }
            s.status = JobStatus::Running;
        }
        self.wal_append_status(job.id, "running");
        match self.run_with_retries(job) {
            Ok((sol, mut memo)) => {
                {
                    let mut s = lock_recover(&job.state);
                    s.episodes_run = sol.episodes_run;
                    s.solution = Some(sol.clone());
                    s.status = JobStatus::Done;
                }
                self.totals.done.fetch_add(1, Ordering::Relaxed);
                self.note_success();
                self.wal_append_status(job.id, "done");
                memo.truncate(self.memo_persist);
                self.archive.insert(Record {
                    net: job.spec.net.clone(),
                    env_fp: job.env_fp,
                    search_fp: job.search_fp,
                    solution: sol,
                    memo,
                    hits: 0,
                });
                // persistence failure must not fail the job — the result
                // is still served from memory; the operator sees the log
                if let Err(e) = self.archive.save_throttled(SAVE_INTERVAL) {
                    eprintln!("[serve] archive save failed: {e:#}");
                }
            }
            Err(e) => {
                let wal_status;
                {
                    let mut s = lock_recover(&job.state);
                    if let Some(c) = e.downcast_ref::<Cancelled>() {
                        s.status = JobStatus::Cancelled;
                        s.error = Some(c.0.to_string());
                        self.totals.cancelled.fetch_add(1, Ordering::Relaxed);
                        // a process shutdown is no verdict on the job:
                        // journaled as `interrupted` (non-terminal), it is
                        // recovered and resumed on the next daemon start
                        wal_status = if c.0 == "shutdown" { "interrupted" } else { "cancelled" };
                    } else {
                        s.status = JobStatus::Failed;
                        s.error = Some(format!("{e:#}"));
                        self.totals.failed.fetch_add(1, Ordering::Relaxed);
                        self.note_failure();
                        wal_status = "failed";
                    }
                }
                self.wal_append_status(job.id, wal_status);
            }
        }
    }

    /// Graceful drain: stop accepting submissions, let the workers finish
    /// everything already accepted (queued AND running), then join them.
    /// Idempotent; blocks until the pool is quiet.
    pub fn drain(&self) {
        {
            let mut g = lock_recover(&self.inner);
            g.draining = true;
            self.cv.notify_all();
            while !g.queue.is_empty() || g.running > 0 {
                g = match self.cv.wait(g) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
        }
        let handles = std::mem::take(&mut *lock_recover(&self.workers));
        for h in handles {
            let _ = h.join();
        }
    }

    pub fn queue_depth(&self) -> usize {
        lock_recover(&self.inner).queue.len()
    }

    pub fn running(&self) -> usize {
        lock_recover(&self.inner).running
    }

    pub fn is_draining(&self) -> bool {
        lock_recover(&self.inner).draining
    }

    /// Is the circuit breaker currently shedding submissions?
    pub fn breaker_open(&self) -> bool {
        self.breaker_open.load(Ordering::Relaxed)
    }

    /// Does the execution backend report healthy?
    pub fn runner_healthy(&self) -> bool {
        self.runner.healthy()
    }

    /// `GET /v1/stats` scheduler fragment.
    pub fn stats_json(&self) -> Json {
        let (queue_depth, running, retained) = {
            let g = lock_recover(&self.inner);
            (g.queue.len(), g.running, g.jobs.len())
        };
        Json::obj(vec![
            ("queue_depth", Json::Num(queue_depth as f64)),
            ("running", Json::Num(running as f64)),
            ("retained_jobs", Json::Num(retained as f64)),
            ("submitted", Json::Num(self.totals.submitted.load(Ordering::Relaxed) as f64)),
            ("done", Json::Num(self.totals.done.load(Ordering::Relaxed) as f64)),
            ("failed", Json::Num(self.totals.failed.load(Ordering::Relaxed) as f64)),
            ("cancelled", Json::Num(self.totals.cancelled.load(Ordering::Relaxed) as f64)),
            ("archive_answers", Json::Num(self.totals.archived.load(Ordering::Relaxed) as f64)),
            ("retries", Json::Num(self.totals.retries.load(Ordering::Relaxed) as f64)),
            (
                "breaker_trips",
                Json::Num(self.totals.breaker_trips.load(Ordering::Relaxed) as f64),
            ),
            ("breaker_open", Json::Bool(self.breaker_open())),
            ("deduped", Json::Num(self.totals.deduped.load(Ordering::Relaxed) as f64)),
            ("wal", self.wal_stats_json()),
        ])
    }

    /// `/v1/stats` journal fragment: enabled flag, recovery and durability
    /// counters. The chaos smoke asserts on `recovered` after a kill -9.
    fn wal_stats_json(&self) -> Json {
        let mut fields = vec![("enabled", Json::Bool(self.wal().is_some()))];
        if let Some(w) = self.wal() {
            fields.push(("path", Json::Str(w.path().display().to_string())));
        }
        fields.extend([
            ("recovered", Json::Num(self.totals.recovered.load(Ordering::Relaxed) as f64)),
            (
                "skipped_records",
                Json::Num(self.totals.wal_skipped.load(Ordering::Relaxed) as f64),
            ),
            (
                "append_failures",
                Json::Num(self.totals.wal_append_failures.load(Ordering::Relaxed) as f64),
            ),
        ]);
        Json::obj(fields)
    }
}
