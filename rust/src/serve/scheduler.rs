//! Job scheduler: a bounded queue of quantization-search jobs multiplexed
//! over a fixed worker-thread pool, with per-job cooperative cancellation
//! (through [`SearchCtl`]), live log tails, instant archive answers for
//! exact resubmissions, and a graceful drain for shutdown.
//!
//! The execution backend is abstracted behind [`JobRunner`] so the queue /
//! backpressure / cancellation / drain machinery is testable without PJRT
//! artifacts (`rust/tests/serve_daemon.rs` drives it with a stub runner);
//! the real backend is `session::SessionRunner`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config::{JobSpec, ServeConfig};
use crate::coordinator::{Cancelled, SearchCtl};
use crate::metrics::{episodes_json, EpisodeLog};
use crate::util::json::Json;

use super::archive::{Archive, Record, Solution};

/// Finished jobs retained for status queries after completion. Without a
/// bound the job table is the daemon's second unbounded map (the first
/// being the accuracy memo, bounded in this same PR).
const FINISHED_RETAIN: usize = 256;

/// Minimum interval between per-completion archive saves (each save
/// rewrites the whole file — see [`Archive::save_throttled`]). The
/// shutdown drain persists unconditionally regardless.
const SAVE_INTERVAL: Duration = Duration::from_secs(5);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled)
    }
}

/// Mutable per-job state (behind the job's own mutex, not the scheduler
/// lock — status polls never contend with queue operations).
pub struct JobState {
    pub status: JobStatus,
    pub error: Option<String>,
    pub episodes_run: usize,
    /// bounded live tail of finished episodes (`GET /v1/jobs/{id}`)
    pub tail: VecDeque<EpisodeLog>,
    pub solution: Option<Solution>,
    /// answered from the archive without running a search
    pub from_archive: bool,
}

pub struct Job {
    pub id: u64,
    pub spec: JobSpec,
    pub env_fp: u64,
    pub search_fp: u64,
    /// cancellation + deadline + progress control, shared with the search
    pub ctl: Arc<SearchCtl>,
    pub state: Arc<Mutex<JobState>>,
}

impl Job {
    /// `GET /v1/jobs/{id}` body: status + live `SearchLog` tail (without
    /// the per-layer probability payloads).
    pub fn status_json(&self) -> Json {
        let s = self.state.lock().unwrap();
        let tail: Vec<EpisodeLog> = s.tail.iter().cloned().collect();
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("net", Json::Str(self.spec.net.clone())),
            ("status", Json::Str(s.status.as_str().to_string())),
            (
                "source",
                Json::Str(if s.from_archive { "archive" } else { "search" }.to_string()),
            ),
            ("episodes_run", Json::Num(s.episodes_run as f64)),
            ("episodes_total", Json::Num(self.spec.cfg.episodes as f64)),
            ("tail", episodes_json(&tail, false)),
        ];
        if let Some(e) = &s.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        Json::obj(fields)
    }

    /// `GET /v1/jobs/{id}/result` body, once the job is done.
    pub fn result_json(&self) -> Option<Json> {
        let s = self.state.lock().unwrap();
        let sol = s.solution.as_ref()?;
        let mut obj = match sol.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("Solution::to_json returns an object"),
        };
        obj.insert("id".to_string(), Json::Num(self.id as f64));
        obj.insert("net".to_string(), Json::Str(self.spec.net.clone()));
        obj.insert(
            "source".to_string(),
            Json::Str(if s.from_archive { "archive" } else { "search" }.to_string()),
        );
        Some(Json::Obj(obj))
    }
}

/// Execution backend for one job. `Send + Sync`: called concurrently from
/// every worker thread.
pub trait JobRunner: Send + Sync {
    /// Validate a submission (does the network exist? is the config sane?)
    /// and return its `(env, search)` fingerprints — the archive key.
    fn prepare(&self, spec: &JobSpec) -> Result<(u64, u64)>;

    /// Execute the search. Stream episodes through `job.ctl`'s progress
    /// hook, honor `job.ctl.check()`. Returns the solution plus the
    /// (bits, accuracy) memo export to persist for warm-starts —
    /// most-relevant-first, because the scheduler truncates it to
    /// `memo_persist` entries before archiving.
    fn run(&self, job: &Job) -> Result<(Solution, Vec<(Vec<u32>, f64)>)>;

    /// Backend fragment of `GET /v1/stats` (sessions, engine counters).
    fn stats(&self) -> Json {
        Json::Null
    }
}

/// What a cancel request actually did (mapped to HTTP statuses by the
/// router — claiming `cancelled: true` for a job that already finished
/// would mislead clients into thinking its solution was not archived).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// the job will stop (queued: already marked; running: at its next
    /// episode boundary) — 200
    Accepted,
    /// the job already reached a terminal state — 409
    AlreadyFinished,
    /// no such job id — 404
    Unknown,
}

/// Why a submission was rejected (mapped to HTTP statuses by the router).
#[derive(Debug)]
pub enum SubmitError {
    /// daemon is shutting down — 503
    Draining,
    /// queue at capacity — 429, retry later
    Full,
    /// bad job spec — 400
    Invalid(anyhow::Error),
}

struct Sched {
    queue: VecDeque<Arc<Job>>,
    jobs: BTreeMap<u64, Arc<Job>>,
    finished_order: VecDeque<u64>,
    running: usize,
    draining: bool,
}

/// Cumulative outcome counters (survive job-table pruning).
#[derive(Default)]
struct Totals {
    submitted: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    /// submissions answered instantly from the archive
    archived: AtomicU64,
}

pub struct Scheduler {
    runner: Arc<dyn JobRunner>,
    pub archive: Arc<Archive>,
    queue_cap: usize,
    log_tail: usize,
    memo_persist: usize,
    next_id: AtomicU64,
    totals: Totals,
    inner: Mutex<Sched>,
    cv: Condvar,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    pub fn new(runner: Arc<dyn JobRunner>, archive: Arc<Archive>, cfg: &ServeConfig)
               -> Arc<Scheduler> {
        Arc::new(Scheduler {
            runner,
            archive,
            queue_cap: cfg.queue_cap,
            log_tail: cfg.log_tail,
            memo_persist: cfg.memo_persist,
            next_id: AtomicU64::new(0),
            totals: Totals::default(),
            inner: Mutex::new(Sched {
                queue: VecDeque::new(),
                jobs: BTreeMap::new(),
                finished_order: VecDeque::new(),
                running: 0,
                draining: false,
            }),
            cv: Condvar::new(),
            workers: Mutex::new(Vec::new()),
        })
    }

    pub fn spawn_workers(self: &Arc<Self>, n: usize) {
        let mut handles = self.workers.lock().unwrap();
        for i in 0..n {
            let me = self.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("releq-worker-{i}"))
                    .spawn(move || me.worker_loop())
                    .expect("spawning worker thread"),
            );
        }
    }

    /// Submit a job: validated, fingerprinted, then either answered from
    /// the archive (no queue slot, no accuracy evals) or enqueued.
    ///
    /// Known limitation: two *identical* jobs submitted before the first
    /// completes both run (the archive only answers after a completion).
    /// The duplicate's accuracy queries — the expensive part — all hit the
    /// shared session memo, so the waste is bounded to the agent-side
    /// episode work; job-level single-flight (parking the duplicate on the
    /// first job's completion) is deliberately deferred.
    pub fn submit(&self, spec: JobSpec) -> Result<Arc<Job>, SubmitError> {
        let (env_fp, search_fp) = self.runner.prepare(&spec).map_err(SubmitError::Invalid)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;

        let state = Arc::new(Mutex::new(JobState {
            status: JobStatus::Queued,
            error: None,
            episodes_run: 0,
            tail: VecDeque::new(),
            solution: None,
            from_archive: false,
        }));
        let tail_cap = self.log_tail;
        let st = state.clone();
        let mut ctl = SearchCtl::new().with_progress(move |ep| {
            let mut s = st.lock().unwrap();
            s.episodes_run = s.episodes_run.max(ep.episode + 1);
            if tail_cap > 0 {
                if s.tail.len() == tail_cap {
                    s.tail.pop_front();
                }
                // the status endpoint serializes the tail without probs
                // (episodes_json(.., false)), so don't retain the
                // O(layers × actions) probability vectors it will drop
                let mut ep = ep.clone();
                ep.probs = Vec::new();
                s.tail.push_back(ep);
            }
        });
        if let Some(ms) = spec.deadline_ms {
            ctl = ctl.with_deadline(Duration::from_millis(ms));
        }
        let job = Arc::new(Job { id, spec, env_fp, search_fp, ctl: Arc::new(ctl), state });

        // one authoritative gate: the draining check precedes the archive
        // lookup so a 503-rejected resubmission can't bump the persistent
        // hit counters, and precedes the enqueue so drain() can never miss
        // a submission. (Lock order inner -> archive/state is safe: no
        // path acquires them in the reverse order while holding either.)
        let mut g = self.inner.lock().unwrap();
        if g.draining {
            return Err(SubmitError::Draining);
        }

        // exact archive hit: the whole point of the archive — answered
        // without a queue slot, a session, or a single accuracy evaluation
        if let Some(sol) = self.archive.lookup(&job.spec.net, env_fp, search_fp) {
            {
                let mut s = job.state.lock().unwrap();
                s.status = JobStatus::Done;
                s.episodes_run = sol.episodes_run;
                s.solution = Some(sol);
                s.from_archive = true;
            }
            // counted only once accepted: a 429/503 rejection must not
            // inflate `submitted` in /v1/stats
            self.totals.submitted.fetch_add(1, Ordering::Relaxed);
            self.totals.archived.fetch_add(1, Ordering::Relaxed);
            g.jobs.insert(id, job.clone());
            g.finished_order.push_back(id);
            Self::prune_finished(&mut g);
            return Ok(job);
        }

        if g.queue.len() >= self.queue_cap {
            return Err(SubmitError::Full);
        }
        self.totals.submitted.fetch_add(1, Ordering::Relaxed);
        g.jobs.insert(id, job.clone());
        g.queue.push_back(job.clone());
        drop(g);
        self.cv.notify_one();
        Ok(job)
    }

    pub fn job(&self, id: u64) -> Option<Arc<Job>> {
        self.inner.lock().unwrap().jobs.get(&id).cloned()
    }

    /// Cancel a job: a queued job flips to `Cancelled` immediately and is
    /// removed from the queue (its slot frees up right away — a cancelled
    /// job must not hold a `queue_cap` place or inflate `queue_depth`);
    /// a running one stops at its next episode boundary.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let Some(job) = self.job(id) else { return CancelOutcome::Unknown };
        let was_queued = {
            let mut s = job.state.lock().unwrap();
            if s.status.is_terminal() {
                return CancelOutcome::AlreadyFinished;
            }
            job.ctl.cancel();
            if s.status == JobStatus::Queued {
                s.status = JobStatus::Cancelled;
                s.error = Some("cancelled while queued".to_string());
                self.totals.cancelled.fetch_add(1, Ordering::Relaxed);
                true
            } else {
                false
            }
        };
        if was_queued {
            let mut g = self.inner.lock().unwrap();
            let before = g.queue.len();
            g.queue.retain(|j| j.id != id);
            // push to finished_order only if we actually removed it — when
            // a worker popped the job in the same instant, the worker's
            // loop records the finish, and a double push would burn a
            // second FINISHED_RETAIN slot and evict an older job early
            if g.queue.len() < before {
                g.finished_order.push_back(id);
                Self::prune_finished(&mut g);
            }
            drop(g);
            // a drain() may be waiting on the queue emptying
            self.cv.notify_all();
        }
        CancelOutcome::Accepted
    }

    fn prune_finished(g: &mut Sched) {
        while g.finished_order.len() > FINISHED_RETAIN {
            if let Some(old) = g.finished_order.pop_front() {
                g.jobs.remove(&old);
            }
        }
    }

    fn worker_loop(self: Arc<Self>) {
        loop {
            let job = {
                let mut g = self.inner.lock().unwrap();
                loop {
                    if let Some(j) = g.queue.pop_front() {
                        g.running += 1;
                        break j;
                    }
                    if g.draining {
                        return;
                    }
                    g = self.cv.wait(g).unwrap();
                }
            };
            // a panic anywhere in the job path (runner, archive) must not
            // kill the worker with `running` stuck high — that would hang
            // drain()/shutdown forever and strand the job in "running"
            let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.execute(&job)
            }));
            if ran.is_err() {
                eprintln!("[serve] job {} panicked in the runner", job.id);
                // the state mutex may be poisoned by the panic; best-effort
                if let Ok(mut s) = job.state.lock() {
                    if !s.status.is_terminal() {
                        s.status = JobStatus::Failed;
                        s.error = Some("job execution panicked".to_string());
                        self.totals.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            let mut g = self.inner.lock().unwrap();
            g.running -= 1;
            g.finished_order.push_back(job.id);
            Self::prune_finished(&mut g);
            drop(g);
            // wake both idle workers and a drain() waiting on running == 0
            self.cv.notify_all();
        }
    }

    fn execute(&self, job: &Arc<Job>) {
        {
            let mut s = job.state.lock().unwrap();
            if s.status.is_terminal() {
                return; // cancelled while queued
            }
            if job.ctl.is_cancelled() {
                // deadline elapsed in the queue
                s.status = JobStatus::Cancelled;
                s.error = Some("deadline exceeded while queued".to_string());
                self.totals.cancelled.fetch_add(1, Ordering::Relaxed);
                return;
            }
            s.status = JobStatus::Running;
        }
        match self.runner.run(job) {
            Ok((sol, mut memo)) => {
                {
                    let mut s = job.state.lock().unwrap();
                    s.episodes_run = sol.episodes_run;
                    s.solution = Some(sol.clone());
                    s.status = JobStatus::Done;
                }
                self.totals.done.fetch_add(1, Ordering::Relaxed);
                memo.truncate(self.memo_persist);
                self.archive.insert(Record {
                    net: job.spec.net.clone(),
                    env_fp: job.env_fp,
                    search_fp: job.search_fp,
                    solution: sol,
                    memo,
                    hits: 0,
                });
                // persistence failure must not fail the job — the result
                // is still served from memory; the operator sees the log
                if let Err(e) = self.archive.save_throttled(SAVE_INTERVAL) {
                    eprintln!("[serve] archive save failed: {e:#}");
                }
            }
            Err(e) => {
                let mut s = job.state.lock().unwrap();
                if let Some(c) = e.downcast_ref::<Cancelled>() {
                    s.status = JobStatus::Cancelled;
                    s.error = Some(c.0.to_string());
                    self.totals.cancelled.fetch_add(1, Ordering::Relaxed);
                } else {
                    s.status = JobStatus::Failed;
                    s.error = Some(format!("{e:#}"));
                    self.totals.failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Graceful drain: stop accepting submissions, let the workers finish
    /// everything already accepted (queued AND running), then join them.
    /// Idempotent; blocks until the pool is quiet.
    pub fn drain(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            g.draining = true;
            self.cv.notify_all();
            while !g.queue.is_empty() || g.running > 0 {
                g = self.cv.wait(g).unwrap();
            }
        }
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_draining(&self) -> bool {
        self.inner.lock().unwrap().draining
    }

    /// `GET /v1/stats` scheduler fragment.
    pub fn stats_json(&self) -> Json {
        let (queue_depth, running, retained) = {
            let g = self.inner.lock().unwrap();
            (g.queue.len(), g.running, g.jobs.len())
        };
        Json::obj(vec![
            ("queue_depth", Json::Num(queue_depth as f64)),
            ("running", Json::Num(running as f64)),
            ("retained_jobs", Json::Num(retained as f64)),
            ("submitted", Json::Num(self.totals.submitted.load(Ordering::Relaxed) as f64)),
            ("done", Json::Num(self.totals.done.load(Ordering::Relaxed) as f64)),
            ("failed", Json::Num(self.totals.failed.load(Ordering::Relaxed) as f64)),
            ("cancelled", Json::Num(self.totals.cancelled.load(Ordering::Relaxed) as f64)),
            ("archive_answers", Json::Num(self.totals.archived.load(Ordering::Relaxed) as f64)),
        ])
    }
}
