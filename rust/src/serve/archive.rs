//! Persistent solution archive: every completed quantization policy, keyed
//! by network + config fingerprint, persisted as one JSON file with
//! atomic write-rename so a crash mid-save never corrupts prior results.
//!
//! Two cache levels ride on the archive:
//!
//! * **exact hits** — a resubmitted job whose (network, env fingerprint,
//!   search fingerprint) triple matches a stored record is answered
//!   instantly, with zero accuracy evaluations;
//! * **warm starts** — a *near*-duplicate job (same network and env
//!   fingerprint, different search knobs) pretrains through the session
//!   cache but seeds its [`crate::parallel::AccMemo`] with the stored
//!   (bits, accuracy) pairs of every matching record. Validity rests on
//!   PR 2's purity invariant: `EnvCore::accuracy` is a pure function of
//!   (env config, bits), so an accuracy computed under the same env
//!   fingerprint is the accuracy, no matter which process computed it.
//!
//! Fingerprints are FNV-1a over the config fields ([`crate::util::fnv`] —
//! not `DefaultHasher`, whose output is allowed to change between Rust
//! releases; archives outlive compiler upgrades).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config;
use crate::coordinator::{EnvConfig, SearchConfig};
use crate::util::fnv::Fnv;
use crate::util::json::Json;

/// Bound on retained records — the archive must not be the daemon's one
/// remaining unbounded map (each distinct job config is a fresh record
/// under multi-tenant traffic). At the cap, the least-hit records are
/// evicted first (ties by key, deterministic): a record that keeps
/// answering resubmissions is exactly the one worth keeping, and the cap
/// also bounds every full-file save at O(ARCHIVE_CAP).
const ARCHIVE_CAP: usize = 4096;

/// Stamped into `archive.json` as a root-level `schema_version` key (record
/// keys always contain `:`, so the name can never collide with one).
/// Versionless files predate PR 8 and load unchanged — the stamp appears on
/// their next save (forward migration). A file stamped NEWER than this
/// constant is refused: its records may rely on semantics this build does
/// not implement, and "silently reinterpret" is exactly what the checksum
/// machinery exists to prevent.
pub const ARCHIVE_SCHEMA_VERSION: u32 = 1;

/// Fingerprint of everything that determines an accuracy value: the
/// network, the quantization ceiling, and the env config. Jobs sharing
/// this share a pretrained session core and may exchange memo entries.
pub fn env_fingerprint(net: &str, bits_max: u32, cfg: &EnvConfig) -> u64 {
    let mut h = Fnv::new();
    h.write_str(net)
        .write_u64(bits_max as u64)
        .write_u64(cfg.pretrain_steps as u64)
        .write_u64(cfg.retrain_steps as u64)
        .write_u64(cfg.long_retrain_steps as u64)
        .write_f64(cfg.lr as f64)
        .write_u64(cfg.train_size as u64)
        .write_u64(cfg.seed);
    // memo_cap and eval_batch are deliberately excluded: one bounds the
    // cache, the other shapes execution batches — neither changes any
    // accuracy value (batched lanes are bit-identical to the scalar path;
    // rust/tests/eval_batch_parity.rs), so jobs differing only in those
    // knobs share a session and an archive key.
    h.finish()
}

/// Fingerprint of the full search outcome determinants: env fingerprint
/// plus every agent/reward/rollout knob. Two jobs sharing this produce the
/// same solution, so the second is answered from the archive.
pub fn search_fingerprint(net: &str, bits_max: u32, cfg: &SearchConfig) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(env_fingerprint(net, bits_max, &cfg.env))
        .write_u64(cfg.episodes as u64)
        .write_f64(cfg.ppo.clip_eps as f64)
        .write_f64(cfg.ppo.ent_coef as f64)
        .write_f64(cfg.ppo.lr as f64)
        .write_u64(cfg.ppo.epochs as u64)
        .write_f64(cfg.ppo.gamma)
        .write_f64(cfg.ppo.lam)
        .write_u64(cfg.ppo.episodes_per_update as u64)
        .write_str(&format!("{:?}", cfg.reward.kind))
        .write_f64(cfg.reward.a)
        .write_f64(cfg.reward.b)
        .write_f64(cfg.reward.th)
        .write_str(&format!("{:?}", cfg.agent_kind))
        .write_str(&format!("{:?}", cfg.action_space))
        // rollout mode + lanes are included: batched vs serial agree only
        // to float-rounding level (see coordinator::rollout), so they are
        // distinct archive keys rather than pretending bit-equality
        .write_str(&format!("{:?}", cfg.rollout))
        .write_u64(cfg.lanes as u64)
        .write_u64(cfg.eval_every_step as u64)
        .write_u64(cfg.min_bits as u64)
        .write_u64(cfg.seed)
        .write_u64(cfg.patience as u64);
    h.finish()
}

/// A finished quantization policy — the archive payload and the job-result
/// wire shape (`GET /v1/jobs/{id}/result`).
#[derive(Debug, Clone)]
pub struct Solution {
    pub bits: Vec<u32>,
    pub avg_bits: f64,
    pub acc_fullp: f64,
    pub acc_final: f64,
    pub acc_loss_pct: f64,
    pub state_q: f64,
    /// best per-episode reward observed during the search
    pub reward: f64,
    pub episodes_run: usize,
    /// Pareto frontier over the search's episode history:
    /// (state_q, state_acc, bits), sorted by increasing state_q
    pub pareto: Vec<(f64, f64, Vec<u32>)>,
}

impl Solution {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits", Json::arr_u32(&self.bits)),
            ("avg_bits", Json::Num(self.avg_bits)),
            ("acc_fullp", Json::Num(self.acc_fullp)),
            ("acc_final", Json::Num(self.acc_final)),
            ("acc_loss_pct", Json::Num(self.acc_loss_pct)),
            ("state_q", Json::Num(self.state_q)),
            ("reward", Json::Num(self.reward)),
            ("episodes_run", Json::Num(self.episodes_run as f64)),
            (
                "pareto",
                Json::Arr(
                    self.pareto
                        .iter()
                        .map(|(q, a, b)| {
                            Json::obj(vec![
                                ("state_q", Json::Num(*q)),
                                ("state_acc", Json::Num(*a)),
                                ("bits", Json::arr_u32(b)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Solution> {
        let bits = config::bits_from_json(j.req("bits")).context("solution bits")?;
        let pareto = j
            .req("pareto")
            .as_arr()
            .context("solution pareto")?
            .iter()
            .map(|p| {
                Ok((
                    p.get("state_q").and_then(Json::as_f64).context("pareto state_q")?,
                    p.get("state_acc").and_then(Json::as_f64).context("pareto state_acc")?,
                    config::bits_from_json(p.req("bits")).context("pareto bits")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let f = |k: &str| j.get(k).and_then(Json::as_f64).with_context(|| format!("solution `{k}`"));
        Ok(Solution {
            bits,
            avg_bits: f("avg_bits")?,
            acc_fullp: f("acc_fullp")?,
            acc_final: f("acc_final")?,
            acc_loss_pct: f("acc_loss_pct")?,
            state_q: f("state_q")?,
            reward: f("reward")?,
            episodes_run: f("episodes_run")? as usize,
            pareto,
        })
    }
}

/// One archived policy: the solution plus its keys, a bounded snapshot of
/// the accuracy memo for warm-starts, and a served-hit counter.
#[derive(Debug, Clone)]
pub struct Record {
    pub net: String,
    pub env_fp: u64,
    pub search_fp: u64,
    pub solution: Solution,
    /// (bits, accuracy) pairs exported from the session memo at completion
    pub memo: Vec<(Vec<u32>, f64)>,
    /// times this record answered a resubmission
    pub hits: u64,
}

impl Record {
    /// A record is archivable only if every numeric field is finite: the
    /// serializer emits non-finite values as `null` (to keep documents
    /// parseable), which `from_json` would then reject at the next
    /// `Archive::open` — one diverged search must not brick the daemon's
    /// restarts or poison warm-starts.
    fn is_finite(&self) -> bool {
        let s = &self.solution;
        [s.avg_bits, s.acc_fullp, s.acc_final, s.acc_loss_pct, s.state_q, s.reward]
            .iter()
            .all(|v| v.is_finite())
            && s.pareto.iter().all(|(q, a, _)| q.is_finite() && a.is_finite())
            && self.memo.iter().all(|(_, a)| a.is_finite())
    }

    /// FNV-1a digest over the payload fields — everything except the
    /// mutable `hits` bookkeeping (and the digest itself), so a lookup
    /// bumping a record's hit counter does not churn its checksum.
    /// Floats fold via `to_bits`, which is safe across the JSON round-trip
    /// because the serializer emits shortest-round-trip representations;
    /// the one bit pattern that does NOT survive (`-0.0` dumps as `0`) is
    /// canonicalized before hashing.
    fn checksum(&self) -> u64 {
        // IEEE: -0.0 + 0.0 == +0.0, every other value is unchanged
        let canon = |x: f64| x + 0.0;
        let s = &self.solution;
        let mut h = Fnv::new();
        h.write_str(&self.net)
            .write_u64(self.env_fp)
            .write_u64(self.search_fp)
            .write_u64(s.bits.len() as u64)
            .write_u32_words(&s.bits)
            .write_f64(canon(s.avg_bits))
            .write_f64(canon(s.acc_fullp))
            .write_f64(canon(s.acc_final))
            .write_f64(canon(s.acc_loss_pct))
            .write_f64(canon(s.state_q))
            .write_f64(canon(s.reward))
            .write_u64(s.episodes_run as u64)
            .write_u64(s.pareto.len() as u64);
        for (q, a, b) in &s.pareto {
            h.write_f64(canon(*q))
                .write_f64(canon(*a))
                .write_u64(b.len() as u64)
                .write_u32_words(b);
        }
        h.write_u64(self.memo.len() as u64);
        for (b, a) in &self.memo {
            h.write_u64(b.len() as u64).write_u32_words(b).write_f64(canon(*a));
        }
        h.finish()
    }

    /// Wire/disk form of the record — public because fleet replication
    /// ships records between processes over `GET /v1/archive` /
    /// `POST /v1/archive/merge`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("net", Json::Str(self.net.clone())),
            ("env_fp", Json::Str(format!("{:016x}", self.env_fp))),
            ("search_fp", Json::Str(format!("{:016x}", self.search_fp))),
            ("solution", self.solution.to_json()),
            (
                "memo",
                Json::Arr(
                    self.memo
                        .iter()
                        .map(|(b, a)| Json::Arr(vec![Json::arr_u32(b), Json::Num(*a)]))
                        .collect(),
                ),
            ),
            ("hits", Json::Num(self.hits as f64)),
            ("checksum", Json::Str(format!("{:016x}", self.checksum()))),
        ])
    }

    /// Decode (and checksum-verify) one record — the counterpart of
    /// [`Record::to_json`], shared by disk loads and fleet merges.
    pub fn from_json(j: &Json) -> Result<Record> {
        let fp = |k: &str| -> Result<u64> {
            let s = j.get(k).and_then(Json::as_str).with_context(|| format!("record `{k}`"))?;
            u64::from_str_radix(s, 16).with_context(|| format!("record `{k}` = `{s}`"))
        };
        let memo = j
            .req("memo")
            .as_arr()
            .context("record memo")?
            .iter()
            .map(|e| {
                let pair = e.as_arr().filter(|a| a.len() == 2).context("memo pair")?;
                Ok((
                    config::bits_from_json(&pair[0]).context("memo bits")?,
                    pair[1].as_f64().context("memo accuracy")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let rec = Record {
            net: j.get("net").and_then(Json::as_str).context("record net")?.to_string(),
            env_fp: fp("env_fp")?,
            search_fp: fp("search_fp")?,
            solution: Solution::from_json(j.req("solution")).context("record solution")?,
            memo,
            hits: j.get("hits").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        };
        // a record without a checksum predates PR 6 — accepted as-is; a
        // record WITH one must verify, or a flipped bit in a stored
        // accuracy would silently poison warm-started memos
        if let Some(s) = j.get("checksum").and_then(Json::as_str) {
            let want =
                u64::from_str_radix(s, 16).with_context(|| format!("record checksum `{s}`"))?;
            let got = rec.checksum();
            anyhow::ensure!(
                got == want,
                "record checksum mismatch (stored {want:016x}, computed {got:016x})"
            );
        }
        Ok(rec)
    }
}

/// What [`Archive::merge_record`] did with one replicated record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeOutcome {
    /// key was absent locally: record adopted
    Added,
    /// key present, remote copy had more hits: local copy replaced
    Raised,
    /// key present with >= hits locally: merge was a no-op
    Unchanged,
    /// record rejected (non-finite payload)
    Skipped,
}

/// Aggregate outcome of one [`Archive::merge_json`] call.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MergeStats {
    pub added: usize,
    pub raised: usize,
    pub unchanged: usize,
    /// records dropped for failing decode, checksum, or finiteness
    pub skipped: usize,
}

impl MergeStats {
    /// Did the merge change this archive at all?
    pub fn changed(&self) -> bool {
        self.added + self.raised > 0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("added", Json::Num(self.added as f64)),
            ("raised", Json::Num(self.raised as f64)),
            ("unchanged", Json::Num(self.unchanged as f64)),
            ("skipped", Json::Num(self.skipped as f64)),
        ])
    }
}

/// The archive: an in-memory map mirrored to `archive.json`.
///
/// Concurrency: one `Mutex` over the map — archive operations are rare
/// (job completion, submission lookup) next to everything else the daemon
/// does. Persistence is explicit ([`Archive::save`]) and atomic: serialize
/// to `<path>.tmp`, then `rename` over the target, so readers of the path
/// always see a complete document.
pub struct Archive {
    path: PathBuf,
    records: Mutex<BTreeMap<String, Record>>,
    /// serializes save(): two workers finishing jobs near-simultaneously
    /// must not interleave writes to the shared tmp file (the rename is
    /// atomic, the write before it is not)
    save_lock: Mutex<()>,
    /// completion time of the last save, for [`Archive::save_throttled`]
    last_save: Mutex<Option<Instant>>,
    hits: AtomicU64,
    /// records dropped at open for failing decode or checksum validation
    skipped: AtomicU64,
}

impl Archive {
    /// The composite key of a record.
    pub fn key(net: &str, env_fp: u64, search_fp: u64) -> String {
        format!("{net}:{env_fp:016x}:{search_fp:016x}")
    }

    /// Open (or start empty at) `path`. A missing file is an empty archive;
    /// a malformed file is an error — silently discarding accumulated
    /// solutions would be worse than refusing to start. An individual
    /// record that fails to decode or fails its checksum is skipped (and
    /// counted in [`Archive::skipped`], surfaced through `/v1/stats`): one
    /// flipped bit must cost one record, not brick the daemon's restart or
    /// wipe everything the other records accumulated.
    pub fn open(path: &Path) -> Result<Archive> {
        let mut records = BTreeMap::new();
        let mut skipped = 0u64;
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading archive {}", path.display()))?;
            let j = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("archive {}: {e}", path.display()))?;
            let schema = j
                .get("schema_version")
                .and_then(Json::as_usize)
                .unwrap_or(0) as u32; // versionless = legacy, loads as-is
            anyhow::ensure!(
                schema <= ARCHIVE_SCHEMA_VERSION,
                "archive {} has schema_version {schema}; this build reads <= {}",
                path.display(),
                ARCHIVE_SCHEMA_VERSION
            );
            for (k, v) in j.as_obj().context("archive root must be an object")? {
                if k == "schema_version" {
                    continue;
                }
                match Record::from_json(v) {
                    Ok(rec) => {
                        records.insert(k.clone(), rec);
                    }
                    Err(e) => {
                        skipped += 1;
                        eprintln!(
                            "[serve] archive {}: skipping corrupted record `{k}`: {e:#}",
                            path.display()
                        );
                    }
                }
            }
        }
        Ok(Archive {
            path: path.to_path_buf(),
            records: Mutex::new(records),
            save_lock: Mutex::new(()),
            last_save: Mutex::new(None),
            hits: AtomicU64::new(0),
            skipped: AtomicU64::new(skipped),
        })
    }

    /// Exact-hit lookup; bumps the record's and the archive's hit counters.
    pub fn lookup(&self, net: &str, env_fp: u64, search_fp: u64) -> Option<Solution> {
        let mut m = self.records.lock().unwrap();
        let rec = m.get_mut(&Self::key(net, env_fp, search_fp))?;
        rec.hits += 1;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(rec.solution.clone())
    }

    /// Insert (or replace) a completed record. A replacement inherits the
    /// replaced record's accumulated hit count — two concurrent identical
    /// jobs race to insert the same key, and the loser's write must not
    /// zero the counter resubmissions have been bumping in between.
    /// Enforces [`ARCHIVE_CAP`] by evicting least-hit records (never the
    /// one just inserted).
    pub fn insert(&self, mut rec: Record) {
        let key = Self::key(&rec.net, rec.env_fp, rec.search_fp);
        if !rec.is_finite() {
            // the job is still served live from memory; it just isn't
            // worth persisting a diverged policy
            eprintln!("[serve] not archiving `{key}`: non-finite values (diverged search)");
            return;
        }
        let mut m = self.records.lock().unwrap();
        if let Some(old) = m.get(&key) {
            rec.hits += old.hits;
        }
        m.insert(key.clone(), rec);
        Self::evict_over_cap(&mut m, &key);
    }

    /// Least-hit eviction down to [`ARCHIVE_CAP`], never touching
    /// `keep_key` (the record that was just written).
    fn evict_over_cap(m: &mut BTreeMap<String, Record>, keep_key: &str) {
        while m.len() > ARCHIVE_CAP {
            let victim = m
                .iter()
                .filter(|(k, _)| k.as_str() != keep_key)
                .min_by(|a, b| (a.1.hits, a.0).cmp(&(b.1.hits, b.0)))
                .map(|(k, _)| k.clone());
            match victim {
                Some(v) => {
                    m.remove(&v);
                }
                None => break,
            }
        }
    }

    /// Merge one record replicated from another archive. Union-by-key:
    /// an absent key is added; a present key keeps whichever copy has the
    /// HIGHER hit count (ties keep the local copy). Unlike
    /// [`Archive::insert`] — where a replacement *adds* the old hit count,
    /// because local completions race local resubmissions — a merge must
    /// take the max, not the sum: pull-merge rounds repeat forever, and
    /// summing would double-count the same hits every round. Max is
    /// idempotent (merging the same snapshot twice is a no-op) and
    /// commutative, so any two archives exchanging records converge.
    pub fn merge_record(&self, rec: Record) -> MergeOutcome {
        if !rec.is_finite() {
            return MergeOutcome::Skipped;
        }
        let key = Self::key(&rec.net, rec.env_fp, rec.search_fp);
        let mut m = self.records.lock().unwrap();
        match m.get_mut(&key) {
            Some(local) => {
                if rec.hits > local.hits {
                    *local = rec;
                    MergeOutcome::Raised
                } else {
                    MergeOutcome::Unchanged
                }
            }
            None => {
                m.insert(key.clone(), rec);
                Self::evict_over_cap(&mut m, &key);
                MergeOutcome::Added
            }
        }
    }

    /// Merge a `{"records": {key: record, ...}}` document (the
    /// `POST /v1/archive/merge` body and the pull-merge payload). Records
    /// are re-keyed from their own content — the sender's map keys are
    /// ignored — so a corrupted or adversarial key cannot alias a record
    /// onto the wrong fingerprint. A record failing decode or checksum is
    /// skipped and counted, same policy as [`Archive::open`]: one bad
    /// record costs one record.
    pub fn merge_json(&self, j: &Json) -> Result<MergeStats> {
        let records = j
            .get("records")
            .and_then(Json::as_obj)
            .context("merge body needs a `records` object")?;
        let mut stats = MergeStats::default();
        for (k, v) in records {
            match Record::from_json(v) {
                Ok(rec) => match self.merge_record(rec) {
                    MergeOutcome::Added => stats.added += 1,
                    MergeOutcome::Raised => stats.raised += 1,
                    MergeOutcome::Unchanged => stats.unchanged += 1,
                    MergeOutcome::Skipped => stats.skipped += 1,
                },
                Err(e) => {
                    stats.skipped += 1;
                    eprintln!("[serve] merge: skipping record `{k}`: {e:#}");
                }
            }
        }
        Ok(stats)
    }

    /// One page of records in key order (= fingerprint order — keys embed
    /// the hex fingerprints). `cursor` is the last key of the previous
    /// page (exclusive); `None` starts from the beginning. Returns the
    /// page and the cursor for the next one (`None` when exhausted). The
    /// caller caps `limit`; a page is the fleet's replication unit, so it
    /// must stay well under [`crate::serve::http::MAX_BODY`].
    pub fn page(&self, cursor: Option<&str>, limit: usize) -> (Vec<(String, Json)>, Option<String>) {
        let m = self.records.lock().unwrap();
        let mut out: Vec<(String, Json)> = m
            .range::<str, _>((
                match cursor {
                    Some(c) => std::ops::Bound::Excluded(c),
                    None => std::ops::Bound::Unbounded,
                },
                std::ops::Bound::Unbounded,
            ))
            .take(limit + 1)
            .map(|(k, r)| (k.clone(), r.to_json()))
            .collect();
        let next = if out.len() > limit {
            out.truncate(limit);
            out.last().map(|(k, _)| k.clone())
        } else {
            None
        };
        (out, next)
    }

    /// Union of the memo snapshots of every record matching (net, env_fp) —
    /// the warm-start set for a new session of that environment.
    pub fn memo_for(&self, net: &str, env_fp: u64) -> Vec<(Vec<u32>, f64)> {
        let m = self.records.lock().unwrap();
        let mut out: BTreeMap<Vec<u32>, f64> = BTreeMap::new();
        for rec in m.values() {
            if rec.net == net && rec.env_fp == env_fp {
                for (b, a) in &rec.memo {
                    out.insert(b.clone(), *a);
                }
                // every completed solution's final bits/accuracy is also a
                // valid short-retrain memo entry ONLY under the short
                // protocol — acc_final comes from the long retrain, so it
                // is deliberately NOT inserted here.
            }
        }
        out.into_iter().collect()
    }

    /// Persist atomically: write `<path>.tmp`, fsync-free rename over the
    /// target (rename within a directory is atomic on POSIX). Saves are
    /// serialized so concurrent completions can't interleave on the tmp
    /// file; each save snapshots the map afresh, so the last one to run
    /// writes the union.
    pub fn save(&self) -> Result<()> {
        let _serialize = self.save_lock.lock().unwrap();
        let doc = {
            let m = self.records.lock().unwrap();
            let mut map: BTreeMap<String, Json> =
                m.iter().map(|(k, r)| (k.clone(), r.to_json())).collect();
            map.insert(
                "schema_version".to_string(),
                Json::Num(ARCHIVE_SCHEMA_VERSION as f64),
            );
            Json::Obj(map)
        };
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let tmp = self.path.with_extension("json.tmp");
        std::fs::write(&tmp, doc.dump())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), self.path.display()))?;
        Ok(())
    }

    /// Throttled persistence for the per-completion hot path: each save
    /// serializes the WHOLE archive, so under heavy traffic saving on
    /// every completion would make completion cost grow with archive
    /// size. Skips (returning false) when a save completed within
    /// `min_interval`. The shutdown drain calls [`Archive::save`]
    /// unconditionally, so a skip here delays persistence to the next
    /// completion after the interval or to shutdown; a crash can lose at
    /// most the last `min_interval` of completions — the archive is a
    /// cache, not a ledger.
    pub fn save_throttled(&self, min_interval: std::time::Duration) -> Result<bool> {
        {
            let last = self.last_save.lock().unwrap();
            if let Some(t) = *last {
                if t.elapsed() < min_interval {
                    return Ok(false);
                }
            }
        }
        // stamp only on success: a failed attempt must not suppress the
        // retry on the very next completion. (Two racing callers may both
        // pass the check and both save — save_lock serializes them and the
        // result is simply one redundant write.)
        self.save()?;
        *self.last_save.lock().unwrap() = Some(Instant::now());
        Ok(true)
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resubmissions served from the archive since this process started.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Corrupted records dropped at [`Archive::open`].
    pub fn skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solution() -> Solution {
        Solution {
            bits: vec![8, 4, 4, 2],
            avg_bits: 4.5,
            acc_fullp: 0.98,
            acc_final: 0.97,
            acc_loss_pct: 1.0,
            state_q: 0.55,
            reward: 1.8,
            episodes_run: 40,
            pareto: vec![(0.4, 0.9, vec![2, 2, 2, 2]), (0.6, 0.99, vec![8, 4, 4, 2])],
        }
    }

    fn record(net: &str, env_fp: u64, search_fp: u64) -> Record {
        Record {
            net: net.to_string(),
            env_fp,
            search_fp,
            solution: solution(),
            memo: vec![(vec![8, 8, 8, 8], 0.97), (vec![4, 4, 4, 4], 0.94)],
            hits: 0,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("releq_archive_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrips_through_disk() {
        let path = tmp_path("roundtrip.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        assert!(a.is_empty());
        a.insert(record("lenet", 0xaa, 0xbb));
        a.insert(record("mobilenet", 0xcc, 0xdd));
        a.save().unwrap();

        let b = Archive::open(&path).unwrap();
        assert_eq!(b.len(), 2);
        let sol = b.lookup("lenet", 0xaa, 0xbb).expect("persisted record");
        assert_eq!(sol.bits, vec![8, 4, 4, 2]);
        assert_eq!(sol.pareto.len(), 2);
        assert_eq!(b.hits(), 1);
        assert!(b.lookup("lenet", 0xaa, 0xff).is_none());
        // per-record hit counters persist across save/open
        b.save().unwrap();
        let c = Archive::open(&path).unwrap();
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn memo_union_is_scoped_to_env_fingerprint() {
        let path = tmp_path("memo.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        a.insert(record("lenet", 0x1, 0x10));
        let mut other = record("lenet", 0x1, 0x20);
        other.memo = vec![(vec![2, 2, 2, 2], 0.80), (vec![4, 4, 4, 4], 0.94)];
        a.insert(other);
        a.insert(record("lenet", 0x2, 0x30)); // different env: excluded
        let warm = a.memo_for("lenet", 0x1);
        assert_eq!(warm.len(), 3); // union, deduped on bits
        assert!(a.memo_for("lenet", 0x9).is_empty());
        assert!(a.memo_for("vgg11", 0x1).is_empty());
    }

    #[test]
    fn non_finite_solutions_are_not_archived() {
        let path = tmp_path("nan.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        let mut diverged = record("lenet", 9, 9);
        diverged.solution.acc_final = f64::NAN;
        a.insert(diverged);
        assert!(a.is_empty(), "diverged solutions must be rejected");
        let mut bad_memo = record("lenet", 9, 10);
        bad_memo.memo.push((vec![2, 2, 2, 2], f64::INFINITY));
        a.insert(bad_memo);
        assert!(a.is_empty());
        // save/reopen of a clean archive still round-trips
        a.insert(record("lenet", 1, 1));
        a.save().unwrap();
        assert_eq!(Archive::open(&path).unwrap().len(), 1);
    }

    #[test]
    fn archive_is_bounded_and_keeps_hot_records() {
        let path = tmp_path("cap.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        let mut hot = record("lenet", 0, 0);
        hot.hits = 50;
        a.insert(hot);
        for i in 1..=(ARCHIVE_CAP as u64 + 8) {
            a.insert(record("lenet", i, i));
        }
        assert_eq!(a.len(), ARCHIVE_CAP, "records map must stay bounded");
        assert!(a.lookup("lenet", 0, 0).is_some(), "least-hit eviction keeps hot records");
    }

    #[test]
    fn throttled_save_coalesces() {
        let path = tmp_path("throttle.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        a.insert(record("lenet", 1, 1));
        assert!(a.save_throttled(std::time::Duration::from_secs(60)).unwrap());
        a.insert(record("lenet", 1, 2));
        assert!(
            !a.save_throttled(std::time::Duration::from_secs(60)).unwrap(),
            "second save within the interval must be skipped"
        );
        // the skipped record is not on disk yet...
        assert_eq!(Archive::open(&path).unwrap().len(), 1);
        // ...until an unconditional save (the shutdown path)
        a.save().unwrap();
        assert_eq!(Archive::open(&path).unwrap().len(), 2);
        // a zero interval never throttles
        assert!(a.save_throttled(std::time::Duration::from_secs(0)).unwrap());
    }

    #[test]
    fn corrupt_archive_is_an_error_not_a_wipe() {
        let path = tmp_path("corrupt.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(Archive::open(&path).is_err());
    }

    #[test]
    fn tampered_record_is_skipped_not_fatal() {
        let path = tmp_path("tamper.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        a.insert(record("lenet", 0x1, 0x2));
        a.insert(record("mobilenet", 0x3, 0x4));
        a.save().unwrap();

        // flip one stored accuracy in the lenet record only; its checksum
        // no longer matches while the mobilenet record stays intact
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"checksum\":"), "records persist a checksum field");
        let i = text.find("lenet").unwrap();
        let j = text[i..].find("\"acc_final\":0.97").map(|k| i + k).unwrap();
        let tampered = format!(
            "{}{}{}",
            &text[..j],
            "\"acc_final\":0.87",
            &text[j + "\"acc_final\":0.97".len()..]
        );
        std::fs::write(&path, tampered).unwrap();

        let b = Archive::open(&path).unwrap();
        assert_eq!(b.len(), 1, "only the tampered record is dropped");
        assert_eq!(b.skipped(), 1, "the drop is counted");
        assert!(b.lookup("lenet", 0x1, 0x2).is_none());
        assert!(b.lookup("mobilenet", 0x3, 0x4).is_some(), "intact records survive");

        // saving the repaired view writes a clean archive again
        b.save().unwrap();
        let c = Archive::open(&path).unwrap();
        assert_eq!((c.len(), c.skipped()), (1, 0));
    }

    #[test]
    fn legacy_records_without_checksum_are_accepted() {
        let path = tmp_path("legacy.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        a.insert(record("lenet", 0x5, 0x6));
        a.save().unwrap();
        // strip the checksum field, emulating a pre-PR-6 archive (objects
        // dump with sorted keys, so `checksum` leads the record and its
        // trailing comma goes with it: `"checksum":"<16 hex>",`)
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped = {
            let i = text.find("\"checksum\":\"").unwrap();
            let end = i + "\"checksum\":\"0000000000000000\",".len();
            format!("{}{}", &text[..i], &text[end..])
        };
        assert!(!stripped.contains("checksum"));
        std::fs::write(&path, stripped).unwrap();
        let b = Archive::open(&path).unwrap();
        assert_eq!((b.len(), b.skipped()), (1, 0));
        assert!(b.lookup("lenet", 0x5, 0x6).is_some());
    }

    #[test]
    fn legacy_versionless_archive_migrates_forward() {
        let path = tmp_path("schema.json");
        let _ = std::fs::remove_file(&path);
        let a = Archive::open(&path).unwrap();
        a.insert(record("lenet", 0x7, 0x8));
        a.save().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"schema_version\":1"), "saves stamp the schema");

        // strip the root-level stamp, emulating a pre-PR-8 archive file
        // (sorted keys put record keys like `lenet:...` before `s`, so the
        // stamp is the LAST root entry and its leading comma goes with it)
        let needle = ",\"schema_version\":1";
        let i = text.find(needle).unwrap();
        let legacy = format!("{}{}", &text[..i], &text[i + needle.len()..]);
        assert!(!legacy.contains("schema_version"));
        std::fs::write(&path, legacy).unwrap();

        // versionless file loads with nothing skipped...
        let b = Archive::open(&path).unwrap();
        assert_eq!((b.len(), b.skipped()), (1, 0));
        assert!(b.lookup("lenet", 0x7, 0x8).is_some());
        // ...and the next save forward-migrates it to the stamped format
        b.save().unwrap();
        let migrated = std::fs::read_to_string(&path).unwrap();
        assert!(migrated.contains("\"schema_version\":1"));
        let c = Archive::open(&path).unwrap();
        assert_eq!((c.len(), c.skipped()), (1, 0));

        // a FUTURE schema is refused outright, not silently reinterpreted
        let future = migrated.replace("\"schema_version\":1", "\"schema_version\":99");
        std::fs::write(&path, future).unwrap();
        assert!(Archive::open(&path).is_err());
    }

    #[test]
    fn fingerprints_separate_env_from_search_knobs() {
        let base = SearchConfig::default();
        let mut search_tweak = base.clone();
        search_tweak.seed += 1;
        let mut env_tweak = base.clone();
        env_tweak.env.retrain_steps += 1;

        let e0 = env_fingerprint("lenet", 8, &base.env);
        assert_eq!(e0, env_fingerprint("lenet", 8, &search_tweak.env));
        assert_ne!(e0, env_fingerprint("lenet", 8, &env_tweak.env));
        assert_ne!(e0, env_fingerprint("vgg11", 8, &base.env));
        assert_ne!(e0, env_fingerprint("lenet", 4, &base.env));

        let s0 = search_fingerprint("lenet", 8, &base);
        assert_eq!(s0, search_fingerprint("lenet", 8, &base.clone()));
        assert_ne!(s0, search_fingerprint("lenet", 8, &search_tweak));
        assert_ne!(s0, search_fingerprint("lenet", 8, &env_tweak));

        // memo_cap is cache sizing, not an accuracy determinant
        let mut cap_tweak = base.clone();
        cap_tweak.env.memo_cap = 7;
        assert_eq!(e0, env_fingerprint("lenet", 8, &cap_tweak.env));
    }

    /// All records of `a` as a merge document (what one pull page carries).
    fn merge_doc(a: &Archive) -> Json {
        let (page, next) = a.page(None, ARCHIVE_CAP);
        assert!(next.is_none());
        Json::obj(vec![("records", Json::Obj(page.into_iter().collect()))])
    }

    #[test]
    fn merge_is_idempotent_and_convergent() {
        let pa = tmp_path("merge_a.json");
        let pb = tmp_path("merge_b.json");
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
        let a = Archive::open(&pa).unwrap();
        let b = Archive::open(&pb).unwrap();
        // overlap on (lenet, 1, 1) with different hit counts; each side
        // also holds a record the other lacks
        let mut hot = record("lenet", 1, 1);
        hot.hits = 9;
        a.insert(hot);
        a.insert(record("lenet", 2, 2));
        let mut cold = record("lenet", 1, 1);
        cold.hits = 3;
        b.insert(cold);
        b.insert(record("mobilenet", 5, 5));

        // one exchange in each direction converges both sides
        let sb = b.merge_json(&merge_doc(&a)).unwrap();
        assert_eq!((sb.added, sb.raised, sb.skipped), (1, 1, 0));
        let sa = a.merge_json(&merge_doc(&b)).unwrap();
        assert_eq!((sa.added, sa.raised, sa.skipped), (1, 0, 0));
        assert!(sa.changed() && sb.changed());
        let keys = |x: &Archive| x.page(None, ARCHIVE_CAP).0.into_iter()
            .map(|(k, _)| k).collect::<Vec<_>>();
        assert_eq!(keys(&a), keys(&b), "one round-trip converges the key sets");
        // max-hits-wins: both sides now carry the 9-hit copy. lookup bumps
        // hits, so read them straight off the page payloads.
        for x in [&a, &b] {
            let (page, _) = x.page(None, ARCHIVE_CAP);
            let hot = page.iter().find(|(k, _)| k.starts_with("lenet:0000000000000001")).unwrap();
            assert_eq!(hot.1.u("hits"), 9);
        }

        // idempotence: re-merging the same snapshot changes nothing
        let again = b.merge_json(&merge_doc(&a)).unwrap();
        assert_eq!((again.added, again.raised), (0, 0));
        assert!(!again.changed());
        assert_eq!(again.unchanged, 3);
    }

    #[test]
    fn merge_rejects_corrupt_records_individually() {
        let p = tmp_path("merge_bad.json");
        let _ = std::fs::remove_file(&p);
        let a = Archive::open(&p).unwrap();
        let good = record("lenet", 1, 1).to_json();
        let mut tampered = match record("lenet", 2, 2).to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        tampered.insert("checksum".into(), Json::Str("00000000deadbeef".into()));
        let doc = Json::obj(vec![(
            "records",
            Json::Obj(
                [
                    ("k1".to_string(), good),
                    ("k2".to_string(), Json::Obj(tampered)),
                    ("k3".to_string(), Json::Str("not a record".into())),
                ]
                .into_iter()
                .collect(),
            ),
        )]);
        let st = a.merge_json(&doc).unwrap();
        assert_eq!((st.added, st.skipped), (1, 2), "bad records cost only themselves");
        assert_eq!(a.len(), 1);
        // a body without `records` is a client error
        assert!(a.merge_json(&Json::obj(vec![("nope", Json::Null)])).is_err());
    }

    #[test]
    fn merge_never_sums_hits_across_rounds() {
        // the regression the max-hits rule exists for: N merge rounds of
        // the same remote snapshot must not inflate the local hit count
        let p = tmp_path("merge_hits.json");
        let _ = std::fs::remove_file(&p);
        let a = Archive::open(&p).unwrap();
        let mut remote = record("lenet", 1, 1);
        remote.hits = 4;
        for _ in 0..5 {
            a.merge_record(remote.clone());
        }
        let (page, _) = a.page(None, 8);
        assert_eq!(page[0].1.u("hits"), 4, "5 rounds of the same record keep hits at 4");
    }

    #[test]
    fn pages_walk_the_archive_in_key_order() {
        let p = tmp_path("page.json");
        let _ = std::fs::remove_file(&p);
        let a = Archive::open(&p).unwrap();
        for i in 0..7u64 {
            a.insert(record("lenet", i, i));
        }
        let mut cursor: Option<String> = None;
        let mut seen = Vec::new();
        loop {
            let (page, next) = a.page(cursor.as_deref(), 3);
            assert!(page.len() <= 3);
            seen.extend(page.into_iter().map(|(k, _)| k));
            match next {
                Some(c) => cursor = Some(c),
                None => break,
            }
        }
        assert_eq!(seen.len(), 7, "pagination visits every record exactly once");
        let mut sorted = seen.clone();
        sorted.sort();
        assert_eq!(seen, sorted, "pages walk in key (fingerprint) order");
        // a cursor past the end is an empty final page, not an error
        assert!(a.page(Some("zzzz"), 3).0.is_empty());
    }
}
