//! Session cache: one pretrained shared-core environment per
//! (network, env fingerprint), shared by every job the daemon runs.
//!
//! PR 2 established the one-pretrain invariant *within* a run: every shard,
//! replica and lane of one search shares one `Arc<EnvCore>`. This module
//! extends it *across jobs*: the first job for a network pays the data
//! generation + full-precision pretraining bring-up, every later job (and
//! every concurrent job — creation is single-flight, same leader/follower
//! protocol as `AccMemo::get_or_compute`) gets a clone of the same handle,
//! with the same single-flight accuracy memo. Sessions are deliberately
//! retained for the process lifetime ("pretrain once per network per
//! process lifetime"): distinct (network, env-config) pairs are few and
//! each holds the device-resident buffers a warm search needs.
//!
//! A freshly built session warm-starts its memo from the solution
//! archive's records for the same (network, env fingerprint) — accuracy is
//! a pure function of (env config, bits), so entries computed by an
//! earlier process are valid verbatim.
//!
//! Concurrent jobs on one session also share the **megabatch accuracy
//! evaluator**: every job's per-step candidate slate goes through the
//! session memo's batch single-flight protocol, so overlapping candidates
//! coalesce onto whichever job's batch claimed them first and the distinct
//! remainder is scored K lanes per device execution
//! (`EnvCore::accuracy_batch`; amortization visible in `/v1/stats` as
//! `eval_batch_execs` / `batched_candidates` / `pad_lanes`).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::config::JobSpec;
use crate::coordinator::{QuantEnv, Searcher};
use crate::pareto;
use crate::runtime::{Engine, Manifest};
use crate::util::json::Json;

use super::archive::{env_fingerprint, search_fingerprint, Archive, Solution};
use super::scheduler::{Job, JobRunner};

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub net: String,
    pub env_fp: u64,
}

enum Slot {
    /// a leader is pretraining; followers wait on the condvar
    Building,
    Ready(QuantEnv),
}

/// Single-flight map of live sessions.
pub struct SessionCache {
    slots: Mutex<HashMap<SessionKey, Slot>>,
    cv: Condvar,
    /// environment bring-ups actually paid (the across-jobs invariant
    /// counter: stays at 1 no matter how many jobs share a network)
    pretrains: AtomicU64,
}

impl Default for SessionCache {
    fn default() -> SessionCache {
        SessionCache {
            slots: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            pretrains: AtomicU64::new(0),
        }
    }
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    /// Get the session for `key`, building it with `build` if absent.
    /// Single-flight: concurrent callers for the same key block on the one
    /// leader instead of each pretraining. A failed build unpins the key
    /// and one waiter retries as the new leader; a *panicking* build does
    /// the same via a drop guard — a wedged `Building` slot would block
    /// every future job for that network forever.
    pub fn get_or_create<F>(&self, key: SessionKey, build: F) -> Result<QuantEnv>
    where
        F: FnOnce() -> Result<QuantEnv>,
    {
        /// Unwind guard for the leader: while armed, dropping it removes
        /// the `Building` slot and wakes waiters so one can retry as the
        /// new leader (same protocol as `AccMemo`'s `UnpinOnDrop`).
        struct ClearOnDrop<'a> {
            cache: &'a SessionCache,
            key: &'a SessionKey,
            armed: bool,
        }
        impl Drop for ClearOnDrop<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                let mut m = self.cache.slots.lock().unwrap();
                if matches!(m.get(self.key), Some(Slot::Building)) {
                    m.remove(self.key);
                }
                self.cache.cv.notify_all();
            }
        }

        {
            let mut m = self.slots.lock().unwrap();
            loop {
                match m.get(&key) {
                    Some(Slot::Ready(env)) => return Ok(env.clone()),
                    Some(Slot::Building) => m = self.cv.wait(m).unwrap(),
                    None => {
                        m.insert(key.clone(), Slot::Building);
                        break;
                    }
                }
            }
        }
        // leader: build outside the lock (pretraining takes seconds)
        let mut guard = ClearOnDrop { cache: self, key: &key, armed: true };
        let built = build();
        guard.armed = false;
        drop(guard);
        let mut m = self.slots.lock().unwrap();
        match built {
            Ok(env) => {
                self.pretrains.fetch_add(1, Ordering::Relaxed);
                m.insert(key, Slot::Ready(env.clone()));
                self.cv.notify_all();
                Ok(env)
            }
            Err(e) => {
                m.remove(&key);
                self.cv.notify_all();
                Err(e)
            }
        }
    }

    /// Environment bring-ups paid since process start.
    pub fn pretrains(&self) -> u64 {
        self.pretrains.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-session stats fragment for `GET /v1/stats` (key-ordered — the
    /// rows collect into `Json::Obj`'s BTreeMap).
    pub fn stats_json(&self) -> Json {
        let m = self.slots.lock().unwrap();
        let rows: Vec<(String, Json)> = m
            .iter()
            .filter_map(|(k, slot)| match slot {
                Slot::Ready(env) => {
                    let s = env.stats();
                    Some((
                        format!("{}:{:016x}", k.net, k.env_fp),
                        Json::obj(vec![
                            ("net", Json::Str(k.net.clone())),
                            ("env_fp", Json::Str(format!("{:016x}", k.env_fp))),
                            ("acc_fullp", Json::Num(env.acc_fullp)),
                            ("evals", Json::Num(s.evals as f64)),
                            ("cache_hits", Json::Num(s.cache_hits as f64)),
                            ("train_execs", Json::Num(s.train_execs as f64)),
                            ("eval_execs", Json::Num(s.eval_execs as f64)),
                            ("eval_batch_execs", Json::Num(s.eval_batch_execs as f64)),
                            ("batched_candidates", Json::Num(s.batched_candidates as f64)),
                            ("pad_lanes", Json::Num(s.pad_lanes as f64)),
                            ("memo_len", Json::Num(s.memo_len as f64)),
                            ("memo_hits", Json::Num(s.memo_hits as f64)),
                            ("memo_misses", Json::Num(s.memo_misses as f64)),
                            ("memo_evictions", Json::Num(s.memo_evictions as f64)),
                            ("spec_submitted", Json::Num(s.spec_submitted as f64)),
                            ("spec_hits", Json::Num(s.spec_hits as f64)),
                            ("spec_wasted", Json::Num(s.spec_wasted as f64)),
                        ]),
                    ))
                }
                Slot::Building => None,
            })
            .collect();
        Json::Obj(rows.into_iter().collect())
    }
}

/// The real execution backend: resolves jobs onto shared-core sessions and
/// runs the ReLeQ search through the PJRT engine.
pub struct SessionRunner {
    manifest: Manifest,
    engine: Arc<Engine>,
    sessions: SessionCache,
    archive: Arc<Archive>,
    /// memo entries exported per job for archive warm-starts (top-k by
    /// recency; the scheduler's `memo_persist` bound)
    memo_persist: usize,
}

impl SessionRunner {
    pub fn new(manifest: Manifest, engine: Arc<Engine>, archive: Arc<Archive>,
               memo_persist: usize) -> SessionRunner {
        SessionRunner { manifest, engine, sessions: SessionCache::new(), archive, memo_persist }
    }

    pub fn sessions(&self) -> &SessionCache {
        &self.sessions
    }
}

impl JobRunner for SessionRunner {
    fn prepare(&self, spec: &JobSpec) -> Result<(u64, u64)> {
        self.manifest.network(&spec.net)?;
        anyhow::ensure!(spec.cfg.episodes >= 1, "job needs episodes >= 1");
        let bits_max = self.manifest.bits_max;
        Ok((
            env_fingerprint(&spec.net, bits_max, &spec.cfg.env),
            search_fingerprint(&spec.net, bits_max, &spec.cfg),
        ))
    }

    fn run(&self, job: &Job) -> Result<(Solution, Vec<(Vec<u32>, f64)>)> {
        let spec = &job.spec;
        let net = self.manifest.network(&spec.net)?;
        let key = SessionKey { net: spec.net.clone(), env_fp: job.env_fp };
        let env = self.sessions.get_or_create(key, || {
            let env = QuantEnv::new(
                self.engine.clone(),
                net,
                self.manifest.bits_max,
                self.manifest.fp_bits,
                spec.cfg.env.clone(),
            )?;
            let warm = self.archive.memo_for(&spec.net, job.env_fp);
            if !warm.is_empty() {
                eprintln!(
                    "[serve] warm-starting {} session memo with {} archived entries",
                    spec.net,
                    warm.len()
                );
                env.memo().extend(warm);
            }
            Ok(env)
        })?;
        // memo_cap and eval_batch are deliberately outside the env
        // fingerprint (one bounds the cache, the other shapes execution
        // batches; neither changes accuracy values), so a job joining an
        // existing session keeps the session's settings — surface that
        // instead of silently dropping the request
        if env.memo().capacity() != spec.cfg.env.memo_cap {
            eprintln!(
                "[serve] job {}: memo_cap {} ignored — session already holds a memo \
                 bounded to {} (set at session creation)",
                job.id,
                spec.cfg.env.memo_cap,
                env.memo().capacity()
            );
        }
        // compare *resolved* widths, not raw knob values: eval_batch = 0
        // and an explicit eval_batch = 8 both resolve to the artifact's
        // baked width, and warning that 8 was "ignored" in favor of 8
        // would just confuse the operator
        if env.eval_batch_width() != env.eval_batch_width_for(spec.cfg.env.eval_batch) {
            eprintln!(
                "[serve] job {}: eval_batch {} ignored — session evaluates at width {} \
                 (set at session creation); concurrent jobs coalesce their accuracy \
                 misses into that session's shared megabatches regardless",
                job.id,
                spec.cfg.env.eval_batch,
                env.eval_batch_width()
            );
        }
        // a cancel during pretraining stops before the search starts
        job.ctl.check()?;

        let mut searcher =
            Searcher::with_env(env.clone(), self.engine.clone(), &self.manifest, spec.cfg.clone())
                .with_context(|| format!("building searcher for {}", spec.net))?;
        let result = searcher.run_ctl(&job.ctl)?;

        // Pareto view of everything this search visited: dedup episode
        // bits (accuracy is pure in bits, so later duplicates are
        // identical), then extract the frontier
        let mut seen: std::collections::BTreeMap<Vec<u32>, (f64, f64)> =
            std::collections::BTreeMap::new();
        for e in &result.log.episodes {
            seen.entry(e.bits.clone()).or_insert((e.state_q, e.state_acc));
        }
        let points: Vec<pareto::Point> = seen
            .into_iter()
            .map(|(bits, (state_q, state_acc))| pareto::Point { bits, state_q, state_acc })
            .collect();
        let frontier = pareto::pareto_frontier(&points);
        let pareto_pts: Vec<(f64, f64, Vec<u32>)> = frontier
            .into_iter()
            .map(|i| (points[i].state_q, points[i].state_acc, points[i].bits.clone()))
            .collect();

        let reward = result
            .log
            .rewards()
            .into_iter()
            .fold(f64::NEG_INFINITY, f64::max);
        let solution = Solution {
            bits: result.bits,
            avg_bits: result.avg_bits,
            acc_fullp: result.acc_fullp,
            acc_final: result.acc_final,
            acc_loss_pct: result.acc_loss_pct,
            state_q: result.state_q,
            reward: if reward.is_finite() { reward } else { 0.0 },
            episodes_run: result.episodes_run,
            pareto: pareto_pts,
        };
        // top-k by recency: the entries this search was actually
        // revisiting, already bounded to what the archive will persist
        Ok((solution, env.memo().entries_by_recency(self.memo_persist)))
    }

    fn stats(&self) -> Json {
        Json::obj(vec![
            ("pretrains", Json::Num(self.sessions.pretrains() as f64)),
            ("sessions", self.sessions.stats_json()),
            (
                "engine",
                Json::Arr(
                    self.engine
                        .exec_stats()
                        .into_iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("artifact", Json::Str(s.name)),
                                ("execs", Json::Num(s.execs as f64)),
                                ("mean_exec_ms", Json::Num(s.mean_exec_ms)),
                                ("mean_download_ms", Json::Num(s.mean_download_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::run_sharded;

    /// The single-flight protocol is testable without PJRT: a counter-typed
    /// "env" is impossible here (build returns QuantEnv), so race the
    /// leader election itself with a build that fails — every caller must
    /// observe the error, the key must unpin, and no slot may leak.
    #[test]
    fn failed_builds_unpin_the_key() {
        let cache = SessionCache::new();
        let key = SessionKey { net: "lenet".to_string(), env_fp: 7 };
        let r = cache.get_or_create(key.clone(), || anyhow::bail!("no artifacts"));
        assert!(r.is_err());
        assert_eq!(cache.len(), 0, "failed build must not leave a Building slot");
        assert_eq!(cache.pretrains(), 0);
        // the key is retryable
        let r2 = cache.get_or_create(key, || anyhow::bail!("still no artifacts"));
        assert!(r2.is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn panicking_build_unpins_the_key() {
        let cache = SessionCache::new();
        let key = SessionKey { net: "lenet".to_string(), env_fp: 3 };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = cache.get_or_create(key.clone(), || panic!("boom"));
        }));
        assert!(r.is_err());
        assert_eq!(cache.len(), 0, "panicked build must not leave a Building slot");
        // the key stays retryable
        assert!(cache.get_or_create(key, || anyhow::bail!("still failing")).is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn concurrent_failed_builds_never_wedge() {
        let cache = std::sync::Arc::new(SessionCache::new());
        let results = run_sharded(vec![(); 8], |i, _| {
            let key = SessionKey { net: "lenet".to_string(), env_fp: 1 };
            let r = cache.get_or_create(key, || anyhow::bail!("build {i} failed"));
            Ok(r.is_err())
        })
        .unwrap();
        assert!(results.into_iter().all(|failed| failed));
        assert_eq!(cache.len(), 0);
    }
}
