//! Session cache: one pretrained shared-core environment per
//! (network, env fingerprint), shared by every job the daemon runs.
//!
//! PR 2 established the one-pretrain invariant *within* a run: every shard,
//! replica and lane of one search shares one `Arc<EnvCore>`. This module
//! extends it *across jobs*: the first job for a network pays the data
//! generation + full-precision pretraining bring-up, every later job (and
//! every concurrent job — creation is single-flight, same leader/follower
//! protocol as `AccMemo::get_or_compute`) gets a clone of the same handle,
//! with the same single-flight accuracy memo. Sessions are deliberately
//! retained for the process lifetime ("pretrain once per network per
//! process lifetime"): distinct (network, env-config) pairs are few and
//! each holds the device-resident buffers a warm search needs.
//!
//! A freshly built session warm-starts its memo from the solution
//! archive's records for the same (network, env fingerprint) — accuracy is
//! a pure function of (env config, bits), so entries computed by an
//! earlier process are valid verbatim.
//!
//! Concurrent jobs on one session also share the **megabatch accuracy
//! evaluator**: every job's per-step candidate slate goes through the
//! session memo's batch single-flight protocol, so overlapping candidates
//! coalesce onto whichever job's batch claimed them first and the distinct
//! remainder is scored K lanes per device execution
//! (`EnvCore::accuracy_batch`; amortization visible in `/v1/stats` as
//! `eval_batch_execs` / `batched_candidates` / `pad_lanes`).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use anyhow::{Context, Result};

use crate::config::JobSpec;
use crate::coordinator::{Durable, QuantEnv, SearchCheckpoint, Searcher};
use crate::pareto;
use crate::registry::{NetVersion, Registry};
use crate::runtime::{Engine, FaultError, Manifest};
use crate::util::json::Json;
use crate::util::lock::{lock_recover, read_recover, write_recover};

use super::archive::{env_fingerprint, search_fingerprint, Archive, Solution};
use super::scheduler::{Job, JobRunner};

/// Session identity: `(net, manifest_version, env fingerprint)`. The version
/// component keeps sessions from ever mixing artifacts across a registry
/// upgrade — a job prepared against version N runs and completes on version
/// N's session even if version N+1 installs while it is queued (new jobs
/// resolve to N+1, whose digest-qualified network name also lands them on a
/// different `env_fp`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub net: String,
    pub version: u64,
    pub env_fp: u64,
}

enum Slot<V> {
    /// no live env: either never built, or evicted by quarantine — the
    /// next caller becomes the build leader (entry bookkeeping survives)
    Vacant,
    /// a leader is pretraining; followers wait on the condvar
    Building,
    Ready(V),
    /// quarantined for good: the env failed K consecutive jobs, was
    /// rebuilt once, and the rebuild failed K more — every new job gets
    /// this typed permanent error immediately instead of burning its
    /// retry budget on a dead environment
    Poisoned(String),
}

struct Entry<V> {
    slot: Slot<V>,
    /// consecutive job failures on the CURRENT Ready env (reset by any
    /// success, and on eviction)
    consec: u32,
    /// quarantine evictions this key has absorbed (the rebuild-once bound)
    rebuilds: u32,
}

/// What a recorded failure did to the session (see
/// [`SessionCache::record_failure`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quarantine {
    /// below the threshold (or quarantine disabled): env retained
    Retained,
    /// threshold hit for the first time: env evicted, next job rebuilds
    Evicted,
    /// threshold hit again after the one rebuild: key poisoned for good
    Poisoned,
}

/// Single-flight map of live sessions, generic over the session value so
/// the quarantine protocol is testable without PJRT (`SessionCache<u32>`
/// in the stub tiers; the daemon runs `SessionCache<QuantEnv>`).
pub struct SessionCache<V = QuantEnv> {
    slots: Mutex<HashMap<SessionKey, Entry<V>>>,
    cv: Condvar,
    /// environment bring-ups actually paid (the across-jobs invariant
    /// counter: stays at 1 no matter how many jobs share a network)
    pretrains: AtomicU64,
    /// consecutive-failure threshold (0 disables quarantine)
    quarantine_k: u32,
    /// quarantine actions taken (evictions + poisonings)
    quarantines: AtomicU64,
}

impl<V> Default for SessionCache<V> {
    fn default() -> SessionCache<V> {
        SessionCache::with_quarantine(0)
    }
}

impl<V: Clone> SessionCache<V> {
    pub fn new() -> SessionCache<V> {
        SessionCache::default()
    }

    /// A cache that quarantines a session after `k` consecutive job
    /// failures: evicted and rebuilt once, poisoned the second time.
    pub fn with_quarantine(k: u32) -> SessionCache<V> {
        SessionCache {
            slots: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            pretrains: AtomicU64::new(0),
            quarantine_k: k,
            quarantines: AtomicU64::new(0),
        }
    }

    /// Get the session for `key`, building it with `build` if absent.
    /// Single-flight: concurrent callers for the same key block on the one
    /// leader instead of each pretraining. A failed build unpins the key
    /// and one waiter retries as the new leader; a *panicking* build does
    /// the same via a drop guard — a wedged `Building` slot would block
    /// every future job for that network forever. A poisoned key fails
    /// immediately with a typed [`FaultError::Permanent`].
    pub fn get_or_create<F>(&self, key: SessionKey, build: F) -> Result<V>
    where
        F: FnOnce() -> Result<V>,
    {
        /// Unwind guard for the leader: while armed, dropping it vacates
        /// the `Building` slot and wakes waiters so one can retry as the
        /// new leader (same protocol as `AccMemo`'s `UnpinOnDrop`).
        struct ClearOnDrop<'a, V> {
            cache: &'a SessionCache<V>,
            key: &'a SessionKey,
            armed: bool,
        }
        impl<V> Drop for ClearOnDrop<'_, V> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                let mut m = lock_recover(&self.cache.slots);
                if let Some(e) = m.get_mut(self.key) {
                    if matches!(e.slot, Slot::Building) {
                        e.slot = Slot::Vacant;
                    }
                }
                self.cache.cv.notify_all();
            }
        }

        {
            let mut m = lock_recover(&self.slots);
            loop {
                match m.get_mut(&key) {
                    Some(e) => match &mut e.slot {
                        Slot::Ready(env) => return Ok(env.clone()),
                        Slot::Building => m = lock_recover_wait(&self.cv, m),
                        Slot::Poisoned(msg) => {
                            return Err(FaultError::Permanent(msg.clone()).into())
                        }
                        Slot::Vacant => {
                            e.slot = Slot::Building;
                            break;
                        }
                    },
                    None => {
                        m.insert(
                            key.clone(),
                            Entry { slot: Slot::Building, consec: 0, rebuilds: 0 },
                        );
                        break;
                    }
                }
            }
        }
        // leader: build outside the lock (pretraining takes seconds)
        let mut guard = ClearOnDrop { cache: self, key: &key, armed: true };
        let built = build();
        guard.armed = false;
        drop(guard);
        let mut m = lock_recover(&self.slots);
        match built {
            Ok(env) => {
                self.pretrains.fetch_add(1, Ordering::Relaxed);
                if let Some(e) = m.get_mut(&key) {
                    e.slot = Slot::Ready(env.clone());
                    e.consec = 0;
                }
                self.cv.notify_all();
                Ok(env)
            }
            Err(e) => {
                if let Some(entry) = m.get_mut(&key) {
                    entry.slot = Slot::Vacant;
                }
                self.cv.notify_all();
                Err(e)
            }
        }
    }

    /// A job on this session succeeded: clear its failure streak.
    pub fn record_success(&self, key: &SessionKey) {
        let mut m = lock_recover(&self.slots);
        if let Some(e) = m.get_mut(key) {
            e.consec = 0;
        }
    }

    /// A job on this session failed (for a non-cancellation reason).
    /// Counts the failure against the key's streak; at `quarantine_k`
    /// consecutive failures the cached env is evicted (first offense —
    /// the next job rebuilds it from scratch) or poisoned (the rebuilt
    /// env ALSO failed K straight: a deterministic fault, not bad luck).
    pub fn record_failure(&self, key: &SessionKey, reason: &str) -> Quarantine {
        if self.quarantine_k == 0 {
            return Quarantine::Retained;
        }
        let mut m = lock_recover(&self.slots);
        let Some(e) = m.get_mut(key) else { return Quarantine::Retained };
        if !matches!(e.slot, Slot::Ready(_)) {
            return Quarantine::Retained;
        }
        e.consec += 1;
        if e.consec < self.quarantine_k {
            return Quarantine::Retained;
        }
        e.consec = 0;
        self.quarantines.fetch_add(1, Ordering::Relaxed);
        if e.rebuilds == 0 {
            e.rebuilds += 1;
            e.slot = Slot::Vacant;
            eprintln!(
                "[serve] session {}:{:016x} quarantined after {} consecutive failures \
                 ({reason}); will rebuild once",
                key.net, key.env_fp, self.quarantine_k
            );
            Quarantine::Evicted
        } else {
            let msg = format!(
                "session {}:{:016x} poisoned: rebuilt env failed {} more consecutive \
                 jobs ({reason})",
                key.net, key.env_fp, self.quarantine_k
            );
            eprintln!("[serve] {msg}");
            e.slot = Slot::Poisoned(msg);
            Quarantine::Poisoned
        }
    }

    /// The poison message for `key`, if it has been quarantined for good.
    pub fn poisoned(&self, key: &SessionKey) -> Option<String> {
        let m = lock_recover(&self.slots);
        match m.get(key).map(|e| &e.slot) {
            Some(Slot::Poisoned(msg)) => Some(msg.clone()),
            _ => None,
        }
    }

    /// Number of keys poisoned for good.
    pub fn poisoned_count(&self) -> usize {
        let m = lock_recover(&self.slots);
        m.values().filter(|e| matches!(e.slot, Slot::Poisoned(_))).count()
    }

    /// Quarantine actions taken (evictions + poisonings) since start.
    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Environment bring-ups paid since process start.
    pub fn pretrains(&self) -> u64 {
        self.pretrains.load(Ordering::Relaxed)
    }

    /// Live (Ready) sessions — vacated and poisoned keys don't count.
    pub fn len(&self) -> usize {
        let m = lock_recover(&self.slots);
        m.values().filter(|e| matches!(e.slot, Slot::Ready(_))).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the Ready sessions: `(key, value)` clones. The archive
    /// merge path walks this to re-warm live memos with replicated
    /// records; building/vacant/poisoned slots are skipped (a building
    /// session warm-starts itself when its leader finishes).
    pub fn ready_sessions(&self) -> Vec<(SessionKey, V)> {
        let m = lock_recover(&self.slots);
        m.iter()
            .filter_map(|(k, e)| match &e.slot {
                Slot::Ready(v) => Some((k.clone(), v.clone())),
                _ => None,
            })
            .collect()
    }
}

/// Condvar wait that recovers a poisoned guard (same rationale as
/// [`crate::util::lock`]: the slot map stays valid across a panic).
fn lock_recover_wait<'a, T>(
    cv: &Condvar, g: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl SessionCache<QuantEnv> {
    /// Per-session stats fragment for `GET /v1/stats` (key-ordered — the
    /// rows collect into `Json::Obj`'s BTreeMap).
    pub fn stats_json(&self) -> Json {
        let m = lock_recover(&self.slots);
        let rows: Vec<(String, Json)> = m
            .iter()
            .filter_map(|(k, entry)| match &entry.slot {
                Slot::Ready(env) => {
                    let s = env.stats();
                    Some((
                        format!("{}:{:016x}", k.net, k.env_fp),
                        Json::obj(vec![
                            ("net", Json::Str(k.net.clone())),
                            ("version", Json::Num(k.version as f64)),
                            ("env_fp", Json::Str(format!("{:016x}", k.env_fp))),
                            ("acc_fullp", Json::Num(env.acc_fullp)),
                            ("evals", Json::Num(s.evals as f64)),
                            ("cache_hits", Json::Num(s.cache_hits as f64)),
                            ("train_execs", Json::Num(s.train_execs as f64)),
                            ("eval_execs", Json::Num(s.eval_execs as f64)),
                            ("eval_batch_execs", Json::Num(s.eval_batch_execs as f64)),
                            ("batched_candidates", Json::Num(s.batched_candidates as f64)),
                            ("pad_lanes", Json::Num(s.pad_lanes as f64)),
                            ("memo_len", Json::Num(s.memo_len as f64)),
                            ("memo_hits", Json::Num(s.memo_hits as f64)),
                            ("memo_misses", Json::Num(s.memo_misses as f64)),
                            ("memo_evictions", Json::Num(s.memo_evictions as f64)),
                            ("spec_submitted", Json::Num(s.spec_submitted as f64)),
                            ("spec_hits", Json::Num(s.spec_hits as f64)),
                            ("spec_wasted", Json::Num(s.spec_wasted as f64)),
                        ]),
                    ))
                }
                _ => None,
            })
            .collect();
        Json::Obj(rows.into_iter().collect())
    }
}

/// The real execution backend: resolves jobs onto shared-core sessions and
/// runs the ReLeQ search through the PJRT engine.
pub struct SessionRunner {
    manifest: Manifest,
    engine: Arc<Engine>,
    sessions: SessionCache,
    archive: Arc<Archive>,
    /// network registry: resolves job nets to (possibly installed) versions
    registry: Arc<Registry>,
    /// version pins: `(logical net, env_fp)` → the resolved version the
    /// session at that fingerprint is bound to. Installed at prepare, read
    /// at run — the seam that keeps an in-flight job on its version when an
    /// upgrade lands in between. An entry holds a registry pin for the life
    /// of its session; it is released only when the session is poisoned
    /// (sessions are otherwise process-lifetime).
    pinned: RwLock<HashMap<(String, u64), Arc<NetVersion>>>,
    /// memo entries exported per job for archive warm-starts (top-k by
    /// recency; the scheduler's `memo_persist` bound)
    memo_persist: usize,
    /// search checkpoint directory (`--checkpoint-dir`); `None` = searches
    /// run without checkpoints
    checkpoint_dir: Option<PathBuf>,
    /// episodes between checkpoint writes (`--checkpoint-every`)
    checkpoint_every: usize,
    /// jobs that resumed from a valid checkpoint instead of starting fresh
    resumes: AtomicU64,
    /// checkpoint files written across all jobs
    checkpoint_saves: AtomicU64,
    /// checkpoint writes that failed (search unaffected)
    checkpoint_save_failures: AtomicU64,
    /// checkpoints refused at load (bad checksum, wrong fingerprint,
    /// newer schema) — the job started fresh instead
    checkpoint_rejects: AtomicU64,
}

impl SessionRunner {
    pub fn new(manifest: Manifest, engine: Arc<Engine>, archive: Arc<Archive>,
               memo_persist: usize, quarantine_k: u32, registry: Arc<Registry>)
               -> SessionRunner {
        SessionRunner {
            manifest,
            engine,
            sessions: SessionCache::with_quarantine(quarantine_k),
            archive,
            registry,
            pinned: RwLock::new(HashMap::new()),
            memo_persist,
            checkpoint_dir: None,
            checkpoint_every: 8,
            resumes: AtomicU64::new(0),
            checkpoint_saves: AtomicU64::new(0),
            checkpoint_save_failures: AtomicU64::new(0),
            checkpoint_rejects: AtomicU64::new(0),
        }
    }

    /// Enable durable searches: checkpoints land in `dir` (one file per
    /// `(net, search fingerprint)`) roughly every `every` episodes, on PPO
    /// update boundaries. A job finding a valid checkpoint for its
    /// fingerprint resumes bit-identically instead of restarting.
    pub fn with_checkpoints(mut self, dir: Option<PathBuf>, every: usize) -> SessionRunner {
        self.checkpoint_dir = dir;
        self.checkpoint_every = every.max(1);
        self
    }

    pub fn sessions(&self) -> &SessionCache {
        &self.sessions
    }

    /// Jobs resumed from a checkpoint since process start (test hook; also
    /// in the stats fragment).
    pub fn resumes(&self) -> u64 {
        self.resumes.load(Ordering::Relaxed)
    }

    /// The version pinned for `(net, env_fp)` — present for every prepared
    /// job (prepare always precedes run through the scheduler).
    fn pinned_version(&self, net: &str, env_fp: u64) -> Result<Arc<NetVersion>> {
        if let Some(v) = read_recover(&self.pinned).get(&(net.to_string(), env_fp)).cloned() {
            return Ok(v);
        }
        // defensive fallback (e.g. a runner driven outside the scheduler):
        // resolve fresh, pinning like prepare would
        let resolved = self.registry.resolve(net)?;
        self.pin(net, env_fp, &resolved);
        Ok(resolved)
    }

    /// Install a version pin for `(net, env_fp)` if none exists yet.
    fn pin(&self, net: &str, env_fp: u64, resolved: &Arc<NetVersion>) {
        let mut pinned = write_recover(&self.pinned);
        pinned.entry((net.to_string(), env_fp)).or_insert_with(|| {
            self.registry.pin(resolved);
            resolved.clone()
        });
    }

    /// The session for `(net, env_fp)` died for good: release its version
    /// pin (a superseded version whose last session drops gets its aliases
    /// evicted here).
    fn release_pin(&self, net: &str, env_fp: u64) {
        let removed = write_recover(&self.pinned).remove(&(net.to_string(), env_fp));
        if let Some(v) = removed {
            self.registry.unpin(&v);
        }
    }

    /// The search body: session resolution + the ReLeQ search. Split from
    /// [`JobRunner::run`] so the success/failure outcome can drive the
    /// session's quarantine bookkeeping in exactly one place.
    fn run_inner(&self, job: &Job, key: &SessionKey)
                 -> Result<(Solution, Vec<(Vec<u32>, f64)>)> {
        let spec = &job.spec;
        // the version this job was pinned to at prepare — NOT a fresh
        // resolve, which would hand an upgraded-mid-queue job the new
        // version's artifacts
        let resolved = self.pinned_version(&spec.net, job.env_fp)?;
        let net = &resolved.meta;
        // grow the shared engine's device pool to this job's request before
        // any session residency is built (grow-only and cheap when already
        // big enough; like memo_cap/eval_batch, `devices` is outside the env
        // fingerprint — a job never shrinks the pool under a concurrent job)
        self.engine.ensure_devices(spec.cfg.devices)?;
        let env = self.sessions.get_or_create(key.clone(), || {
            let env = QuantEnv::new(
                self.engine.clone(),
                net,
                self.manifest.bits_max,
                self.manifest.fp_bits,
                spec.cfg.env.clone(),
            )?;
            let warm = self.archive.memo_for(&spec.net, job.env_fp);
            if !warm.is_empty() {
                eprintln!(
                    "[serve] warm-starting {} session memo with {} archived entries",
                    spec.net,
                    warm.len()
                );
                env.memo().extend(warm);
            }
            Ok(env)
        })?;
        // memo_cap and eval_batch are deliberately outside the env
        // fingerprint (one bounds the cache, the other shapes execution
        // batches; neither changes accuracy values), so a job joining an
        // existing session keeps the session's settings — surface that
        // instead of silently dropping the request
        if env.memo().capacity() != spec.cfg.env.memo_cap {
            eprintln!(
                "[serve] job {}: memo_cap {} ignored — session already holds a memo \
                 bounded to {} (set at session creation)",
                job.id,
                spec.cfg.env.memo_cap,
                env.memo().capacity()
            );
        }
        // compare *resolved* widths, not raw knob values: eval_batch = 0
        // and an explicit eval_batch = 8 both resolve to the artifact's
        // baked width, and warning that 8 was "ignored" in favor of 8
        // would just confuse the operator
        if env.eval_batch_width() != env.eval_batch_width_for(spec.cfg.env.eval_batch) {
            eprintln!(
                "[serve] job {}: eval_batch {} ignored — session evaluates at width {} \
                 (set at session creation); concurrent jobs coalesce their accuracy \
                 misses into that session's shared megabatches regardless",
                job.id,
                spec.cfg.env.eval_batch,
                env.eval_batch_width()
            );
        }
        // a cancel during pretraining stops before the search starts
        job.ctl.check()?;

        let mut searcher =
            Searcher::with_env(env.clone(), self.engine.clone(), &self.manifest, spec.cfg.clone())
                .with_context(|| format!("building searcher for {}", spec.net))?;

        // durable searches: one checkpoint file per (net, search_fp). A
        // valid checkpoint for this exact fingerprint resumes the search
        // bit-identically; anything invalid (bad checksum, foreign
        // fingerprint, newer schema) is rejected and the job starts fresh —
        // a stale file must never be able to wedge a search.
        let mut durable = match &self.checkpoint_dir {
            Some(dir) => {
                let path =
                    dir.join(format!("{}.{:016x}.ckpt.json", spec.net, job.search_fp));
                let mut d =
                    Durable::new(path, self.checkpoint_every, &spec.net, job.search_fp)?;
                match SearchCheckpoint::load(&d.path) {
                    Ok(Some(ck)) => match searcher.restore(ck, &mut d) {
                        Ok(()) => {
                            self.resumes.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "[serve] job {}: resuming {} from checkpoint at episode {}",
                                job.id,
                                spec.net,
                                d.resumed_from.unwrap_or(0)
                            );
                        }
                        Err(e) => {
                            self.checkpoint_rejects.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "[serve] job {}: checkpoint rejected ({e:#}); starting fresh",
                                job.id
                            );
                        }
                    },
                    Ok(None) => {}
                    Err(e) => {
                        self.checkpoint_rejects.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[serve] job {}: checkpoint unreadable ({e:#}); starting fresh",
                            job.id
                        );
                    }
                }
                Some(d)
            }
            None => None,
        };
        let result = searcher.run_durable(&job.ctl, durable.as_mut());
        // account saves before propagating any error — an interrupted job's
        // final-flush checkpoint still counts
        if let Some(d) = &durable {
            self.checkpoint_saves.fetch_add(d.saves, Ordering::Relaxed);
            self.checkpoint_save_failures
                .fetch_add(d.save_failures, Ordering::Relaxed);
        }
        let result = result?;
        if let Some(d) = &mut durable {
            d.complete();
        }

        // Pareto view of everything this search visited: dedup episode
        // bits (accuracy is pure in bits, so later duplicates are
        // identical), then extract the frontier
        let mut seen: std::collections::BTreeMap<Vec<u32>, (f64, f64)> =
            std::collections::BTreeMap::new();
        for e in &result.log.episodes {
            seen.entry(e.bits.clone()).or_insert((e.state_q, e.state_acc));
        }
        let points: Vec<pareto::Point> = seen
            .into_iter()
            .map(|(bits, (state_q, state_acc))| pareto::Point { bits, state_q, state_acc })
            .collect();
        let frontier = pareto::pareto_frontier(&points);
        let pareto_pts: Vec<(f64, f64, Vec<u32>)> = frontier
            .into_iter()
            .map(|i| (points[i].state_q, points[i].state_acc, points[i].bits.clone()))
            .collect();

        let reward = result
            .log
            .rewards()
            .into_iter()
            .fold(f64::NEG_INFINITY, f64::max);
        let solution = Solution {
            bits: result.bits,
            avg_bits: result.avg_bits,
            acc_fullp: result.acc_fullp,
            acc_final: result.acc_final,
            acc_loss_pct: result.acc_loss_pct,
            state_q: result.state_q,
            reward: if reward.is_finite() { reward } else { 0.0 },
            episodes_run: result.episodes_run,
            pareto: pareto_pts,
        };
        // top-k by recency: the entries this search was actually
        // revisiting, already bounded to what the archive will persist
        Ok((solution, env.memo().entries_by_recency(self.memo_persist)))
    }
}

impl JobRunner for SessionRunner {
    fn prepare(&self, spec: &JobSpec) -> Result<(u64, u64)> {
        crate::config::validate_net_name(&spec.net)?;
        // resolve through the registry: newest installed version, else the
        // startup manifest. The *resolved* name feeds the fingerprints —
        // installed versions carry digest-qualified names, so each version
        // gets its own env/search fingerprints (and archive records), while
        // baseline networks keep fingerprints byte-identical to the
        // pre-registry daemon (resolved name == client name).
        let resolved = self.registry.resolve(&spec.net)?;
        anyhow::ensure!(spec.cfg.episodes >= 1, "job needs episodes >= 1");
        let bits_max = self.manifest.bits_max;
        let env_fp = env_fingerprint(&resolved.meta.name, bits_max, &spec.cfg.env);
        // a poisoned session 503s at submission — don't queue a job whose
        // environment is known-dead
        let key =
            SessionKey { net: spec.net.clone(), version: resolved.version, env_fp };
        if let Some(msg) = self.sessions.poisoned(&key) {
            return Err(FaultError::Permanent(msg).into());
        }
        let search_fp = search_fingerprint(&resolved.meta.name, bits_max, &spec.cfg);
        self.pin(&spec.net, env_fp, &resolved);
        Ok((env_fp, search_fp))
    }

    fn run(&self, job: &Job) -> Result<(Solution, Vec<(Vec<u32>, f64)>)> {
        let version = self
            .pinned_version(&job.spec.net, job.env_fp)
            .map(|v| v.version)
            .unwrap_or(1);
        let key = SessionKey { net: job.spec.net.clone(), version, env_fp: job.env_fp };
        match self.run_inner(job, &key) {
            Ok(out) => {
                self.sessions.record_success(&key);
                Ok(out)
            }
            Err(e) => {
                // a cancellation says nothing about the env's health; any
                // other failure counts against the session's streak
                if e.downcast_ref::<crate::coordinator::Cancelled>().is_none() {
                    let q = self.sessions.record_failure(&key, &format!("{e:#}"));
                    if q == Quarantine::Poisoned {
                        // the session is dead for good — drop its version
                        // pin so a superseded version can be evicted
                        self.release_pin(&job.spec.net, job.env_fp);
                    }
                }
                Err(e)
            }
        }
    }

    fn healthy(&self) -> bool {
        self.engine.health().is_healthy()
    }

    /// A fleet pull-merge landed new records: fold their memo entries into
    /// every LIVE session of the matching (net, env fingerprint). Sessions
    /// built later warm-start from the archive anyway (see `run_inner`);
    /// this hook closes the gap for sessions that were already running
    /// when the records arrived. Purity makes it safe: accuracy is a pure
    /// function of (env config, bits), so for entries both sides already
    /// hold, `AccMemo::extend`'s overwrite writes back the same value.
    fn absorb_archive(&self, archive: &Archive) {
        for (key, env) in self.sessions.ready_sessions() {
            let warm = archive.memo_for(&key.net, key.env_fp);
            if !warm.is_empty() {
                env.memo().extend(warm);
            }
        }
    }

    fn registry(&self) -> Option<Arc<Registry>> {
        Some(self.registry.clone())
    }

    fn stats(&self) -> Json {
        let loads = self.engine.device_loads();
        let healthy = self.engine.devices_healthy();
        Json::obj(vec![
            ("pretrains", Json::Num(self.sessions.pretrains() as f64)),
            ("quarantines", Json::Num(self.sessions.quarantines() as f64)),
            ("resumes", Json::Num(self.resumes.load(Ordering::Relaxed) as f64)),
            (
                "checkpoint_saves",
                Json::Num(self.checkpoint_saves.load(Ordering::Relaxed) as f64),
            ),
            (
                "checkpoint_save_failures",
                Json::Num(self.checkpoint_save_failures.load(Ordering::Relaxed) as f64),
            ),
            (
                "checkpoint_rejects",
                Json::Num(self.checkpoint_rejects.load(Ordering::Relaxed) as f64),
            ),
            ("poisoned_sessions", Json::Num(self.sessions.poisoned_count() as f64)),
            // pool-global counters: one fault plan / retry ledger shared by
            // every per-device client, so `exec_retries == faults_injected`
            // holds at any pool size (see `runtime::faults`)
            ("exec_retries", Json::Num(self.engine.exec_retries() as f64)),
            ("faults_injected", Json::Num(self.engine.faults_injected() as f64)),
            ("engine_healthy", Json::Bool(self.engine.health().is_healthy())),
            ("devices", Json::Num(self.engine.n_devices() as f64)),
            (
                "device_inflight",
                Json::Arr(loads.iter().map(|&l| Json::Num(l as f64)).collect()),
            ),
            (
                "device_healthy",
                Json::Arr(healthy.iter().map(|&h| Json::Bool(h)).collect()),
            ),
            ("sessions", self.sessions.stats_json()),
            // aggregate per-artifact rows: execs summed over devices, means
            // exec-weighted — so `total_execs`-style consumers keep summing
            // this array unchanged at any device count
            (
                "engine",
                Json::Arr(
                    self.engine
                        .exec_stats_agg()
                        .into_iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("artifact", Json::Str(s.name)),
                                ("execs", Json::Num(s.execs as f64)),
                                ("mean_exec_ms", Json::Num(s.mean_exec_ms)),
                                ("mean_download_ms", Json::Num(s.mean_download_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            // per-(artifact, device) split; `in_flight` is the row's
            // device-level in-flight depth at snapshot time (placement
            // signal, not a per-artifact queue)
            (
                "engine_devices",
                Json::Arr(
                    self.engine
                        .exec_stats()
                        .into_iter()
                        .map(|s| {
                            let inflight = loads.get(s.device).copied().unwrap_or(0);
                            Json::obj(vec![
                                ("artifact", Json::Str(s.name)),
                                ("device", Json::Num(s.device as f64)),
                                ("execs", Json::Num(s.execs as f64)),
                                ("mean_exec_ms", Json::Num(s.mean_exec_ms)),
                                ("mean_download_ms", Json::Num(s.mean_download_ms)),
                                ("in_flight", Json::Num(inflight as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::run_sharded;

    /// The single-flight protocol is testable without PJRT now that the
    /// cache is generic: race the leader election with a build that fails —
    /// every caller must observe the error, the key must unpin, and no
    /// slot may leak.
    #[test]
    fn failed_builds_unpin_the_key() {
        let cache: SessionCache<u32> = SessionCache::new();
        let key = SessionKey { net: "lenet".to_string(), version: 1, env_fp: 7 };
        let r = cache.get_or_create(key.clone(), || anyhow::bail!("no artifacts"));
        assert!(r.is_err());
        assert_eq!(cache.len(), 0, "failed build must not leave a Building slot");
        assert_eq!(cache.pretrains(), 0);
        // the key is retryable
        let r2 = cache.get_or_create(key, || anyhow::bail!("still no artifacts"));
        assert!(r2.is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn panicking_build_unpins_the_key() {
        let cache: SessionCache<u32> = SessionCache::new();
        let key = SessionKey { net: "lenet".to_string(), version: 1, env_fp: 3 };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = cache.get_or_create(key.clone(), || panic!("boom"));
        }));
        assert!(r.is_err());
        assert_eq!(cache.len(), 0, "panicked build must not leave a Building slot");
        // the key stays retryable
        assert!(cache.get_or_create(key, || anyhow::bail!("still failing")).is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn concurrent_failed_builds_never_wedge() {
        let cache = std::sync::Arc::new(SessionCache::<u32>::new());
        let results = run_sharded(vec![(); 8], |i, _| {
            let key = SessionKey { net: "lenet".to_string(), version: 1, env_fp: 1 };
            let r = cache.get_or_create(key, || anyhow::bail!("build {i} failed"));
            Ok(r.is_err())
        })
        .unwrap();
        assert!(results.into_iter().all(|failed| failed));
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn quarantine_evicts_then_rebuilds_then_poisons() {
        use crate::runtime::{classify, FaultClass};

        let cache: SessionCache<u32> = SessionCache::with_quarantine(2);
        let key = SessionKey { net: "lenet".to_string(), version: 1, env_fp: 9 };
        assert_eq!(cache.get_or_create(key.clone(), || Ok(1)).unwrap(), 1);
        assert_eq!(cache.pretrains(), 1);

        // below the threshold: retained, and success clears the streak
        assert_eq!(cache.record_failure(&key, "exec died"), Quarantine::Retained);
        cache.record_success(&key);
        assert_eq!(cache.record_failure(&key, "exec died"), Quarantine::Retained);

        // hit the threshold: first offense evicts, the env rebuilds once
        assert_eq!(cache.record_failure(&key, "exec died"), Quarantine::Evicted);
        assert_eq!(cache.len(), 0, "evicted env is gone");
        assert_eq!(cache.quarantines(), 1);
        assert_eq!(cache.get_or_create(key.clone(), || Ok(2)).unwrap(), 2, "rebuild happens");
        assert_eq!(cache.pretrains(), 2);

        // the rebuilt env failing K more times poisons the key for good
        assert_eq!(cache.record_failure(&key, "exec died"), Quarantine::Retained);
        assert_eq!(cache.record_failure(&key, "exec died"), Quarantine::Poisoned);
        assert_eq!(cache.poisoned_count(), 1);
        assert_eq!(cache.quarantines(), 2);
        let err = cache.get_or_create(key.clone(), || Ok(3)).unwrap_err();
        assert_eq!(classify(&err), FaultClass::Permanent, "poisoned key is a typed error");
        assert!(err.to_string().contains("poisoned"));
        assert_eq!(cache.pretrains(), 2, "no rebuild after poisoning");
        assert!(cache.poisoned(&key).is_some());
    }

    #[test]
    fn quarantine_zero_disables_the_protocol() {
        let cache: SessionCache<u32> = SessionCache::with_quarantine(0);
        let key = SessionKey { net: "lenet".to_string(), version: 1, env_fp: 1 };
        cache.get_or_create(key.clone(), || Ok(5)).unwrap();
        for _ in 0..32 {
            assert_eq!(cache.record_failure(&key, "exec died"), Quarantine::Retained);
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.quarantines(), 0);
    }

    #[test]
    fn failure_streaks_are_per_key() {
        let cache: SessionCache<u32> = SessionCache::with_quarantine(1);
        let a = SessionKey { net: "lenet".to_string(), version: 1, env_fp: 1 };
        let b = SessionKey { net: "vgg11".to_string(), version: 1, env_fp: 2 };
        cache.get_or_create(a.clone(), || Ok(1)).unwrap();
        cache.get_or_create(b.clone(), || Ok(2)).unwrap();
        assert_eq!(cache.record_failure(&a, "exec died"), Quarantine::Evicted);
        assert_eq!(cache.len(), 1, "only the failing key is evicted");
        assert!(cache.get_or_create(b, || Ok(9)).is_ok());
        assert_eq!(cache.pretrains(), 2, "the healthy key never rebuilt");
    }
}
