//! Dependency-free HTTP/1.1 plumbing for the serve daemon and the fleet
//! router (the build environment is offline — no hyper/axum; DESIGN.md §9).
//! JSON bodies only, via [`crate::util::json::Json`].
//!
//! Scope is deliberately narrow: request line + headers + `Content-Length`
//! body. No chunked transfer, no TLS — the daemon fronts a trusted
//! deployment pipeline on localhost, not the open internet. Hard limits
//! ([`MAX_BODY`], [`MAX_HEADERS`], [`MAX_LINE`]) bound what one connection
//! can make the daemon buffer.
//!
//! # Connection reuse
//!
//! Responses are always Content-Length framed, so a connection CAN carry
//! more than one exchange. A client that sends `Connection: keep-alive`
//! gets `Connection: keep-alive` back and may reuse the socket (bounded:
//! [`MAX_REQS_PER_CONN`] requests per connection, [`KEEPALIVE_IDLE`]
//! between them); the fleet router's per-worker [`Conn`] pool rides on
//! this — without it, router→worker latency is dominated by per-request
//! TCP setup. Absent the header, the connection closes after one exchange.
//! That default is deliberately NOT the HTTP/1.1 spec default (which is
//! keep-alive): every pre-fleet client of this daemon — curl sessions, the
//! smoke scripts, `examples/serve_client.rs` — speaks one-shot close, and
//! an external caller that never opts in must never be left holding a
//! half-open socket.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Maximum accepted request/response body (a job submission is < 1 KiB;
/// archive pages are chunked well below this — pure defense).
pub const MAX_BODY: usize = 1 << 20;
/// Maximum header lines read before giving up on a connection.
pub const MAX_HEADERS: usize = 64;
/// Maximum bytes in one request/status/header line — without this cap a
/// newline-free stream would grow `read_line`'s buffer without limit.
pub const MAX_LINE: usize = 8 << 10;
/// Requests served over one kept-alive connection before the server closes
/// it anyway (bounds how long one client can monopolize a handler thread).
pub const MAX_REQS_PER_CONN: u64 = 1024;
/// Idle budget between requests on a kept-alive connection. The FIRST
/// request gets the looser 30 s budget (same as the pre-keep-alive
/// daemon); once a client has opted into reuse it is expected to either
/// pipeline promptly or close.
pub const KEEPALIVE_IDLE: Duration = Duration::from_secs(10);
/// Read timeout for the one-shot client helpers.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(600);

/// `read_line` with the [`MAX_LINE`] bound: reads through a `Take` so a
/// pathological sender can't buffer more than the cap.
fn read_line_capped<R: BufRead>(r: &mut R, line: &mut String) -> Result<usize> {
    let n = r
        .take(MAX_LINE as u64 + 1)
        .read_line(line)
        .context("reading line")?;
    anyhow::ensure!(n <= MAX_LINE, "line exceeds {MAX_LINE} bytes");
    Ok(n)
}

/// The header subset both sides of this module care about.
#[derive(Debug, Default)]
struct Headers {
    content_len: Option<usize>,
    /// `Some(true)` for `Connection: keep-alive`, `Some(false)` for
    /// `Connection: close`, `None` when the header is absent.
    connection: Option<bool>,
}

/// Scan the header section up to the blank line. Shared by the server
/// parser and the client helpers so the two sides cannot drift. EOF before
/// the blank line is tolerated only for header-only messages (no
/// content-length).
fn read_headers<R: BufRead>(r: &mut R) -> Result<Headers> {
    let mut line = String::new();
    let mut h = Headers::default();
    for _ in 0..MAX_HEADERS {
        line.clear();
        if read_line_capped(r, &mut line)? == 0 {
            break; // EOF
        }
        let t = line.trim_end();
        if t.is_empty() {
            return Ok(h);
        }
        if let Some((k, v)) = t.split_once(':') {
            let k = k.trim();
            if k.eq_ignore_ascii_case("content-length") {
                h.content_len = Some(
                    v.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad content-length `{}`", v.trim()))?,
                );
            } else if k.eq_ignore_ascii_case("connection") {
                h.connection = Some(v.trim().eq_ignore_ascii_case("keep-alive"));
            }
        }
    }
    anyhow::ensure!(
        h.content_len.is_none(),
        "header section exceeds {MAX_HEADERS} lines"
    );
    Ok(h)
}

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// the client sent `Connection: keep-alive` (absent header = close;
    /// see the module docs for why that inverts the HTTP/1.1 default)
    pub keep_alive: bool,
}

impl Request {
    /// Decode the body as JSON; an empty body decodes to `Json::Null`.
    pub fn json(&self) -> Result<Json> {
        if self.body.is_empty() {
            return Ok(Json::Null);
        }
        let text = std::str::from_utf8(&self.body).context("request body is not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow::anyhow!("request body: {e}"))
    }

    /// Decoded `?key=value&...` query pairs (no percent-decoding — the
    /// daemon's cursors and limits are plain `[a-zA-Z0-9:._-]` tokens).
    pub fn query(&self) -> std::collections::BTreeMap<String, String> {
        let mut q = std::collections::BTreeMap::new();
        if let Some((_, qs)) = self.path.split_once('?') {
            for pair in qs.split('&') {
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                q.insert(k.to_string(), v.to_string());
            }
        }
        q
    }
}

/// Read one request off a buffered stream. `Ok(None)` on a clean EOF
/// before any request byte — the peer closing a kept-alive connection
/// between requests is normal, not an error. Fails (closing the
/// connection) on a malformed request line, an oversized body, or header
/// overflow.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>> {
    let mut line = String::new();
    if read_line_capped(r, &mut line).context("reading request line")? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let path = parts.next().context("request line has no path")?.to_string();
    let version = parts.next().context("request line has no version")?;
    anyhow::ensure!(version.starts_with("HTTP/1."), "unsupported version `{version}`");

    let headers = read_headers(r)?;
    let content_len = headers.content_len.unwrap_or(0);
    anyhow::ensure!(content_len <= MAX_BODY, "body of {content_len} bytes exceeds {MAX_BODY}");

    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading request body")?;
    Ok(Some(Request { method, path, body, keep_alive: headers.connection == Some(true) }))
}

/// One JSON response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: Json,
}

impl Response {
    pub fn ok(body: Json) -> Response {
        Response { status: 200, body }
    }

    pub fn status(status: u16, body: Json) -> Response {
        Response { status, body }
    }

    /// Error envelope: `{"error": msg}` under the given status.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::status(status, Json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    /// Serialize with the given connection disposition; returns the body
    /// byte count (the access log's `bytes` field).
    pub fn write<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<usize> {
        let body = self.body.dump();
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
            self.status,
            reason(self.status),
            body.len(),
            if keep_alive { "keep-alive" } else { "close" },
            body
        )?;
        w.flush()?;
        Ok(body.len())
    }

    /// One-shot serialization (`Connection: close`) — the pre-keep-alive
    /// wire format, byte for byte.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        self.write(w, false).map(|_| ())
    }
}

/// Reason phrase for the handful of statuses the daemon emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// One structured access-log line (JSON, sorted keys): method, path,
/// status, body bytes, wall latency, and — when the response body carries
/// a `worker` field (fleet submissions) — the worker the request was
/// routed to. Shared by the serve daemon and the fleet router so the two
/// log streams grep identically.
pub fn access_log_line(
    tag: &str, method: &str, path: &str, status: u16, bytes: usize, latency_ms: f64,
    worker: Option<&str>,
) -> String {
    let ts_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0);
    let mut fields = vec![
        ("ts_ms", Json::Num(ts_ms)),
        ("tag", Json::Str(tag.to_string())),
        ("method", Json::Str(method.to_string())),
        ("path", Json::Str(path.to_string())),
        ("status", Json::Num(status as f64)),
        ("bytes", Json::Num(bytes as f64)),
        ("latency_ms", Json::Num((latency_ms * 1000.0).round() / 1000.0)),
    ];
    if let Some(w) = worker {
        fields.push(("worker", Json::Str(w.to_string())));
    }
    Json::obj(fields).dump()
}

/// What one connection did, reported back to the accept loop.
pub struct ConnStats {
    /// requests served (each got a response, including error responses)
    pub served: u64,
    /// a handler asked the accept loop to exit (completed shutdown)
    pub exit: bool,
}

/// Serve one connection to completion: read requests, dispatch each
/// through `route`, write responses honoring the client's keep-alive
/// opt-in. Both the serve daemon and the fleet router run their accept
/// threads through this one loop, so framing, reuse bounds, and access
/// logging cannot drift between them.
pub fn serve_conn<F>(stream: TcpStream, access_log: bool, tag: &str, mut route: F) -> ConnStats
where
    F: FnMut(&Request) -> (Response, bool),
{
    let mut st = ConnStats { served: 0, exit: false };
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let Ok(read_half) = stream.try_clone() else { return st };
    let mut reader = BufReader::new(read_half);
    let mut w = stream;
    loop {
        let t0 = Instant::now();
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => break, // peer closed cleanly between requests
            Err(_) if st.served > 0 => break, // idle timeout / partial request on a reused conn
            Err(e) => {
                let resp = Response::error(400, &format!("{e:#}"));
                let n = resp.write(&mut w, false);
                st.served += 1;
                if access_log {
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    eprintln!("{}", access_log_line(tag, "-", "-", 400, n.unwrap_or(0), ms, None));
                }
                break;
            }
        };
        let (resp, exit) = route(&req);
        st.served += 1;
        let keep = req.keep_alive && !exit && st.served < MAX_REQS_PER_CONN;
        let wrote = resp.write(&mut w, keep);
        if access_log {
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let worker = resp.body.get("worker").and_then(Json::as_str);
            eprintln!(
                "{}",
                access_log_line(
                    tag,
                    &req.method,
                    &req.path,
                    resp.status,
                    wrote.as_ref().copied().unwrap_or(0),
                    ms,
                    worker
                )
            );
        }
        if exit {
            st.exit = true;
            break;
        }
        if !keep || wrote.is_err() {
            break;
        }
        // tighter budget between requests on a reused connection — the
        // timeout is a socket option, shared with the reader's dup'd fd
        let _ = w.set_read_timeout(Some(KEEPALIVE_IDLE));
    }
    st
}

/// Read one framed response: status code, decoded JSON body, and whether
/// the server committed to keeping the connection open (keep-alive header
/// AND a Content-Length frame — an unframed body is delimited by close).
fn read_response<R: BufRead>(r: &mut R) -> Result<(u16, Json, bool)> {
    let mut line = String::new();
    read_line_capped(r, &mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line `{}`", line.trim_end()))?;
    let headers = read_headers(r)?;
    let body = match headers.content_len {
        Some(n) => {
            anyhow::ensure!(n <= MAX_BODY, "response body too large");
            let mut b = vec![0u8; n];
            r.read_exact(&mut b)?;
            b
        }
        None => {
            let mut b = Vec::new();
            r.read_to_end(&mut b)?;
            b
        }
    };
    let keep = headers.connection == Some(true) && headers.content_len.is_some();
    if body.is_empty() {
        return Ok((status, Json::Null, keep));
    }
    let text = std::str::from_utf8(&body).context("response body is not UTF-8")?;
    let json = Json::parse(text).map_err(|e| anyhow::anyhow!("response body: {e}"))?;
    Ok((status, json, keep))
}

/// Minimal blocking client: one request, one connection
/// (`Connection: close`). Returns the status code and the decoded JSON
/// body (`Json::Null` for an empty body). Used by
/// `examples/serve_client.rs` and the integration tests; production
/// clients can use anything that speaks HTTP (see README for the curl
/// session).
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    request_timeout(addr, method, path, body, CLIENT_TIMEOUT)
}

/// [`request`] with an explicit connect/read budget — the fleet health
/// monitor polls with a short one so a hung worker costs milliseconds,
/// not the default ten minutes.
pub fn request_timeout(
    addr: &str, method: &str, path: &str, body: Option<&Json>, timeout: Duration,
) -> Result<(u16, Json)> {
    let mut stream = match addr.parse::<SocketAddr>() {
        Ok(sa) => TcpStream::connect_timeout(&sa, timeout)
            .with_context(|| format!("connecting to {addr}"))?,
        Err(_) => TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?,
    };
    stream.set_read_timeout(Some(timeout))?;
    let body = body.map(|j| j.dump()).unwrap_or_default();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut r = BufReader::new(stream);
    let (status, json, _keep) = read_response(&mut r)?;
    Ok((status, json))
}

/// A persistent keep-alive client connection — the router's per-worker
/// transport. Requests go out with `Connection: keep-alive`; the
/// connection stays reusable until the server declines (responds close /
/// unframed) or the [`MAX_REQS_PER_CONN`] bound is reached. NOT
/// thread-safe by design: the fleet pools `Conn`s behind a mutex and
/// checks one out per request.
pub struct Conn {
    reader: BufReader<TcpStream>,
    addr: String,
    sent: u64,
    reusable: bool,
}

impl Conn {
    pub fn connect(addr: &str) -> Result<Conn> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_read_timeout(Some(CLIENT_TIMEOUT))?;
        let _ = stream.set_nodelay(true);
        Ok(Conn { reader: BufReader::new(stream), addr: addr.to_string(), sent: 0, reusable: true })
    }

    /// One request/response exchange on this connection. Any error marks
    /// the connection non-reusable: a failed exchange leaves the stream at
    /// an unknown framing position, and reusing it would desynchronize
    /// every later response.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&Json>)
                   -> Result<(u16, Json)> {
        anyhow::ensure!(self.reusable, "connection to {} is no longer reusable", self.addr);
        self.reusable = false;
        let body = body.map(|j| j.dump()).unwrap_or_default();
        let stream = self.reader.get_mut();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            self.addr,
            body.len()
        )?;
        stream.flush()?;
        self.sent += 1;
        let (status, json, server_keeps) = read_response(&mut self.reader)?;
        self.reusable = server_keeps && self.sent < MAX_REQS_PER_CONN;
        Ok((status, json))
    }

    /// Requests sent over this one socket (the keep-alive reuse test's
    /// witness).
    pub fn requests_sent(&self) -> u64 {
        self.sent
    }

    /// Can this connection carry another request?
    pub fn is_reusable(&self) -> bool {
        self.reusable
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = "POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{\"net\":\"lenet\"}";
        let req = read_request(&mut Cursor::new(raw)).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/jobs");
        assert_eq!(req.json().unwrap().s("net"), "lenet");
        assert!(!req.keep_alive, "absent Connection header means close");
    }

    #[test]
    fn parses_bodyless_request() {
        let raw = "GET /v1/stats HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw)).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert_eq!(req.json().unwrap(), Json::Null);
    }

    #[test]
    fn keep_alive_header_is_parsed_case_insensitively() {
        let raw = "GET /v1/stats HTTP/1.1\r\nConnection: Keep-Alive\r\n\r\n";
        assert!(read_request(&mut Cursor::new(raw)).unwrap().unwrap().keep_alive);
        let raw = "GET /v1/stats HTTP/1.1\r\nconnection: close\r\n\r\n";
        assert!(!read_request(&mut Cursor::new(raw)).unwrap().unwrap().keep_alive);
    }

    #[test]
    fn eof_before_a_request_is_none_not_an_error() {
        assert!(read_request(&mut Cursor::new("")).unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(read_request(&mut Cursor::new("\r\n")).is_err());
        assert!(read_request(&mut Cursor::new("GET\r\n\r\n")).is_err());
        assert!(read_request(&mut Cursor::new("GET /x SPDY/3\r\n\r\n")).is_err());
        assert!(read_request(&mut Cursor::new(
            "POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n"
        ))
        .is_err());
        let oversized = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(read_request(&mut Cursor::new(oversized)).is_err());
        // a newline-free request line must hit the MAX_LINE cap, not grow
        // the buffer until the stream ends
        let endless = "G".repeat(MAX_LINE + 100);
        assert!(read_request(&mut Cursor::new(endless)).is_err());
        // ... and an oversized header line likewise
        let long_header =
            format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "p".repeat(MAX_LINE + 10));
        assert!(read_request(&mut Cursor::new(long_header)).is_err());
        // declared body longer than the stream
        assert!(read_request(&mut Cursor::new(
            "POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"
        ))
        .is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::error(429, "queue full").write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Type: application/json"));
        assert!(text.contains("Connection: close"));
        assert!(text.ends_with("{\"error\":\"queue full\"}"));
        let body_len = "{\"error\":\"queue full\"}".len();
        assert!(text.contains(&format!("Content-Length: {body_len}")));

        // the keep-alive variant differs only in the Connection header and
        // reports the body length back for the access log
        let mut out = Vec::new();
        let n = Response::error(429, "queue full").write(&mut out, true).unwrap();
        assert_eq!(n, body_len);
        assert!(String::from_utf8(out).unwrap().contains("Connection: keep-alive"));
    }

    #[test]
    fn query_pairs_parse() {
        let req = Request {
            method: "GET".into(),
            path: "/v1/jobs?limit=2&cursor=abc:01".into(),
            body: Vec::new(),
            keep_alive: false,
        };
        let q = req.query();
        assert_eq!(q.get("limit").map(String::as_str), Some("2"));
        assert_eq!(q.get("cursor").map(String::as_str), Some("abc:01"));
        let bare = Request {
            method: "GET".into(),
            path: "/v1/jobs".into(),
            body: Vec::new(),
            keep_alive: false,
        };
        assert!(bare.query().is_empty());
    }

    #[test]
    fn access_log_line_is_one_json_object() {
        let line = access_log_line("serve", "POST", "/v1/jobs", 202, 64, 1.25, Some("w1"));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.s("method"), "POST");
        assert_eq!(j.u("status"), 202);
        assert_eq!(j.u("bytes"), 64);
        assert_eq!(j.s("worker"), "w1");
        assert!(j.f("latency_ms") >= 0.0);
        // no worker field when none was routed
        let j = Json::parse(&access_log_line("serve", "GET", "/v1/stats", 200, 8, 0.1, None))
            .unwrap();
        assert!(j.get("worker").is_none());
    }

    /// End-to-end keep-alive over a real socket: N requests on ONE client
    /// connection, one server-side connection loop serving all of them.
    #[test]
    fn keep_alive_reuses_one_socket() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            serve_conn(stream, false, "test", |req| {
                (Response::ok(Json::obj(vec![("path", Json::Str(req.path.clone()))])), false)
            })
        });
        let mut conn = Conn::connect(&addr).unwrap();
        for i in 0..5 {
            let (status, body) = conn.request("GET", &format!("/ping/{i}"), None).unwrap();
            assert_eq!(status, 200);
            assert_eq!(body.s("path"), format!("/ping/{i}"));
            assert!(conn.is_reusable());
        }
        assert_eq!(conn.requests_sent(), 5);
        drop(conn); // clean close ends the server loop
        let st = server.join().unwrap();
        assert_eq!(st.served, 5, "one connection served every request");
        assert!(!st.exit);
    }

    /// A close-mode client (the one-shot helper) against the same loop:
    /// exactly one request per connection, like the pre-fleet daemon.
    #[test]
    fn close_mode_clients_get_one_exchange() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            serve_conn(stream, false, "test", |_req| (Response::ok(Json::Null), false))
        });
        let (status, _) = request(&addr, "GET", "/once", None).unwrap();
        assert_eq!(status, 200);
        let st = server.join().unwrap();
        assert_eq!(st.served, 1);
    }
}
