//! Dependency-free HTTP/1.1 plumbing for the serve daemon (the build
//! environment is offline — no hyper/axum; DESIGN.md §9). One request per
//! connection (`Connection: close`), JSON bodies only, via
//! [`crate::util::json::Json`].
//!
//! Scope is deliberately narrow: request line + headers + `Content-Length`
//! body. No chunked transfer, no keep-alive, no TLS — the daemon fronts a
//! trusted deployment pipeline on localhost, not the open internet. Hard
//! limits ([`MAX_BODY`], [`MAX_HEADERS`], [`MAX_LINE`]) bound what one
//! connection can make the daemon buffer.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Maximum accepted request/response body (a job submission is < 1 KiB;
/// this is pure defense).
pub const MAX_BODY: usize = 1 << 20;
/// Maximum header lines read before giving up on a connection.
pub const MAX_HEADERS: usize = 64;
/// Maximum bytes in one request/status/header line — without this cap a
/// newline-free stream would grow `read_line`'s buffer without limit.
pub const MAX_LINE: usize = 8 << 10;

/// `read_line` with the [`MAX_LINE`] bound: reads through a `Take` so a
/// pathological sender can't buffer more than the cap.
fn read_line_capped<R: BufRead>(r: &mut R, line: &mut String) -> Result<usize> {
    let n = r
        .take(MAX_LINE as u64 + 1)
        .read_line(line)
        .context("reading line")?;
    anyhow::ensure!(n <= MAX_LINE, "line exceeds {MAX_LINE} bytes");
    Ok(n)
}

/// Scan the header section up to the blank line, returning the
/// `Content-Length` value if present. Shared by the server parser and the
/// test/example client so the two sides cannot drift. EOF before the blank
/// line is tolerated only for header-only messages (no content-length).
fn read_headers<R: BufRead>(r: &mut R) -> Result<Option<usize>> {
    let mut line = String::new();
    let mut content_len: Option<usize> = None;
    for _ in 0..MAX_HEADERS {
        line.clear();
        if read_line_capped(r, &mut line)? == 0 {
            break; // EOF
        }
        let t = line.trim_end();
        if t.is_empty() {
            return Ok(content_len);
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = Some(
                    v.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad content-length `{}`", v.trim()))?,
                );
            }
        }
    }
    anyhow::ensure!(
        content_len.is_none(),
        "header section exceeds {MAX_HEADERS} lines"
    );
    Ok(None)
}

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

impl Request {
    /// Decode the body as JSON; an empty body decodes to `Json::Null`.
    pub fn json(&self) -> Result<Json> {
        if self.body.is_empty() {
            return Ok(Json::Null);
        }
        let text = std::str::from_utf8(&self.body).context("request body is not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow::anyhow!("request body: {e}"))
    }
}

/// Read one request off a buffered stream. Fails (closing the connection)
/// on a malformed request line, an oversized body, or header overflow.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request> {
    let mut line = String::new();
    read_line_capped(r, &mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let path = parts.next().context("request line has no path")?.to_string();
    let version = parts.next().context("request line has no version")?;
    anyhow::ensure!(version.starts_with("HTTP/1."), "unsupported version `{version}`");

    let content_len = read_headers(r)?.unwrap_or(0);
    anyhow::ensure!(content_len <= MAX_BODY, "body of {content_len} bytes exceeds {MAX_BODY}");

    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading request body")?;
    Ok(Request { method, path, body })
}

/// One JSON response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: Json,
}

impl Response {
    pub fn ok(body: Json) -> Response {
        Response { status: 200, body }
    }

    pub fn status(status: u16, body: Json) -> Response {
        Response { status, body }
    }

    /// Error envelope: `{"error": msg}` under the given status.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::status(status, Json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let body = self.body.dump();
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            self.status,
            reason(self.status),
            body.len(),
            body
        )?;
        w.flush()
    }
}

/// Reason phrase for the handful of statuses the daemon emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// Minimal blocking client: one request, one connection. Returns the status
/// code and the decoded JSON body (`Json::Null` for an empty body). Used by
/// `examples/serve_client.rs` and the integration tests; production clients
/// can use anything that speaks HTTP (see README for the curl session).
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(600)))?;
    let body = body.map(|j| j.dump()).unwrap_or_default();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;

    let mut r = BufReader::new(stream);
    let mut line = String::new();
    read_line_capped(&mut r, &mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line `{}`", line.trim_end()))?;
    let body = match read_headers(&mut r)? {
        Some(n) => {
            anyhow::ensure!(n <= MAX_BODY, "response body too large");
            let mut b = vec![0u8; n];
            r.read_exact(&mut b)?;
            b
        }
        None => {
            let mut b = Vec::new();
            r.read_to_end(&mut b)?;
            b
        }
    };
    if body.is_empty() {
        return Ok((status, Json::Null));
    }
    let text = std::str::from_utf8(&body).context("response body is not UTF-8")?;
    let json = Json::parse(text).map_err(|e| anyhow::anyhow!("response body: {e}"))?;
    Ok((status, json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = "POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{\"net\":\"lenet\"}";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/jobs");
        assert_eq!(req.json().unwrap().s("net"), "lenet");
    }

    #[test]
    fn parses_bodyless_request() {
        let raw = "GET /v1/stats HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert_eq!(req.json().unwrap(), Json::Null);
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(read_request(&mut Cursor::new("\r\n")).is_err());
        assert!(read_request(&mut Cursor::new("GET\r\n\r\n")).is_err());
        assert!(read_request(&mut Cursor::new("GET /x SPDY/3\r\n\r\n")).is_err());
        assert!(read_request(&mut Cursor::new(
            "POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n"
        ))
        .is_err());
        let oversized = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(read_request(&mut Cursor::new(oversized)).is_err());
        // a newline-free request line must hit the MAX_LINE cap, not grow
        // the buffer until the stream ends
        let endless = "G".repeat(MAX_LINE + 100);
        assert!(read_request(&mut Cursor::new(endless)).is_err());
        // ... and an oversized header line likewise
        let long_header =
            format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "p".repeat(MAX_LINE + 10));
        assert!(read_request(&mut Cursor::new(long_header)).is_err());
        // declared body longer than the stream
        assert!(read_request(&mut Cursor::new(
            "POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"
        ))
        .is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::error(429, "queue full").write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Type: application/json"));
        assert!(text.ends_with("{\"error\":\"queue full\"}"));
        let body_len = "{\"error\":\"queue full\"}".len();
        assert!(text.contains(&format!("Content-Length: {body_len}")));
    }
}
