//! Sharded parallel driver: a scoped-thread worker pool with deterministic
//! merge order, plus the shared concurrent accuracy memo-cache.
//!
//! ReLeQ's wall-clock cost is thousands of small PJRT executions; several of
//! the surrounding loops are embarrassingly parallel once the engine is
//! `Send + Sync`:
//!
//! * Pareto `enumerate` — the assignment list splits into contiguous chunks
//!   evaluated against one shared-core `QuantEnv`, accuracies deduplicated
//!   through [`AccMemo`];
//! * multi-seed search replicas — independent `Searcher`s per seed over one
//!   shared pretrained env core;
//! * the per-step accuracy fan-out of the lockstep batched rollout
//!   (`coordinator::rollout`);
//! * the per-network loop in `examples/e2e_releq.rs`.
//!
//! Design rules (EXPERIMENTS.md §Perf):
//! * shards share one immutable post-pretrain `EnvCore` (`Arc`), one
//!   `Engine`, and one [`AccMemo`]; everything mutable on the hot path is an
//!   atomic or behind the memo's single-flight protocol;
//! * results merge in **shard-index order**, never completion order, so a
//!   sharded run reports the same sequence regardless of thread scheduling;
//! * shard count comes from `RELEQ_SHARDS` when set, else
//!   `available_parallelism` clamped to the number of work units.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use anyhow::Result;

use crate::util::lock::{lock_recover, write_recover};

/// Number of shards to use for `n_units` independent units of work:
/// `RELEQ_SHARDS` if set (>= 1), else `available_parallelism`, clamped to
/// `n_units` so no shard is empty.
pub fn default_shards(n_units: usize) -> usize {
    let hw = std::env::var("RELEQ_SHARDS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    hw.min(n_units.max(1))
}

/// Split `items` into `n` contiguous chunks whose sizes differ by at most 1
/// (the first `len % n` chunks get the extra element). Order is preserved, so
/// concatenating the chunks reproduces `items` exactly — the invariant the
/// deterministic merge relies on.
pub fn chunk_evenly<T>(items: Vec<T>, n: usize) -> Vec<Vec<T>> {
    let n = n.max(1);
    let len = items.len();
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n.min(len.max(1)));
    let mut it = items.into_iter();
    for i in 0..n {
        let take = base + usize::from(i < extra);
        if take == 0 {
            continue;
        }
        out.push(it.by_ref().take(take).collect());
    }
    out
}

/// Round-robin-stripe `items` across `n` lanes, tagging each item with its
/// original index: lane `d` receives items `d, d+n, d+2n, ...` in order.
/// This is the device-pool work distribution — chunk `i` of a megabatch
/// always lands on device `i % n` regardless of pool load, so the lane
/// contents (and therefore which device executes which chunk) are a pure
/// function of the item count. The retained indices let the caller merge
/// per-lane results back into original order deterministically. Empty lanes
/// are kept (the result always has exactly `n` lanes).
pub fn stripe_evenly<T>(items: Vec<T>, n: usize) -> Vec<Vec<(usize, T)>> {
    let n = n.max(1);
    let mut lanes: Vec<Vec<(usize, T)>> = (0..n).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        lanes[i % n].push((i, item));
    }
    lanes
}

/// Fan `shards` out across scoped worker threads and merge the results in
/// shard-index order. `worker(shard_index, shard)` runs on its own thread;
/// the merge is deterministic: element `i` of the returned vec is shard `i`'s
/// result no matter which thread finished first. On failure the error of the
/// lowest-indexed failing shard is returned (also deterministic).
///
/// A single shard runs inline on the caller's thread — no pool overhead for
/// the sequential case.
pub fn run_sharded<T, R, F>(shards: Vec<T>, worker: F) -> Result<Vec<R>>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> Result<R> + Sync,
{
    if shards.len() <= 1 {
        return shards
            .into_iter()
            .enumerate()
            .map(|(i, s)| worker(i, s))
            .collect();
    }
    let results: Vec<Result<R>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| scope.spawn({ let worker = &worker; move || worker(i, shard) }))
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                // downcast the payload: `{:?}` on Box<dyn Any> prints only
                // "Any { .. }", losing the actual panic message
                Err(panic) => {
                    let msg = panic
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| panic.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    Err(anyhow::anyhow!("shard worker panicked: {msg}"))
                }
            })
            .collect()
    });
    results.into_iter().collect()
}

/// Shared concurrent accuracy memo-cache: bitwidth vector -> validation
/// accuracy, shared across shards so one shard's evaluation saves every
/// other shard the PJRT executions for the same assignment.
///
/// Lookups are **single-flight** via [`AccMemo::get_or_compute`]: the first
/// caller to miss on a key becomes the leader and computes it; concurrent
/// callers for the same key block on the leader's in-flight entry instead of
/// duplicating the PJRT evaluation (the pre-single-flight behavior was
/// "both compute, last write wins"). If the leader's computation fails, the
/// in-flight entry is removed and exactly one waiter retries as the new
/// leader, so a transient failure never wedges the key.
///
/// Hit/miss counters are global (atomics); per-env accounting stays in
/// `EnvStats`.
///
/// # Bounding
///
/// A long-running process (the `releq serve` daemon) would otherwise grow
/// the memo without limit — every distinct bits vector ever evaluated stays
/// resident. [`AccMemo::with_capacity`] bounds the number of **finished**
/// entries; when an insert pushes the map past the bound, the
/// least-recently-touched quarter of the finished entries is evicted in one
/// batch (coarse LRU: reads stamp a monotone touch tick under the shared
/// read lock, so the hit path never takes the write lock). In-flight
/// entries are never evicted — a leader's followers must always find their
/// flight. `capacity == 0` means unbounded (the one-shot CLI default
/// before PR 3; searches touch far fewer vectors than the daemon bound).
pub struct AccMemo {
    map: RwLock<HashMap<Vec<u32>, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// bound on finished entries (0 = unbounded)
    cap: usize,
    /// monotone clock for the coarse-LRU touch stamps
    tick: AtomicU64,
}

impl Default for AccMemo {
    fn default() -> AccMemo {
        AccMemo::with_capacity(0)
    }
}

/// Cache slot: a finished value (with its last-touch tick), or a leader's
/// in-flight computation that followers wait on.
enum Slot {
    Done { v: f64, touched: AtomicU64 },
    InFlight(Arc<Flight>),
}

/// Rendezvous for one in-flight computation. `result` transitions
/// None -> Some(outcome) exactly once; `Some(None)` means the leader failed
/// and waiters must retry.
#[derive(Default)]
struct Flight {
    result: Mutex<Option<Option<f64>>>,
    cv: Condvar,
}

impl Flight {
    /// Poison-tolerant on both sides: `finish` runs from Drop guards during
    /// panic unwinds (an `unwrap` there would double-panic and abort) and
    /// `wait` must keep serving followers after such a leader death.
    fn finish(&self, outcome: Option<f64>) {
        *lock_recover(&self.result) = Some(outcome);
        self.cv.notify_all();
    }

    fn wait(&self) -> Option<f64> {
        let mut g = lock_recover(&self.result);
        while g.is_none() {
            g = match self.cv.wait(g) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        g.unwrap()
    }
}

impl AccMemo {
    /// Unbounded memo (one-shot search runs; see [`AccMemo::with_capacity`]).
    pub fn new() -> AccMemo {
        AccMemo::default()
    }

    /// Memo bounded to `cap` finished entries (`0` = unbounded).
    pub fn with_capacity(cap: usize) -> AccMemo {
        AccMemo {
            map: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            cap,
            tick: AtomicU64::new(0),
        }
    }

    /// Next touch-clock value (monotone; relaxed is fine — ties only blur
    /// the eviction order, never correctness).
    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn touch(&self, touched: &AtomicU64) {
        touched.store(self.next_tick(), Ordering::Relaxed);
    }

    fn done(&self, v: f64) -> Slot {
        Slot::Done { v, touched: AtomicU64::new(self.next_tick()) }
    }

    /// Enforce the capacity bound; call with the write lock held, after an
    /// insert. Evicts the least-recently-touched finished entries in one
    /// batch down to 3/4 of capacity, so the O(n) scan amortizes over the
    /// next cap/4 inserts. In-flight entries are exempt.
    fn evict_excess(&self, m: &mut HashMap<Vec<u32>, Slot>) {
        if self.cap == 0 || m.len() <= self.cap {
            return;
        }
        let n_done = m.values().filter(|s| matches!(s, Slot::Done { .. })).count();
        let target = self.cap - self.cap / 4;
        if n_done <= target {
            return;
        }
        let mut ages: Vec<(u64, Vec<u32>)> = m
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Done { touched, .. } => Some((touched.load(Ordering::Relaxed), k.clone())),
                Slot::InFlight(_) => None,
            })
            .collect();
        // (tick, key) sort: deterministic even on touch-tick ties
        ages.sort_unstable();
        let n_evict = n_done - target;
        for (_, k) in ages.into_iter().take(n_evict) {
            m.remove(&k);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Non-blocking lookup of a finished value (counts a hit or a miss).
    /// An in-flight computation by another thread counts as a miss — use
    /// [`AccMemo::get_or_compute`] to coalesce with it instead.
    pub fn get(&self, bits: &[u32]) -> Option<f64> {
        let got = match self.map.read().unwrap().get(bits) {
            Some(Slot::Done { v, touched }) => {
                self.touch(touched);
                Some(*v)
            }
            _ => None,
        };
        match got {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Counter-free peek: is a finished value cached for `bits`? (The
    /// lockstep driver uses this to split a batch into hits and misses
    /// without skewing the hit/miss statistics.)
    pub fn contains(&self, bits: &[u32]) -> bool {
        matches!(self.map.read().unwrap().get(bits), Some(Slot::Done { .. }))
    }

    /// Single-flight lookup-or-compute. Returns `(value, was_cached)`:
    /// `was_cached` is true when the value was served without running
    /// `compute` on this thread (a finished entry, or another thread's
    /// in-flight result we waited for).
    pub fn get_or_compute<F>(&self, bits: &[u32], mut compute: F) -> Result<(f64, bool)>
    where
        F: FnMut() -> Result<f64>,
    {
        /// Unwinding/error guard for the leader: while armed, dropping it
        /// removes the in-flight slot and wakes waiters with "failed" so a
        /// panicking or erroring computation can never wedge the key (its
        /// followers retry; one becomes the new leader).
        struct UnpinOnDrop<'a> {
            memo: &'a AccMemo,
            bits: &'a [u32],
            armed: bool,
        }
        impl Drop for UnpinOnDrop<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                // poison-tolerant: this runs during panic unwinds, where an
                // unwrap would double-panic and abort the worker's process
                let mut m = write_recover(&self.memo.map);
                // remove only if the slot is still this leader's in-flight
                // entry — a concurrent insert()/extend() may have replaced
                // it with a Done value (resolving our waiters), which must
                // not be evicted
                let still_in_flight = matches!(m.get(self.bits), Some(Slot::InFlight(_)));
                if still_in_flight {
                    if let Some(Slot::InFlight(f)) = m.remove(self.bits) {
                        f.finish(None);
                    }
                }
            }
        }

        loop {
            // fast path: finished value under the shared read lock — the
            // steady-state of a converged search is hit-only and must not
            // contend on the write lock or allocate an owned key
            if let Some(Slot::Done { v, touched }) = self.map.read().unwrap().get(bits) {
                self.touch(touched);
                let v = *v;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((v, true));
            }
            // miss or in-flight: re-check and claim under one write lock
            // (entry API: lookup and insert in one borrow)
            let flight = {
                let mut m = self.map.write().unwrap();
                match m.entry(bits.to_vec()) {
                    std::collections::hash_map::Entry::Occupied(e) => match e.get() {
                        Slot::Done { v, touched } => {
                            self.touch(touched);
                            let v = *v;
                            self.hits.fetch_add(1, Ordering::Relaxed);
                            return Ok((v, true));
                        }
                        Slot::InFlight(f) => Some(f.clone()),
                    },
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(Slot::InFlight(Arc::new(Flight::default())));
                        None
                    }
                }
            };
            if let Some(f) = flight {
                // follower: block on the leader; retry (possibly as the new
                // leader) if it failed
                match f.wait() {
                    Some(v) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Ok((v, true));
                    }
                    None => continue,
                }
            }
            // leader: compute outside the lock, publish, wake followers
            self.misses.fetch_add(1, Ordering::Relaxed);
            let mut guard = UnpinOnDrop { memo: self, bits, armed: true };
            let result = compute();
            match result {
                Ok(v) => {
                    guard.armed = false;
                    let mut m = self.map.write().unwrap();
                    let old = m.insert(bits.to_vec(), self.done(v));
                    if let Some(Slot::InFlight(f)) = old {
                        f.finish(Some(v));
                    }
                    self.evict_excess(&mut m);
                    return Ok((v, false));
                }
                // the armed guard unpins the key and wakes waiters
                Err(e) => return Err(e),
            }
        }
    }

    /// Batch single-flight lookup-or-compute: the whole-batch extension of
    /// [`AccMemo::get_or_compute`], the protocol behind
    /// `EnvCore::accuracy_batch`. Returns one `(value, was_cached)` pair per
    /// input key, in input order (duplicate keys resolve to the same value).
    ///
    /// Under **one** write lock the caller walks every distinct key and
    /// becomes the leader of *all* currently-unclaimed misses at once —
    /// `compute` then receives exactly that miss list (cache hits and keys
    /// another thread already has in flight shrink the batch) and must
    /// return one value per miss, which lets the computation amortize K
    /// misses into one device execution. Keys found in flight are waited on
    /// *after* our own compute finishes (racers coalesce per-key, exactly
    /// as in the scalar protocol); a failed or panicking leader unpins
    /// **every** key it claimed and wakes their waiters, so one batch
    /// failure never wedges any key — a waiter (or a retry loop iteration
    /// here) re-claims each failed key as a new, possibly smaller, batch.
    ///
    /// `compute` must not re-enter the memo for any of the keys it was
    /// handed: they are claimed in-flight by the current thread and a
    /// nested lookup would deadlock on itself.
    ///
    /// Hit/miss counters tick per *distinct* key per call: each resolved
    /// distinct key counts one hit (cached/coalesced) or one miss (computed
    /// here), matching one scalar `get_or_compute` per distinct key.
    pub fn get_or_compute_batch<F>(&self, keys: &[Vec<u32>], mut compute: F)
                                   -> Result<Vec<(f64, bool)>>
    where
        F: FnMut(&[Vec<u32>]) -> Result<Vec<f64>>,
    {
        /// Failure guard for a batch leader: while armed, dropping it
        /// unpins every claimed key and wakes their waiters with "failed"
        /// (the batch analogue of the scalar `UnpinOnDrop`).
        struct UnpinBatchOnDrop<'a> {
            memo: &'a AccMemo,
            claimed: &'a [Vec<u32>],
            armed: bool,
        }
        impl Drop for UnpinBatchOnDrop<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                // poison-tolerant: runs during panic unwinds (see UnpinOnDrop)
                let mut m = write_recover(&self.memo.map);
                for k in self.claimed {
                    // remove only our own in-flight entry; a concurrent
                    // insert()/extend() may have published a Done value
                    // (resolving our waiters), which must survive
                    if matches!(m.get(k.as_slice()), Some(Slot::InFlight(_))) {
                        if let Some(Slot::InFlight(f)) = m.remove(k.as_slice()) {
                            f.finish(None);
                        }
                    }
                }
            }
        }

        let mut out: Vec<Option<(f64, bool)>> = vec![None; keys.len()];
        // Each round claims/coalesces every unresolved key; a round leaves
        // keys unresolved only when another leader's flight failed, so the
        // loop terminates (some thread makes progress on every failure).
        while out.iter().any(Option::is_none) {
            // fast prepass under the shared read lock: the steady state of
            // a converged search is an all-hits slate and must not contend
            // on the write lock or clone a single key (mirrors the scalar
            // fast path). First occurrences only — duplicates copy below.
            {
                let m = self.map.read().unwrap();
                for i in 0..keys.len() {
                    if out[i].is_some() || keys[..i].contains(&keys[i]) {
                        continue;
                    }
                    if let Some(Slot::Done { v, touched }) = m.get(keys[i].as_slice()) {
                        self.touch(touched);
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        out[i] = Some((*v, true));
                    }
                }
            }
            for i in 0..keys.len() {
                if out[i].is_none() {
                    if let Some(j) = keys[..i].iter().position(|k| k == &keys[i]) {
                        out[i] = out[j];
                    }
                }
            }
            if out.iter().all(Option::is_some) {
                break;
            }
            let mut claimed: Vec<Vec<u32>> = Vec::new();
            let mut flights: Vec<(usize, Arc<Flight>)> = Vec::new();
            {
                let mut m = self.map.write().unwrap();
                for i in 0..keys.len() {
                    if out[i].is_some() {
                        continue;
                    }
                    // duplicate of an earlier unresolved key in this batch:
                    // it resolves with that occurrence (leader or follower)
                    if keys[..i].iter().enumerate().any(|(j, k)| out[j].is_none() && k == &keys[i])
                    {
                        continue;
                    }
                    match m.entry(keys[i].clone()) {
                        std::collections::hash_map::Entry::Occupied(e) => match e.get() {
                            Slot::Done { v, touched } => {
                                self.touch(touched);
                                self.hits.fetch_add(1, Ordering::Relaxed);
                                out[i] = Some((*v, true));
                            }
                            Slot::InFlight(f) => flights.push((i, f.clone())),
                        },
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(Slot::InFlight(Arc::new(Flight::default())));
                            claimed.push(keys[i].clone());
                        }
                    }
                }
            }
            // leader work first: our claims must publish before we block on
            // anyone else (no cycle — flights we wait on are owned by other
            // threads that never wait on ours to finish *their* compute)
            if !claimed.is_empty() {
                self.misses.fetch_add(claimed.len() as u64, Ordering::Relaxed);
                let mut guard = UnpinBatchOnDrop { memo: self, claimed: &claimed, armed: true };
                let vals = compute(&claimed)?;
                anyhow::ensure!(
                    vals.len() == claimed.len(),
                    "batch compute returned {} values for {} misses",
                    vals.len(),
                    claimed.len()
                );
                guard.armed = false;
                let mut m = self.map.write().unwrap();
                for (k, &v) in claimed.iter().zip(&vals) {
                    if let Some(Slot::InFlight(f)) = m.insert(k.clone(), self.done(v)) {
                        f.finish(Some(v));
                    }
                }
                self.evict_excess(&mut m);
                drop(m);
                for (i, k) in keys.iter().enumerate() {
                    if out[i].is_none() {
                        if let Some(pos) = claimed.iter().position(|c| c == k) {
                            out[i] = Some((vals[pos], false));
                        }
                    }
                }
            }
            // followers: coalesce on the other leaders' flights; a failed
            // flight leaves its key unresolved for the next round
            for (i, f) in flights {
                if let Some(v) = f.wait() {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    out[i] = Some((v, true));
                }
            }
            // resolve duplicates against their first occurrence (the one
            // that claimed or followed); still-None firsts retry next round
            for i in 0..keys.len() {
                if out[i].is_none() {
                    if let Some(j) = keys[..i].iter().position(|k| k == &keys[i]) {
                        out[i] = out[j];
                    }
                }
            }
        }
        Ok(out.into_iter().map(|o| o.expect("all resolved")).collect())
    }

    /// Insert an evaluated accuracy. Replacing another thread's in-flight
    /// entry resolves it with this value so its waiters wake instead of
    /// hanging.
    pub fn insert(&self, bits: &[u32], acc: f64) {
        let mut m = self.map.write().unwrap();
        let old = m.insert(bits.to_vec(), self.done(acc));
        if let Some(Slot::InFlight(f)) = old {
            f.finish(Some(acc));
        }
        self.evict_excess(&mut m);
    }

    /// Bulk-import finished entries (e.g. warming a fresh memo from the
    /// solution archive's snapshot of a previous run — see
    /// `serve::archive`). The capacity bound is enforced once at the end of
    /// the import, so a warm-start larger than the bound keeps the
    /// most-recently-imported entries.
    pub fn extend<I: IntoIterator<Item = (Vec<u32>, f64)>>(&self, entries: I) {
        let mut m = self.map.write().unwrap();
        for (k, v) in entries {
            if let Some(Slot::InFlight(f)) = m.insert(k, self.done(v)) {
                f.finish(Some(v));
            }
        }
        self.evict_excess(&mut m);
    }

    /// Snapshot of all finished (bits, accuracy) pairs, sorted by bits
    /// vector so the export is deterministic regardless of hash order (the
    /// archive persists a truncated prefix of this).
    pub fn entries(&self) -> Vec<(Vec<u32>, f64)> {
        let mut v: Vec<(Vec<u32>, f64)> = self
            .map
            .read()
            .unwrap()
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Done { v, .. } => Some((k.clone(), *v)),
                Slot::InFlight(_) => None,
            })
            .collect();
        v.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Up to `limit` finished (bits, accuracy) pairs ordered
    /// most-recently-touched first (ties broken by bits vector). This is
    /// the archive-persistence export: the prefix keeps the entries the
    /// search was actually revisiting, not an arbitrary lexicographic
    /// corner. Top-k, not clone-everything-and-sort: a warm daemon memo
    /// holds tens of thousands of entries and a job persists a few
    /// hundred, so the cutoff tick is found first (no key clones) and
    /// only entries at or above it are materialized.
    pub fn entries_by_recency(&self, limit: usize) -> Vec<(Vec<u32>, f64)> {
        if limit == 0 {
            return Vec::new();
        }
        let m = self.map.read().unwrap();
        let mut ticks: Vec<u64> = m
            .values()
            .filter_map(|s| match s {
                Slot::Done { touched, .. } => Some(touched.load(Ordering::Relaxed)),
                Slot::InFlight(_) => None,
            })
            .collect();
        if ticks.is_empty() {
            return Vec::new();
        }
        let cutoff = if ticks.len() <= limit {
            0
        } else {
            // the limit-th largest tick; concurrent touches only raise
            // ticks, so the second pass can select more than `limit`
            // (handled by the truncate), never fewer
            let idx = ticks.len() - limit;
            *ticks.select_nth_unstable(idx).1
        };
        let mut v: Vec<(u64, Vec<u32>, f64)> = m
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Done { v, touched } => {
                    let t = touched.load(Ordering::Relaxed);
                    (t >= cutoff).then(|| (t, k.clone(), *v))
                }
                Slot::InFlight(_) => None,
            })
            .collect();
        drop(m);
        v.sort_unstable_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        v.truncate(limit);
        v.into_iter().map(|(_, k, val)| (k, val)).collect()
    }

    /// Number of finished entries (in-flight computations excluded).
    pub fn len(&self) -> usize {
        self.map
            .read()
            .unwrap()
            .values()
            .filter(|s| matches!(s, Slot::Done { .. }))
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Configured bound on finished entries (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Finished entries dropped by the capacity bound since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

/// Speculation bookkeeping for the prefetching pipeline
/// (`coordinator::rollout`, `pipeline > 0`): which bits vectors were
/// enqueued speculatively and are still awaiting a real consumer, plus the
/// submitted/hit/wasted accounting `EnvStats` reports.
///
/// Protocol (all methods are `&self`; the ledger is shared through the env
/// core like [`AccMemo`]):
///
/// 1. the producer marks a candidate with [`SpecLedger::begin`] (refused if
///    already outstanding — no double speculation; a successful begin
///    counts into `submitted` *immediately*), and rolls a mark back with
///    [`SpecLedger::cancel`] if its dispatch was refused;
/// 2. the consuming rollout step [`SpecLedger::claim`]s every candidate it
///    actually evaluates — a claim of an outstanding key counts one hit;
/// 3. at the end of the search, [`SpecLedger::abandon`] counts everything
///    still outstanding as wasted.
///
/// Counting `submitted` at begin-time (not after the dispatch succeeds) is
/// what keeps the accounting race-free when producers and consumers share
/// one ledger: a key claimed in the begin→dispatch window has already been
/// counted, so `hits` can never outrun `submitted`, and a `cancel` that
/// loses that race (the key is gone) leaves the begin's count in place —
/// the key resolves as submitted+hit, exactly as if the dispatch had won.
///
/// Invariant (enforced in `rust/tests/pipeline_parity.rs` and the CI serve
/// smoke): `hits <= submitted` always, and `hits + wasted == submitted`
/// once the producer has abandoned. The values themselves are never stored
/// here — speculation is memo-warming only; the [`AccMemo`] stays the one
/// source of accuracy truth.
#[derive(Default)]
pub struct SpecLedger {
    outstanding: Mutex<HashSet<Vec<u32>>>,
    submitted: AtomicU64,
    hits: AtomicU64,
    wasted: AtomicU64,
}

impl SpecLedger {
    pub fn new() -> SpecLedger {
        SpecLedger::default()
    }

    /// Mark `bits` as speculated-outstanding and count it into `submitted`.
    /// `false` (no mark, no count) when it already is outstanding — the
    /// caller must then skip the duplicate.
    pub fn begin(&self, bits: &[u32]) -> bool {
        let inserted = self.outstanding.lock().unwrap().insert(bits.to_vec());
        if inserted {
            self.submitted.fetch_add(1, Ordering::Relaxed);
        }
        inserted
    }

    /// Roll back a [`SpecLedger::begin`] whose dispatch was refused (e.g.
    /// the in-flight cap): un-counts the key if it is still ours. If a
    /// concurrent [`SpecLedger::claim`] got there first, the begin's count
    /// stands (that key already resolved as submitted+hit) — see the
    /// race-freedom note in the type docs.
    pub fn cancel(&self, bits: &[u32]) {
        if self.outstanding.lock().unwrap().remove(bits) {
            self.submitted.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// A real consumer is evaluating `bits`: if it was outstanding, count a
    /// hit and clear it. Harmless no-op (returns false) otherwise.
    pub fn claim(&self, bits: &[u32]) -> bool {
        if self.outstanding.lock().unwrap().remove(bits) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// No consumer is coming: count everything still outstanding as wasted
    /// and clear the ledger (end of the pipelined search).
    pub fn abandon(&self) {
        let mut g = self.outstanding.lock().unwrap();
        self.wasted.fetch_add(g.len() as u64, Ordering::Relaxed);
        g.clear();
    }

    /// Speculated keys not yet claimed or abandoned.
    pub fn outstanding(&self) -> usize {
        self.outstanding.lock().unwrap().len()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn wasted(&self) -> u64 {
        self.wasted.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn chunks_preserve_order_and_balance() {
        let items: Vec<usize> = (0..10).collect();
        let chunks = chunk_evenly(items.clone(), 3);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], vec![0, 1, 2, 3]);
        assert_eq!(chunks[1], vec![4, 5, 6]);
        assert_eq!(chunks[2], vec![7, 8, 9]);
        let flat: Vec<usize> = chunks.into_iter().flatten().collect();
        assert_eq!(flat, items);
    }

    #[test]
    fn stripes_are_round_robin_and_index_tagged() {
        let lanes = stripe_evenly(vec!["a", "b", "c", "d", "e"], 2);
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0], vec![(0, "a"), (2, "c"), (4, "e")]);
        assert_eq!(lanes[1], vec![(1, "b"), (3, "d")]);
        // merging by the retained indices reproduces original order exactly
        let mut merged: Vec<(usize, &str)> = lanes.into_iter().flatten().collect();
        merged.sort_by_key(|(i, _)| *i);
        assert_eq!(merged.iter().map(|(_, s)| *s).collect::<Vec<_>>(), ["a", "b", "c", "d", "e"]);
    }

    #[test]
    fn stripes_keep_empty_lanes_and_n_one_is_identity() {
        let lanes = stripe_evenly(vec![10, 20], 4);
        assert_eq!(lanes.len(), 4, "empty lanes are kept");
        assert!(lanes[2].is_empty() && lanes[3].is_empty());
        let one = stripe_evenly(vec![1, 2, 3], 1);
        assert_eq!(one, vec![vec![(0, 1), (1, 2), (2, 3)]]);
    }

    #[test]
    fn chunks_more_shards_than_items() {
        let chunks = chunk_evenly(vec![1, 2], 5);
        assert_eq!(chunks, vec![vec![1], vec![2]]);
        assert!(chunk_evenly(Vec::<u8>::new(), 4).is_empty());
    }

    #[test]
    fn merge_order_is_shard_order_not_completion_order() {
        // earlier shards sleep longer, so completion order is reversed;
        // the merged output must still be in shard-index order
        let shards: Vec<u64> = (0..6).collect();
        let out = run_sharded(shards, |i, s| {
            std::thread::sleep(std::time::Duration::from_millis(30 - 5 * i as u64));
            Ok(s * 10)
        })
        .unwrap();
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50]);
    }

    #[test]
    fn first_failing_shard_error_wins() {
        let err = run_sharded(vec![0u32, 1, 2, 3], |i, _| {
            if i >= 2 {
                anyhow::bail!("shard {i} failed")
            }
            Ok(i)
        })
        .unwrap_err();
        assert!(err.to_string().contains("shard 2"), "{err}");
    }

    #[test]
    fn single_shard_runs_inline() {
        let out = run_sharded(vec![41u64], |i, s| Ok(s + i as u64 + 1)).unwrap();
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn get_or_compute_caches_and_recovers() {
        let memo = AccMemo::new();
        let (v, cached) = memo.get_or_compute(&[4, 2], || Ok(0.75)).unwrap();
        assert!(!cached);
        assert_eq!(v, 0.75);
        let (v2, cached2) = memo
            .get_or_compute(&[4, 2], || panic!("must not recompute a cached key"))
            .unwrap();
        assert!(cached2);
        assert_eq!(v2, 0.75);
        // a failed computation must not poison the key
        assert!(memo.get_or_compute(&[9], || anyhow::bail!("boom")).is_err());
        assert!(!memo.contains(&[9]), "failed compute must unpin the key");
        let (v3, cached3) = memo.get_or_compute(&[9], || Ok(0.5)).unwrap();
        assert!(!cached3);
        assert_eq!(v3, 0.5);
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn memo_counts_hits_across_threads() {
        let memo = Arc::new(AccMemo::new());
        memo.insert(&[4, 4], 0.9);
        let shards: Vec<u32> = (0..8).collect();
        run_sharded(shards, |_, _| {
            assert_eq!(memo.get(&[4, 4]), Some(0.9)); // hit
            if memo.get(&[2, 2]).is_none() {
                memo.insert(&[2, 2], 0.5); // racy insert: last write wins
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(memo.hits(), 8);
        assert!(memo.misses() >= 1);
        assert_eq!(memo.len(), 2);
        assert_eq!(memo.get(&[2, 2]), Some(0.5));
    }

    #[test]
    fn bounded_memo_evicts_least_recently_touched() {
        let memo = AccMemo::with_capacity(8);
        for i in 0..8u32 {
            memo.insert(&[i], i as f64 / 10.0);
        }
        assert_eq!(memo.len(), 8);
        assert_eq!(memo.evictions(), 0);
        // touch the first four so they are the most-recently-used half
        for i in 0..4u32 {
            assert!(memo.get(&[i]).is_some());
        }
        // pushing past the bound evicts down to 3/4 capacity = 6 entries,
        // dropping the least-recently-touched ones ([4] .. [6])
        memo.insert(&[100], 0.99);
        assert_eq!(memo.len(), 6);
        assert_eq!(memo.evictions(), 3);
        for i in 0..4u32 {
            assert!(memo.contains(&[i]), "recently touched [{i}] must survive");
        }
        assert!(memo.contains(&[100]), "the triggering insert must survive");
        assert!(!memo.contains(&[4]) && !memo.contains(&[5]) && !memo.contains(&[6]));
        // an evicted key recomputes transparently
        let (v, cached) = memo.get_or_compute(&[4], || Ok(0.4)).unwrap();
        assert!(!cached);
        assert_eq!(v, 0.4);
        // unbounded memo never evicts
        let unbounded = AccMemo::new();
        for i in 0..64u32 {
            unbounded.insert(&[i], 0.5);
        }
        assert_eq!(unbounded.len(), 64);
        assert_eq!(unbounded.evictions(), 0);
        assert_eq!(unbounded.capacity(), 0);
    }

    #[test]
    fn batch_partial_hits_shrink_the_compute() {
        let memo = AccMemo::new();
        memo.insert(&[1], 0.1);
        memo.insert(&[3], 0.3);
        // hits ([1], [3]) and an in-batch duplicate ([2] twice) must shrink
        // the miss list handed to compute to the distinct misses, in order
        let keys = vec![vec![1u32], vec![2], vec![3], vec![2], vec![4]];
        let res = memo
            .get_or_compute_batch(&keys, |misses| {
                assert_eq!(misses, &[vec![2u32], vec![4]]);
                Ok(vec![0.2, 0.4])
            })
            .unwrap();
        assert_eq!(
            res,
            vec![(0.1, true), (0.2, false), (0.3, true), (0.2, false), (0.4, false)]
        );
        // everything is now cached: compute must not run at all
        let res2 = memo
            .get_or_compute_batch(&keys, |_| panic!("fully cached batch must not compute"))
            .unwrap();
        assert!(res2.iter().all(|&(_, cached)| cached));
        assert_eq!(res2[4].0, 0.4);
        assert_eq!(memo.len(), 4);
    }

    #[test]
    fn batch_empty_and_singleton() {
        let memo = AccMemo::new();
        assert!(memo.get_or_compute_batch(&[], |_| unreachable!()).unwrap().is_empty());
        let res = memo.get_or_compute_batch(&[vec![9u32]], |m| {
            assert_eq!(m.len(), 1);
            Ok(vec![0.9])
        });
        assert_eq!(res.unwrap(), vec![(0.9, false)]);
    }

    #[test]
    fn batch_failed_leader_unpins_every_claimed_key() {
        let memo = AccMemo::new();
        memo.insert(&[1], 0.1);
        let keys = vec![vec![1u32], vec![5], vec![6]];
        let err = memo.get_or_compute_batch(&keys, |_| anyhow::bail!("device fell over"));
        assert!(err.is_err());
        // every claimed key must be unpinned and retryable; the hit is kept
        assert!(!memo.contains(&[5]) && !memo.contains(&[6]));
        assert!(memo.contains(&[1]));
        let res = memo
            .get_or_compute_batch(&keys, |misses| {
                assert_eq!(misses, &[vec![5u32], vec![6]]);
                Ok(vec![0.5, 0.6])
            })
            .unwrap();
        assert_eq!(res[1], (0.5, false));
        assert_eq!(res[2], (0.6, false));
    }

    #[test]
    fn batch_panicking_leader_unpins_every_claimed_key() {
        let memo = AccMemo::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = memo.get_or_compute_batch(&[vec![5u32], vec![6]], |_| panic!("boom"));
        }));
        assert!(r.is_err());
        assert!(!memo.contains(&[5]) && !memo.contains(&[6]));
        let res = memo
            .get_or_compute_batch(&[vec![5u32], vec![6]], |m| {
                Ok(m.iter().map(|k| k[0] as f64 / 10.0).collect())
            })
            .unwrap();
        assert_eq!(res, vec![(0.5, false), (0.6, false)]);
    }

    #[test]
    fn batch_wrong_compute_arity_is_an_error_not_a_wedge() {
        let memo = AccMemo::new();
        let err = memo.get_or_compute_batch(&[vec![5u32], vec![6]], |_| Ok(vec![0.5]));
        assert!(err.is_err());
        // the arity-check failure path must unpin like any other failure
        assert!(!memo.contains(&[5]) && !memo.contains(&[6]));
        assert!(memo
            .get_or_compute_batch(&[vec![5u32], vec![6]], |_| Ok(vec![0.5, 0.6]))
            .is_ok());
    }

    #[test]
    fn batch_coalesces_with_scalar_inflight() {
        // a scalar leader holds [7] in flight; a batch containing [7] must
        // compute only its own miss and coalesce on the leader's value
        let memo = Arc::new(AccMemo::new());
        let m2 = memo.clone();
        let leader = std::thread::spawn(move || {
            m2.get_or_compute(&[7], || {
                std::thread::sleep(std::time::Duration::from_millis(60));
                Ok(0.7)
            })
            .unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(15));
        let res = memo
            .get_or_compute_batch(&[vec![7u32], vec![8]], |misses| {
                assert_eq!(misses, &[vec![8u32]], "in-flight key must not be re-claimed");
                Ok(vec![0.8])
            })
            .unwrap();
        assert_eq!(res, vec![(0.7, true), (0.8, false)]);
        assert_eq!(leader.join().unwrap(), (0.7, false));
    }

    #[test]
    fn concurrent_batches_compute_each_key_once() {
        use std::sync::atomic::AtomicUsize;
        let memo = Arc::new(AccMemo::new());
        let computes = Arc::new(AtomicUsize::new(0));
        // 8 threads race overlapping 4-key windows over 11 keys; the batch
        // claims must partition the misses: every key computed exactly once
        let shards: Vec<u32> = (0..8).collect();
        run_sharded(shards, |_, s| {
            let keys: Vec<Vec<u32>> = (s..s + 4).map(|k| vec![k]).collect();
            let res = memo.get_or_compute_batch(&keys, |misses| {
                computes.fetch_add(misses.len(), Ordering::Relaxed);
                Ok(misses.iter().map(|k| k[0] as f64).collect())
            })?;
            for (i, (v, _)) in res.iter().enumerate() {
                assert_eq!(*v, (s + i as u32) as f64);
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(computes.load(Ordering::Relaxed), 11, "each distinct key exactly once");
        assert_eq!(memo.len(), 11);
        assert_eq!(memo.misses(), 11);
    }

    #[test]
    fn memo_entries_export_is_sorted() {
        let memo = AccMemo::with_capacity(16);
        memo.insert(&[8, 2], 0.7);
        memo.insert(&[2, 8], 0.6);
        memo.insert(&[4, 4], 0.9);
        let e = memo.entries();
        let keys: Vec<Vec<u32>> = e.iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec![vec![2, 8], vec![4, 4], vec![8, 2]]);
    }

    #[test]
    fn recency_export_leads_with_recently_touched() {
        let memo = AccMemo::with_capacity(16);
        memo.insert(&[1, 1], 0.1);
        memo.insert(&[2, 2], 0.2);
        memo.insert(&[3, 3], 0.3);
        // re-touch the oldest entry: it must lead the recency export even
        // though it sorts first lexicographically too — so also check the
        // untouched pair ordering flips vs insertion
        assert_eq!(memo.get(&[1, 1]), Some(0.1));
        let keys: Vec<Vec<u32>> =
            memo.entries_by_recency(10).into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![vec![1, 1], vec![3, 3], vec![2, 2]]);
        // top-k truncation keeps the most recent, drops the stalest
        let top2: Vec<Vec<u32>> =
            memo.entries_by_recency(2).into_iter().map(|(k, _)| k).collect();
        assert_eq!(top2, vec![vec![1, 1], vec![3, 3]]);
        assert!(memo.entries_by_recency(0).is_empty());
    }

    #[test]
    fn default_shards_clamps_to_units() {
        assert_eq!(default_shards(1), 1);
        assert!(default_shards(1024) >= 1);
        assert!(default_shards(2) <= 2);
    }

    #[test]
    fn spec_ledger_accounting_balances() {
        let l = SpecLedger::new();
        // begin twice: the duplicate is refused and counted once
        assert!(l.begin(&[4, 4]));
        assert!(!l.begin(&[4, 4]));
        assert!(l.begin(&[2, 8]));
        assert!(l.begin(&[8, 2]));
        assert_eq!(l.submitted(), 3, "each successful begin counts immediately");
        l.cancel(&[8, 2]); // [8,2]'s dispatch was refused: un-counted
        assert_eq!((l.outstanding(), l.submitted()), (2, 2));
        // a consumer claims one (hit) and an unspeculated key (no-op)
        assert!(l.claim(&[4, 4]));
        assert!(!l.claim(&[6, 6]));
        assert!(!l.claim(&[4, 4]), "a claim clears the key");
        // a cancel that lost the race to a claim must NOT un-count: the
        // key already resolved as submitted+hit
        l.cancel(&[4, 4]);
        assert_eq!(l.submitted(), 2);
        // end of search: the unclaimed remainder is wasted
        l.abandon();
        assert_eq!(l.outstanding(), 0);
        assert_eq!((l.submitted(), l.hits(), l.wasted()), (2, 1, 1));
        assert!(l.hits() <= l.submitted());
        assert_eq!(l.hits() + l.wasted(), l.submitted());
    }

    #[test]
    fn spec_ledger_is_concurrency_safe() {
        let l = Arc::new(SpecLedger::new());
        // 8 threads race begin/claim/cancel on overlapping keys; every
        // surviving begin resolves as exactly one hit or one wasted
        run_sharded((0..8u32).collect::<Vec<_>>(), |_, s| {
            for k in s..s + 4 {
                l.begin(&[k]);
            }
            for k in s..s + 2 {
                l.claim(&[k]);
            }
            l.cancel(&[s + 3]); // may race another window's claim of s+3
            Ok(())
        })
        .unwrap();
        l.abandon();
        assert_eq!(l.hits() + l.wasted(), l.submitted());
        assert!(l.hits() <= l.submitted());
    }
}
