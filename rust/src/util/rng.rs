//! Deterministic PRNG (PCG32 + SplitMix64 seeding).
//!
//! The `rand` crate is unavailable offline (DESIGN.md §9). Everything random
//! in the coordinator — action sampling, synthetic data generation, seed
//! derivation — goes through this generator so whole search runs replay
//! bit-exactly from a single seed.

/// PCG32 (XSH-RR variant), state seeded through SplitMix64.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-episode / per-dataset seeding).
    pub fn derive(&self, stream: u64) -> Pcg32 {
        Pcg32::new(self.state ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough method for our uses.
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f32 {
        let u1 = (1.0 - self.next_f64()) as f32; // avoid ln(0)
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical over zero weights");
        let mut t = self.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg32::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::new(11);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
