//! Bench harness (criterion is unavailable offline, DESIGN.md §9).
//!
//! Plain `harness = false` bench mains call [`Bench::run`] per case: warmup,
//! timed iterations, mean/p50/p95 reporting, and a JSON record appended to
//! `target/bench_results.json` so the experiment harness can diff runs.

use std::time::{Duration, Instant};

use super::json::Json;

pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
    results: Vec<Json>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            target_time: Duration::from_secs(2),
            results: Vec::new(),
        }
    }

    /// Time `f` and report; returns the stats for programmatic use.
    pub fn case<F: FnMut()>(&mut self, case: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.target_time && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        // total_cmp: a pathological timer reading must not panic the harness
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        let stats = Stats {
            iters: n,
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            p50_ns: samples[n / 2],
            p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples[0],
        };
        println!(
            "{:<44} {:>12} (p50 {:>12}, p95 {:>12}, n={})",
            format!("{}/{}", self.name, case),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
            n
        );
        self.results.push(Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("case", Json::Str(case.to_string())),
            ("mean_ns", Json::Num(stats.mean_ns)),
            ("p50_ns", Json::Num(stats.p50_ns)),
            ("p95_ns", Json::Num(stats.p95_ns)),
            ("min_ns", Json::Num(stats.min_ns)),
            ("iters", Json::Num(n as f64)),
        ]));
        stats
    }

    /// Where bench records go: `$BENCH_OUT` when set (CI / the perf harness
    /// redirect runs to e.g. `BENCH_1.json`), else `target/bench_results.json`.
    pub fn out_path() -> std::path::PathBuf {
        match std::env::var("BENCH_OUT") {
            Ok(p) if !p.trim().is_empty() => std::path::PathBuf::from(p),
            _ => std::path::PathBuf::from("target/bench_results.json"),
        }
    }

    /// Append this bench's records to [`Bench::out_path`] (JSON lines).
    pub fn flush(&self) {
        let path = Self::out_path();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&r.dump());
            out.push('\n');
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            let _ = f.write_all(out.as_bytes());
        }
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        self.flush();
    }
}
