//! Poison-tolerant mutex acquisition.
//!
//! A thread panicking while holding a `std::sync::Mutex` poisons it, and
//! every later `lock().unwrap()` propagates the panic — one crashed job
//! could wedge every status read in the serve scheduler. All the state
//! guarded that way here is kept consistent by construction (each critical
//! section is a small field update with no tearable multi-step invariant),
//! so the right response to poison is to keep going with the data, not to
//! cascade the panic.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock `m`, recovering the guard if a previous holder panicked. Use this
/// instead of `lock().unwrap()` wherever the protected state stays valid
/// across a panic (single-field updates, monotonic counters, status maps).
pub fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`lock_recover`] for `RwLock` readers.
pub fn read_recover<T: ?Sized>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`lock_recover`] for `RwLock` writers. Drop guards that must run during
/// a panic unwind (e.g. the memo's in-flight unpinning) use this: an
/// `unwrap` there would double-panic and abort the process.
pub fn write_recover<T: ?Sized>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        // poison it: panic while holding the guard
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "precondition: the mutex is poisoned");
        assert_eq!(*lock_recover(&m), 7, "the data survives the panic");
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn plain_lock_passes_through() {
        let m = Mutex::new(String::from("x"));
        lock_recover(&m).push('y');
        assert_eq!(*lock_recover(&m), "xy");
    }

    #[test]
    fn recovers_a_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(3u32));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison");
        })
        .join();
        assert!(l.read().is_err(), "precondition: the rwlock is poisoned");
        assert_eq!(*read_recover(&l), 3);
        *write_recover(&l) = 4;
        assert_eq!(*read_recover(&l), 4);
    }
}
