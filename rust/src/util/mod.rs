//! In-repo substrates for what the offline environment lacks (DESIGN.md §9):
//! JSON, CLI parsing, deterministic PRNG, and a bench harness.

pub mod benchkit;
pub mod cli;
pub mod fnv;
pub mod json;
pub mod lock;
pub mod rng;
pub mod sha256;
pub mod signals;

pub use lock::{lock_recover, read_recover, write_recover};
