//! Declarative CLI argument parser (clap is unavailable offline, DESIGN.md §9).
//!
//! Grammar: `releq <subcommand> [positional...] [--flag value | --switch]...`

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (element 0 = program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut a = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare switch
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(name.to_string(), v);
                } else {
                    a.switches.push(name.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn str_of(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn f64_of(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn usize_of(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn u64_of(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(
            std::iter::once("releq".to_string()).chain(s.split_whitespace().map(String::from)),
        )
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("search --net lenet --episodes 500 --verbose");
        assert_eq!(a.subcommand, "search");
        assert_eq!(a.str_of("net", ""), "lenet");
        assert_eq!(a.usize_of("episodes", 0), 500);
        assert!(a.has("verbose"));
    }

    #[test]
    fn eq_form_and_positional() {
        let a = parse("exp table2 --seed=7");
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.u64_of("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.f64_of("lr", 0.05), 0.05);
        assert_eq!(a.str_of("net", "lenet"), "lenet");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, "");
        assert!(a.has("help"));
    }
}
