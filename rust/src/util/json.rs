//! Minimal JSON parser/serializer.
//!
//! The build environment is offline and `serde_json` is unavailable (see
//! DESIGN.md §9), so the manifest and metrics files are handled by this
//! small, strict-enough JSON implementation. Supports the full JSON grammar
//! except exotic number formats; numbers are f64 (adequate: the manifest
//! carries shapes/offsets well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn u(&self, key: &str) -> usize {
        self.req(key).as_usize().unwrap_or_else(|| panic!("key `{key}` not a number"))
    }

    pub fn f(&self, key: &str) -> f64 {
        self.req(key).as_f64().unwrap_or_else(|| panic!("key `{key}` not a number"))
    }

    pub fn s(&self, key: &str) -> &str {
        self.req(key).as_str().unwrap_or_else(|| panic!("key `{key}` not a string"))
    }

    // -- construction / serialization -----------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_u32(v: &[u32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/inf; `format!("{n}")` would emit the
                    // bare token `NaN`, making the whole document
                    // unparseable. `null` keeps every emitted document
                    // valid (the JSON.stringify convention).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (handles UTF-8 transparently)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].s("b"), "x");
        assert_eq!(j.req("c"), &Json::Null);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s"],"n":-3,"o":{"t":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".to_string())
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // a diverged search can produce NaN accuracies; the emitted
        // document must still parse
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        let doc = Json::obj(vec![("acc", Json::Num(f64::NAN))]);
        let reparsed = Json::parse(&doc.dump()).unwrap();
        assert_eq!(reparsed.req("acc"), &Json::Null);
    }
}
