//! Minimal SIGTERM/SIGINT latch — dependency-free graceful shutdown.
//!
//! The serve daemon and fleet router are long-running processes that hold
//! durable state (job journal, checkpoints, archives). A plain Ctrl-C or a
//! supervisor's SIGTERM must not tear the process down mid-write; instead
//! both servers install this latch and a watcher thread turns "signal
//! pending" into the same orderly drain the `POST /v1/shutdown` endpoint
//! performs: cancel running searches (they flush a final checkpoint at
//! their last update boundary), leave queued jobs journaled for the next
//! process, save the archive, stop accepting.
//!
//! No `signal_hook`/`libc` crates exist in the build environment, so this
//! module talks to `signal(2)` directly through one `extern "C"` binding.
//! The handler body is async-signal-safe: a single relaxed atomic store.
//! Everything else (draining, file writes) happens on a normal thread that
//! polls [`triggered`]. On non-unix targets installation is a no-op and the
//! latch never fires.

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the signal handler; read by watcher threads.
static TERM_PENDING: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::TERM_PENDING;
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        /// `signal(2)` — returns the previous handler (or SIG_ERR, which we
        /// can only ignore: a failed install leaves the default handler,
        /// i.e. exactly the pre-PR behavior).
        fn signal(signum: i32, handler: usize) -> usize;
    }

    /// The registered handler: one async-signal-safe atomic store.
    extern "C" fn on_term(_signum: i32) {
        TERM_PENDING.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_term as usize);
            signal(SIGTERM, on_term as usize);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install the SIGINT/SIGTERM latch handlers. Idempotent; a no-op off unix.
pub fn install() {
    imp::install();
}

/// Has a termination signal arrived since [`install`]?
pub fn triggered() -> bool {
    TERM_PENDING.load(Ordering::Relaxed)
}

/// Test hook: arm or clear the latch without delivering a real signal (the
/// stub tier exercises the watcher path in-process).
pub fn set_pending(v: bool) {
    TERM_PENDING.store(v, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latch_arms_and_clears() {
        set_pending(false);
        assert!(!triggered());
        set_pending(true);
        assert!(triggered());
        set_pending(false);
        assert!(!triggered());
    }

    #[test]
    fn install_is_idempotent() {
        install();
        install();
    }
}
