//! FNV-1a hashing — the repo's one stable hash (unlike `DefaultHasher`,
//! its output is fixed across Rust releases and platforms, so values
//! derived from it can be persisted: archive fingerprints outlive compiler
//! upgrades, and the env's retrain cursor stays bit-reproducible).
//!
//! Two folding granularities share the constants:
//!
//! * byte-wise ([`Fnv::write_bytes`] and the typed writers on top of it) —
//!   the standard FNV-1a, used by the serve archive's config fingerprints;
//! * word-wise ([`Fnv::write_u32_words`], one fold per `u32`) — the
//!   variant `EnvCore::bits_cursor` has used since PR 2; kept distinct for
//!   bit-compatibility of every memoized accuracy value.

/// Streaming FNV-1a hasher.
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }
}

impl Fnv {
    pub fn new() -> Fnv {
        Fnv::default()
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Fnv {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
        self
    }

    pub fn write_u64(&mut self, x: u64) -> &mut Fnv {
        self.write_bytes(&x.to_le_bytes())
    }

    pub fn write_f64(&mut self, x: f64) -> &mut Fnv {
        self.write_u64(x.to_bits())
    }

    pub fn write_str(&mut self, s: &str) -> &mut Fnv {
        // length-prefix so ("ab","c") and ("a","bc") differ
        self.write_u64(s.len() as u64).write_bytes(s.as_bytes())
    }

    /// Word-wise folding: one xor-multiply per `u32`, not per byte.
    pub fn write_u32_words(&mut self, words: &[u32]) -> &mut Fnv {
        for &w in words {
            self.0 ^= w as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
        self
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_wise_matches_the_historic_bits_cursor_fold() {
        // pinned against the inline loop EnvCore::bits_cursor shipped in
        // PR 2 — the memoized accuracy values depend on these exact hashes
        let reference = |bits: &[u32]| {
            let mut h: u64 = 0xcbf29ce484222325;
            for &b in bits {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h
        };
        for bits in [&[8u32, 4, 4, 8][..], &[2][..], &[][..]] {
            assert_eq!(Fnv::new().write_u32_words(bits).finish(), reference(bits));
        }
    }

    #[test]
    fn length_prefix_separates_string_splits() {
        let h = |parts: &[&str]| {
            let mut f = Fnv::new();
            for p in parts {
                f.write_str(p);
            }
            f.finish()
        };
        assert_ne!(h(&["ab", "c"]), h(&["a", "bc"]));
        assert_ne!(h(&["ab"]), h(&["ab", ""]));
    }
}
